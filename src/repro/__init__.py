"""Reproduction package for "On Scheduling Ring-All-Reduce Learning Jobs
in Multi-Tenant GPU Clusters with Communication Contention".

Subpackages:

* ``repro.core``    -- contention model, policy registry, simulator, theory
* ``repro.dist``    -- RAR collectives, sharding rules, train/serve steps
* ``repro.models``  -- the 10 assigned architectures (6 families)
* ``repro.kernels`` -- Pallas TPU kernels (interpret mode on CPU)
* ``repro.launch``  -- dry-run / train / serve / scheduler-launch drivers

Importing ``repro`` (or any submodule) applies the jax forward-compat
shims in :mod:`repro._compat` so the whole tree is written once against
the modern ``jax.shard_map`` / ``jax.set_mesh`` surface.
"""
from repro import _compat as _compat  # noqa: F401  (applies jax shims)
