from repro.data.pipeline import DataConfig, batch_iterator, make_batch

__all__ = ["DataConfig", "batch_iterator", "make_batch"]
