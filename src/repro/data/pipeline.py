"""Synthetic data pipeline: deterministic, shardable, family-aware.

Production shape: an infinite iterator of global batches keyed by step, so
every host can regenerate its shard without coordination (the same property
a deterministic tf.data/grain pipeline gives you).  Token streams follow a
Zipf distribution (more realistic softmax/router load than uniform);
modality stubs (patches/frames) are unit Gaussians.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.models.config import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.3


def _tokens(rng: np.random.Generator, shape, vocab: int, a: float):
    z = rng.zipf(a, size=shape)
    return ((z - 1) % vocab).astype(np.int32)


def make_batch(cfg: ModelConfig, shape: InputShape, step: int,
               data_cfg: DataConfig = DataConfig(),
               batch_override: int | None = None) -> dict:
    """Deterministic global batch for (arch, shape, step)."""
    rng = np.random.default_rng((data_cfg.seed, step, hash(cfg.name) & 0xFFFF))
    B = batch_override or shape.global_batch
    S = shape.seq_len
    if cfg.family == "vlm":
        return {
            "tokens": _tokens(rng, (B, S - cfg.n_patches), cfg.vocab,
                              data_cfg.zipf_a),
            "patches": rng.standard_normal(
                (B, cfg.n_patches, cfg.d_model), dtype=np.float32),
        }
    if cfg.family == "audio":
        return {
            "frames": rng.standard_normal(
                (B, cfg.enc_frames, cfg.d_model), dtype=np.float32),
            "tokens": _tokens(rng, (B, S), cfg.vocab, data_cfg.zipf_a),
        }
    return {"tokens": _tokens(rng, (B, S), cfg.vocab, data_cfg.zipf_a)}


def batch_iterator(cfg: ModelConfig, shape: InputShape,
                   data_cfg: DataConfig = DataConfig(),
                   batch_override: int | None = None) -> Iterator[dict]:
    step = 0
    while True:
        yield make_batch(cfg, shape, step, data_cfg, batch_override)
        step += 1
