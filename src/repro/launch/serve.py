"""Batched serving driver: prefill a prompt batch, then decode greedily.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch llama3.2-1b --reduced --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.dist.steps import make_serve_step
    from repro.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    max_seq = args.prompt_len + args.gen
    model = build_model(cfg, max_seq=max_seq)
    params = model.init(jax.random.PRNGKey(0))

    B = args.batch
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt_len)),
                         jnp.int32)
    if cfg.family == "vlm":
        raise SystemExit("vlm serving needs patch inputs; use examples/")
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)), jnp.float32)

    serve = jax.jit(make_serve_step(model))
    cache = model.init_cache(B, max_seq)
    if cfg.family == "audio":
        # run the encoder once and pin enc_out into the cache
        cache["enc_out"] = jax.jit(model.encode)(params, extra["frames"])
    # prefill by stepping the prompt through the cache (keeps one code path
    # for recurrent and attention families alike)
    t0 = time.time()
    tok = prompt[:, 0]
    for pos in range(args.prompt_len - 1):
        _, _, cache = serve(params, cache, prompt[:, pos],
                            jnp.full((B,), pos, jnp.int32))
    tok = prompt[:, -1]
    prefill_t = time.time() - t0

    out = []
    t0 = time.time()
    for i in range(args.gen):
        pos = args.prompt_len - 1 + i
        tok, logits, cache = serve(params, cache, tok,
                                   jnp.full((B,), pos, jnp.int32))
        out.append(np.asarray(tok))
    gen_t = time.time() - t0
    gen = np.stack(out, 1)
    print(f"[serve] {cfg.name}: batch {B}, prompt {args.prompt_len}, "
          f"generated {args.gen} tokens/seq")
    print(f"[serve] prefill {prefill_t:.2f}s, decode {gen_t:.2f}s "
          f"({B*args.gen/max(gen_t,1e-9):.1f} tok/s)")
    print(f"[serve] sample tokens (seq 0): {gen[0][:16].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)


if __name__ == "__main__":
    main()
