"""Scheduler-integrated multi-job launcher: SJF-BCO placing *real* JAX
RAR training jobs onto device slices.

This is the paper's full loop made executable: a multi-tenant "cluster" of
host devices grouped into servers, a queue of RAR data-parallel training
jobs (reduced archs), SJF-BCO (or a baseline policy) deciding placement and
order, and each job actually training with the explicit ring-all-reduce
collective on a mesh built from exactly the devices the scheduler assigned.

On the CPU container jobs execute sequentially (one process), so wall-clock
contention is not physical; the simulator provides the contention-aware
makespan for the chosen placement, and the launcher proves the placements
are *executable* (each job really trains on its assigned slice).  On a real
TPU/GPU cluster each job would be launched concurrently on its slice.

    PYTHONPATH=src python -m repro.launch.sched_launch \
        --devices 8 --servers 2 --jobs 6 --policy sjf-bco --steps 4
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--policy", default="sjf-bco",
                    choices=("sjf-bco", "ff", "ls", "rand", "reserved",
                             "sjf-bco-adaptive"))
    ap.add_argument("--steps", type=int, default=4,
                    help="real train steps per job (F_j for the simulator "
                         "is scaled from this)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import Cluster, Job, ScheduleRequest, get_policy, simulate
    try:
        from repro.dist.steps import make_rar_train_step
    except ImportError:
        make_rar_train_step = None
    from repro.configs import ARCHS, get_config
    from repro.data import DataConfig, make_batch
    from repro.models import build_model
    from repro.models.config import InputShape
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig

    if args.devices % args.servers:
        raise SystemExit("--devices must divide evenly into --servers")
    per_srv = args.devices // args.servers
    cluster = Cluster(capacities=(per_srv,) * args.servers)

    # --- job queue: reduced archs, power-of-two ring widths ----------------
    rng = np.random.default_rng(args.seed)
    arch_pool = ["llama3.2-1b", "xlstm-350m", "internvl2-1b", "whisper-tiny",
                 "hymba-1.5b", "deepseek-moe-16b"]
    jobs, job_archs = [], []
    for j in range(args.jobs):
        g = int(rng.choice([1, 2, min(4, args.devices)]))
        arch = arch_pool[j % len(arch_pool)]
        jobs.append(Job(jid=j, num_gpus=g,
                        iters=int(rng.integers(1000, 3000)),
                        grad_size=float(rng.uniform(5e-4, 2e-3)),
                        batch=32, dt_fwd=3e-4,
                        dt_bwd=float(rng.uniform(4e-3, 1.2e-2))))
        job_archs.append(arch)

    # --- schedule -----------------------------------------------------------
    sched = get_policy(args.policy)(
        ScheduleRequest(cluster=cluster, jobs=jobs, horizon=100000))
    sim = simulate(cluster, jobs, sched.assignment)
    print(f"[sched] policy={args.policy}: simulated makespan "
          f"{sim.makespan:.0f} slots, avg JCT {sim.avg_jct:.0f}, "
          f"peak contention {sim.peak_contention}")
    if make_rar_train_step is None:
        for j, gpu_ids in sched.assignment:
            srvs = sorted({int(g) // per_srv for g in gpu_ids})
            print(f"[sched] job {j:2d} ({job_archs[j]:18s} "
                  f"w={len(gpu_ids)}) -> devices {list(map(int, gpu_ids))} "
                  f"(servers {srvs}) [start slot {sim.start[j]}, "
                  f"finish {sim.finish[j]}]")
        print("[sched] repro.dist unavailable in this environment; "
              "placements shown but not executed (see docs/ARCHITECTURE.md "
              "§repro.dist for what the substrate provides)")
        return

    # --- execute each job on its assigned device slice ---------------------
    devices = np.asarray(jax.devices())
    shape = InputShape("sched", args.seq, 0, "train")
    for j, gpu_ids in sched.assignment:
        arch = job_archs[j]
        cfg = get_config(arch).reduced()
        w = len(gpu_ids)
        mesh = Mesh(devices[np.asarray(gpu_ids)], ("data",))
        model = build_model(cfg, max_seq=args.seq)
        params = model.init(jax.random.PRNGKey(j))
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=args.steps)
        opt = adamw.init(ocfg, params)
        step_fn = make_rar_train_step(model, ocfg, mesh)
        batch_size = max(w, 2)
        t0 = time.time()
        loss0 = loss = None
        for step in range(args.steps):
            batch = make_batch(cfg, shape, step, DataConfig(seed=j),
                               batch_override=batch_size)
            batch = jax.tree.map(jax.numpy.asarray, batch)
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            loss0 = loss0 if loss0 is not None else loss
        srvs = sorted({int(g) // per_srv for g in gpu_ids})
        print(f"[sched] job {j:2d} ({arch:18s} w={w}) on devices "
              f"{list(map(int, gpu_ids))} (servers {srvs}): "
              f"loss {loss0:.3f}->{loss:.3f} in {time.time()-t0:.1f}s "
              f"[start slot {sim.start[j]}, finish {sim.finish[j]}]")

    print(f"[sched] all {len(jobs)} jobs executed on their assigned slices")


if __name__ == "__main__":
    main()
