"""Production mesh definitions (TPU v5e pods).

Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16); the "pod"
axis crosses DCN — the contended inter-server path of the paper's model
(DESIGN.md hardware-adaptation notes).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state; the dry-run sets
XLA_FLAGS before calling it.
"""
from __future__ import annotations

import jax

# v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
DCN_BW = 6.25e9                   # bytes/s per chip across pods (4x100G NIC
                                  # per 8-chip host) — the contended b^e path
POD_CHIPS = 256


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (forced) host devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))
