"""End-to-end training driver.

Runs real steps on the host devices (CPU container: 1 device; pass
--devices N to force a host mesh and exercise the RAR data-parallel mode).

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-1b --steps 300 --seq 256 --batch 8 --reduced

``--mode rar`` uses the paper-faithful explicit ring-all-reduce step;
``--mode pjit`` the production path.  Checkpoints land in --ckpt-dir.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke variant (CPU-friendly)")
    ap.add_argument("--mode", choices=("pjit", "rar"), default="pjit")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (sets XLA_FLAGS; must be "
                         "first jax use)")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import numpy as np

    from repro import ckpt
    from repro.configs import get_config
    from repro.data import DataConfig, make_batch
    from repro.dist.steps import make_rar_train_step, make_train_step
    from repro.models import build_model
    from repro.models.config import InputShape
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = InputShape("cli", args.seq, args.batch, "train")
    model = build_model(cfg, max_seq=args.seq)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
    print(f"[train] {cfg.name}{' (reduced)' if args.reduced else ''}: "
          f"{n_params/1e6:.1f}M params, {len(jax.devices())} device(s), "
          f"mode={args.mode}")

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                       total_steps=args.steps)
    opt = adamw.init(ocfg, params)

    if args.mode == "rar":
        n_dev = len(jax.devices())
        if args.batch % n_dev:
            sys.exit(f"batch {args.batch} must divide over {n_dev} devices")
        mesh = jax.make_mesh((n_dev,), ("data",))
        step_fn = make_rar_train_step(model, ocfg, mesh)
    else:
        step_fn = jax.jit(make_train_step(model, ocfg))

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = make_batch(cfg, shape, step, DataConfig())
        batch = jax.tree.map(jax.numpy.asarray, batch)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            path = os.path.join(args.ckpt_dir, f"{cfg.name}_{step}.npz")
            ckpt.save(path, params=params, opt_state=opt, step=step)
            print(f"[train] checkpoint -> {path}")

    first = np.mean(losses[: max(3, len(losses) // 10)])
    last = np.mean(losses[-max(3, len(losses) // 10):])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
