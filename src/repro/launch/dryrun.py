import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

This is the no-hardware proof that the distribution config is coherent:
every assigned architecture, at every assigned input shape, must lower and
compile against the production meshes —

    single-pod : (data=16, model=16)           = 256 chips
    multi-pod  : (pod=2, data=16, model=16)    = 512 chips

using ShapeDtypeStruct stand-ins (zero allocation).  For each pair we print
``memory_analysis()`` (does it fit 16 GB/chip?) and ``cost_analysis()``
FLOPs/bytes + parsed collective bytes (feeds EXPERIMENTS.md §Roofline).

Usage:
    python -m repro.launch.dryrun                      # full matrix, 1 pod
    python -m repro.launch.dryrun --multi-pod          # full matrix, 2 pods
    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    python -m repro.launch.dryrun --json out.json
"""
import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import (INPUT_SHAPES, ARCHS, cache_slots, get_config,
                           input_specs, supported_shapes)
from repro.dist import sharding as shd
from repro.dist.steps import make_serve_step, make_train_step
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


def build_jitted(arch: str, shape_name: str, mesh, *,
                 opt_overrides: dict | None = None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg, max_seq=min(shape.seq_len, 65536))
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = shd.named(shd.param_specs(params_sds, mesh, cfg), mesh)

    if shape.kind == "train":
        ocfg = AdamWConfig(**(opt_overrides or {}))
        opt_sds = jax.eval_shape(partial(adamw.init, ocfg), params_sds)
        o_shard = shd.named(shd.param_specs(opt_sds, mesh, cfg), mesh)
        batch_sds = input_specs(cfg, shape)
        b_shard = shd.named(shd.batch_specs(batch_sds, mesh), mesh)
        step = make_train_step(model, ocfg)
        # donate params+opt: the update is in-place on real hardware
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        return jitted, (params_sds, opt_sds, batch_sds)

    if shape.kind == "prefill":
        batch_sds = input_specs(cfg, shape)
        b_shard = shd.named(shd.batch_specs(batch_sds, mesh), mesh)

        def prefill_step(params, batch):
            # serving prefill: sampling needs only the last position — the
            # full [B, S, V] logits slab is never materialised as output
            logits = model.prefill(params, batch)
            if os.environ.get("REPRO_NAIVE_SHARDING"):
                return logits                      # baseline: full slab out
            return logits[:, -1, :]

        jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                         out_shardings=None)
        return jitted, (params_sds, batch_sds)

    # decode: one new token against a seq_len KV cache / recurrent state
    B = shape.global_batch
    slots = cache_slots(cfg, shape)
    cache_sds = jax.eval_shape(lambda: model.init_cache(B, slots))
    seq_shard = shape.name == "long_500k"
    c_spec = shd.cache_specs(cache_sds, mesh, seq_shard=seq_shard)
    c_shard = shd.named(c_spec, mesh)
    io_sds = input_specs(cfg, shape)
    tok_spec = shd.named(shd.batch_specs(io_sds, mesh), mesh)
    serve = make_serve_step(model)
    # donate the cache: decode updates it in place
    jitted = jax.jit(
        serve,
        in_shardings=(p_shard, c_shard, tok_spec["tok"], tok_spec["pos"]),
        out_shardings=(None, None, c_shard), donate_argnums=(1,))
    return jitted, (params_sds, cache_sds, io_sds["tok"], io_sds["pos"])


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             opt_overrides: dict | None = None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()
    # jax.set_mesh (not the bare `with mesh:`) exposes the abstract mesh to
    # trace time so in-model shard_hint constraints resolve axis names.
    with jax.set_mesh(mesh):
        jitted, args = build_jitted(arch, shape_name, mesh,
                                    opt_overrides=opt_overrides)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t1 = time.time()
    flops, byts = roofline.cost_terms(compiled)
    hlo_text = compiled.as_text()
    xf, xb = roofline.loop_cost_correction(hlo_text)
    flops += xf
    byts += xb
    stats = roofline.parse_collectives(
        hlo_text, pod_size=256 if multi_pod else 0)
    mem = roofline.memory_peak(compiled)
    rl = roofline.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        chips=mesh.devices.size,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=stats.total_bytes, collectives=stats,
        model_flops=roofline.model_step_flops(cfg, shape),
        per_device_hbm_peak=mem)
    row = rl.row()
    row["compile_s"] = round(t1 - t0, 1)
    row["collective_counts"] = stats.count_by_kind
    row["collective_bytes_by_kind"] = stats.bytes_by_kind
    row["dcn_bytes"] = stats.dcn_bytes
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"compile {row['compile_s']}s, "
              f"mem/device {mem/2**30:.2f} GiB, "
              f"flops/device {flops:.3e}, bytes/device {byts:.3e}, "
              f"collective {stats.total_bytes:.3e} B "
              f"({stats.total_count} ops), bottleneck={row['bottleneck']}")
        print(f"         memory_analysis: {compiled.memory_analysis()}")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="write rows to this file")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    rows, failures = [], []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else supported_shapes(cfg)
        for shape_name in shapes:
            if shape_name not in supported_shapes(cfg):
                print(f"[dryrun] SKIP {arch} x {shape_name} (DESIGN.md)")
                continue
            for mp in meshes:
                try:
                    rows.append(run_pair(arch, shape_name, multi_pod=mp))
                except Exception as e:                     # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape_name, mp, repr(e)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    print(f"\n[dryrun] {len(rows)} pairs compiled, {len(failures)} failed")
    for f_ in failures:
        print("  FAIL:", f_)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
