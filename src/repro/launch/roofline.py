"""Roofline-term extraction from compiled XLA artifacts.

Three terms per (arch, shape, mesh), in seconds (v5e constants):

  compute    = HLO_FLOPs            / (chips * 197e12)
  memory     = HLO_bytes            / (chips * 819e9)
  collective = collective_bytes     / (chips * 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
not in cost_analysis: we parse the post-SPMD HLO text and sum the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  (Sizes in the partitioned module are already
per-participant, so the sum is the per-device traffic injected onto the
fabric; DCN-crossing ops are attributed by replica-group span when the
mesh has a pod axis.)
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.launch.mesh import DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[16,128]{1,0} all-reduce(" — capture the *output* shape of the op
_OP_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\b(" + "|".join(_COLLECTIVES) +
    r")(-start|-done)?\(")


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]     # dynamic counts (loop-expanded)
    dcn_bytes: float = 0.0            # pod-crossing share (multi-pod mesh)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    @property
    def ici_bytes(self) -> float:
        return self.total_bytes - self.dcn_bytes

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_kind.values()))


# replica_groups=[16,32]<=[2,16,16]T(1,0,2)  (iota format)  or  {{0,1},{2,3}}
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([\d,{} ]+)\}\}")


def _crosses_pod(line: str, pod_size: int) -> bool:
    """Does this collective's replica grouping mix devices from different
    pods?  Pod p owns ids [p*pod_size, (p+1)*pod_size).  This is the TPU
    analogue of the paper's inter-server (b^e) vs intra-server (b^i) link
    distinction: pod-crossing collectives ride DCN."""
    m = _RG_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims)))
        arr = ids.reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(t) for t in m.group(4).split(",")])
        groups = arr.reshape(g, s)
        pods = groups // pod_size
        return bool((pods != pods[:, :1]).any())
    m = _RG_LIST_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if ids and len({i // pod_size for i in ids}) > 1:
                return True
    return False


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
# the while operand may be a bare name or carry the full printed tuple
# type (XLA version dependent) — match non-greedily up to "), condition="
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict[str, str]:
    """computation name -> body text (top-level '{...}' blocks)."""
    comps: dict[str, str] = {}
    lines = hlo_text.splitlines()
    name, buf, entry = None, [], None
    for ln in lines:
        m = _COMP_RE.match(ln)
        if m and not ln.startswith(" "):
            name = m.group(1)
            if ln.startswith("ENTRY"):
                entry = name
            buf = []
            continue
        if name is not None:
            if ln.startswith("}"):
                comps[name] = "\n".join(buf)
                name = None
            else:
                buf.append(ln)
    if entry is not None:
        comps["__entry__"] = comps.get(entry, "")
        comps["__entry_name__"] = entry
    return comps


def _trip_count(cond_text: str) -> int:
    """Heuristic scan trip count: the largest int constant in the loop
    condition (the compare bound)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def _multipliers(comps: dict[str, str]) -> dict[str, int]:
    """Dynamic execution multiplier per computation (loop nesting aware)."""
    entry = comps.get("__entry_name__")
    mult: dict[str, int] = {entry: 1} if entry else {}
    frontier = [entry] if entry else []
    seen = set(frontier)
    while frontier:
        cur = frontier.pop()
        body = comps.get(cur, "")
        m_cur = mult.get(cur, 1)
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.group(1), wm.group(2)
            # prefer XLA's own annotation; fall back to the cond heuristic
            line_end = body.find("\n", wm.end())
            tm = _KNOWN_TRIP_RE.search(
                body[wm.end(): line_end if line_end != -1 else len(body)])
            trip = int(tm.group(1)) if tm else _trip_count(comps.get(cond, ""))
            for child in (cond, wbody):
                mult[child] = max(mult.get(child, 0), m_cur * trip)
                if child not in seen:
                    seen.add(child)
                    frontier.append(child)
        for cm in _CALL_RE.finditer(body):
            child = cm.group(1)
            mult[child] = max(mult.get(child, 0), m_cur)
            if child not in seen:
                seen.add(child)
                frontier.append(child)
    return mult


_SHAPE_RE = re.compile(r"%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\]")
# optional "f32[64,64]{1,0} " operand-type prefix: some XLA versions print
# typed operands ("dot(f32[..] %a, ..)"), others bare names ("dot(%a, ..)")
_TYPE_PREFIX = r"(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?\s+)?"
_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\bdot\(" + _TYPE_PREFIX +
    r"%([\w\.\-]+),")
_OPND_RE = re.compile(r"[(,]\s*" + _TYPE_PREFIX + r"%([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]+)\}")


def _dims(s: str) -> list[int]:
    return [int(d) for d in s.split(",")] if s else []


_GTE_RE = re.compile(
    r"%([\w\.\-]+)\s*=\s*[^=]*get-tuple-element\((?:\([^)]*\)\s*)?"
    r"%([\w\.\-]+)\),\s*index=(\d+)")
_ROOT_TUPLE_RE = re.compile(r"ROOT\s+%[\w\.\-]+\s*=\s*\([^=]*tuple\(([^)]*)\)")


def _invariant_names(body: str) -> set[str]:
    """Names of loop-INVARIANT values in a while body: get-tuple-elements of
    the loop parameter that are passed through unchanged to the root tuple.
    These are weights/closures — assumed fabric/VMEM-resident across
    iterations, so their operand bytes are charged once, not per trip.
    (A scanned layer stack is still charged correctly: the per-iteration
    dynamic-slice output IS counted; only the full stacked array is not.)"""
    gtes: dict[int, str] = {}
    for m in _GTE_RE.finditer(body):
        gtes[int(m.group(3))] = m.group(1)
    rm = _ROOT_TUPLE_RE.search(body)
    if not rm:
        return set()
    # operands may be typed ("f32[8,8]{1,0} %w") or bare ("%w")
    operands = [o.strip().split()[-1].lstrip("%")
                for o in rm.group(1).split(",") if o.strip()]
    inv = set()
    for idx, name in gtes.items():
        if idx < len(operands) and operands[idx] == name:
            inv.add(name)
    return inv


def loop_cost_correction(hlo_text: str) -> tuple[float, float]:
    """(extra_flops, extra_bytes): XLA's cost_analysis counts a while body
    exactly ONCE (verified empirically), so a 126-layer scanned stack is
    undercounted 126x.  We re-count dot FLOPs (2 * |out| * contraction) and
    op bytes (outputs + resolvable operands of top-level ops, matching
    cost_analysis's fusion-boundary accounting) inside loop computations and
    add (trip - 1) copies.  Loop-invariant operands are charged once."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    # computations entered via calls= (fusions): count their dots for flops,
    # but exclude them from bytes (cost_analysis charges fusion boundaries).
    called = set()
    for body in comps.values():
        called.update(_CALL_RE.findall(body))

    extra_flops = 0.0
    extra_bytes = 0.0
    for name, body in comps.items():
        m = mult.get(name, 1)
        if m <= 1:
            continue
        shapes = {nm: (dt, _dims(dd))
                  for nm, dt, dd in _SHAPE_RE.findall(body)}
        invariant = _invariant_names(body)
        for line in body.splitlines():
            dm = _DOT_RE.search(line)
            if dm:
                out_dt, out_dims, lhs_name = dm.group(1), dm.group(2), dm.group(3)
                out_n = 1
                for d in _dims(out_dims):
                    out_n *= d
                contract = 1
                cm = _LHS_CONTRACT_RE.search(line)
                if cm and lhs_name in shapes:
                    lhs_dims = shapes[lhs_name][1]
                    for ci in _dims(cm.group(1)):
                        if ci < len(lhs_dims):
                            contract *= lhs_dims[ci]
                extra_flops += (m - 1) * 2.0 * out_n * contract
            if name not in called:
                sm = _SHAPE_RE.search(line)
                if sm and "parameter(" not in line and " = (" not in line:
                    dt, dd = sm.group(2), _dims(sm.group(3))
                    if dt in _DTYPE_BYTES:
                        n = 1
                        for d in dd:
                            n *= d
                        out_b = n * _DTYPE_BYTES[dt]
                        if "dynamic-update-slice" in line:
                            # in-place slice write: charge the update slice,
                            # not the whole buffer (operands also skipped)
                            upd = _OPND_RE.findall(line)
                            out_b = 0
                            if len(upd) >= 2 and upd[1] in shapes:
                                udt, udd = shapes[upd[1]]
                                un = 1
                                for d in udd:
                                    un *= d
                                out_b = 2 * un * _DTYPE_BYTES.get(udt, 4)
                            extra_bytes += (m - 1) * out_b
                            continue
                        opnd_b = 0
                        is_fusion = "fusion(" in line
                        for opname in _OPND_RE.findall(line):
                            if opname in invariant:
                                continue
                            if opname in shapes:
                                odt, odd = shapes[opname]
                                if odt in _DTYPE_BYTES:
                                    on = 1
                                    for d in odd:
                                        on *= d
                                    ob = on * _DTYPE_BYTES[odt]
                                    if is_fusion:
                                        # fused kernels read ~output-sized
                                        # windows of big (sliced) buffers
                                        ob = min(ob, out_b)
                                    opnd_b += ob
                        extra_bytes += (m - 1) * (out_b + opnd_b)
    return extra_flops, extra_bytes


def bytes_breakdown(hlo_text: str, top: int = 15) -> list[dict]:
    """Largest loop-expanded HBM-traffic contributors (the §Perf profiling
    view for memory-bound pairs)."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    called = set()
    for body in comps.values():
        called.update(_CALL_RE.findall(body))
    rows = []
    for name, body in comps.items():
        m = mult.get(name, 1)
        if m <= 1 or name in called:
            continue
        shapes = {nm: (dt, _dims(dd))
                  for nm, dt, dd in _SHAPE_RE.findall(body)}
        invariant = _invariant_names(body)
        for line in body.splitlines():
            sm = _SHAPE_RE.search(line)
            if not sm or "parameter(" in line or " = (" in line:
                continue
            dt, dd = sm.group(2), _dims(sm.group(3))
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dd:
                n *= d
            total = n * _DTYPE_BYTES[dt]
            if "dynamic-update-slice" in line:
                upd = _OPND_RE.findall(line)
                total = 0
                if len(upd) >= 2 and upd[1] in shapes:
                    udt, udd = shapes[upd[1]]
                    un = 1
                    for d in udd:
                        un *= d
                    total = 2 * un * _DTYPE_BYTES.get(udt, 4)
                rows.append({"comp": name, "op": sm.group(1), "mult": m,
                             "bytes": total * (m - 1),
                             "line": line.strip()[:110]})
                continue
            out_b0 = total
            is_fusion = "fusion(" in line
            for opname in _OPND_RE.findall(line):
                if opname in invariant or opname not in shapes:
                    continue
                odt, odd = shapes[opname]
                if odt in _DTYPE_BYTES:
                    on = 1
                    for d in odd:
                        on *= d
                    ob = on * _DTYPE_BYTES[odt]
                    if is_fusion:
                        ob = min(ob, out_b0)
                    total += ob
            rows.append({"comp": name, "op": sm.group(1), "mult": m,
                         "bytes": total * (m - 1),
                         "line": line.strip()[:110]})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:top]


def collective_breakdown(hlo_text: str, top: int = 12) -> list[dict]:
    """Per-op-line collective contributions (loop-expanded), largest first.
    The §Perf profiling view: 'which collective, in which loop, costs what'."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    out = []
    for name, body in comps.items():
        m = mult.get(name, 1)
        for om in _OP_RE.finditer(body):
            dtype, dims, kind, suffix = (om.group(1), om.group(2),
                                         om.group(3), om.group(4))
            if suffix == "-done" or dtype not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            line_start = body.rfind("\n", 0, om.start()) + 1
            opname = body[line_start:om.start()].strip().split(" ")[0]
            out.append({"comp": name, "op": opname, "kind": kind,
                        "shape": f"{dtype}[{dims}]", "mult": m,
                        "bytes": n * _DTYPE_BYTES[dtype] * m})
    out.sort(key=lambda r: -r["bytes"])
    return out[:top]


def parse_collectives(hlo_text: str, pod_size: int = 0) -> CollectiveStats:
    """Sum collective operand bytes, expanding while-loop trip counts so a
    collective inside the scanned layer stack counts once per layer.
    With ``pod_size`` > 0 (multi-pod mesh), pod-crossing collectives are
    tallied separately as DCN traffic — the paper's b^e vs b^i split."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(comps)
    entry = comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    bytes_by: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    dcn = 0.0
    for name, body in comps.items():
        m = mult.get(name, 1 if name == entry else 0)
        if m == 0:
            m = 1  # unreferenced computation (conservative)
        for om in _OP_RE.finditer(body):
            dtype, dims, kind, suffix = (om.group(1), om.group(2),
                                         om.group(3), om.group(4))
            if suffix == "-done" or dtype not in _DTYPE_BYTES:
                continue  # count async pairs once (at -start)
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            b = n * _DTYPE_BYTES[dtype] * m
            bytes_by[kind] += b
            count_by[kind] += m
            if pod_size:
                line_start = body.rfind("\n", 0, om.start()) + 1
                line_end = body.find("\n", om.end())
                line = body[line_start:line_end if line_end > 0 else None]
                if _crosses_pod(line, pod_size):
                    dcn += b
    return CollectiveStats(bytes_by, count_by, dcn_bytes=dcn)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # PER-DEVICE FLOPs (XLA cost_analysis runs
                                  # on the partitioned module; == global/chips)
    hlo_bytes: float              # per-device HBM traffic
    collective_bytes: float       # per-device fabric traffic
    collectives: CollectiveStats
    model_flops: float            # 6*N*D (or 6*N_active*D) per step, GLOBAL
    per_device_hbm_peak: float    # from memory_analysis, bytes

    @property
    def t_compute(self) -> float:
        # == global_FLOPs / (chips * peak): cost_analysis is already /chip
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        dcn = self.collectives.dcn_bytes if self.collectives else 0.0
        ici = self.collective_bytes - dcn
        return ici / ICI_BW + dcn / DCN_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / max(self.hlo_flops * self.chips, 1.0)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "hbm_peak_bytes": self.per_device_hbm_peak,
        }


def cost_terms(compiled) -> tuple[float, float]:
    """(flops, bytes) from compiled.cost_analysis(); tolerant of missing
    keys on some backends."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return flops, byts


def memory_peak(compiled) -> float:
    """Per-device HBM requirement: live args + outputs + temporaries."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return 0.0
    total = (getattr(ma, "argument_size_in_bytes", 0)
             + getattr(ma, "output_size_in_bytes", 0)
             + getattr(ma, "temp_size_in_bytes", 0)
             - getattr(ma, "alias_size_in_bytes", 0))
    return float(max(total, getattr(ma, "peak_memory_in_bytes", 0)))


def model_step_flops(cfg, shape) -> float:
    """6*N*D for a train step (fwd 2ND + bwd 4ND); 2*N*D for pure forward
    (prefill); 2*N_active per generated token for decode."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: one token each
