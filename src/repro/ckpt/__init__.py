from repro.ckpt.checkpoint import load, save

__all__ = ["load", "save"]
