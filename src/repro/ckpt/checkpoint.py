"""Flat-npz checkpointing for params + optimizer state.

Paths are '/'-joined pytree keys; restore rebuilds the exact tree.  Good
enough for single-host CPU validation and structurally identical to what a
sharded orbax layout would store per shard.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, *, params: Any, opt_state: Any | None = None,
         step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    payload["meta/step"] = np.asarray(step)
    np.savez(path, **payload)


def load(path: str, *, params_like: Any, opt_like: Any | None = None
         ) -> tuple[Any, Any | None, int]:
    """Restore into the structure of the provided templates."""
    data = np.load(path)

    def restore(template: Any, prefix: str) -> Any:
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
        new_leaves = []
        for path_k, leaf in leaves_p:
            key = prefix + "/".join(
                str(p.key) if isinstance(p, jax.tree_util.DictKey)
                else str(p.idx) for p in path_k)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
            new_leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    params = restore(params_like, "params/")
    opt = restore(opt_like, "opt/") if opt_like is not None else None
    return params, opt, int(data["meta/step"])
