"""internvl2-1b [vlm] — InternViT frontend (stub) + InternLM2/Qwen2-style
decoder backbone.

Source: InternVL 1.5/2 [arXiv:2404.16821].
24L, d_model=896, 14 heads (GQA kv=2, head_dim 64), d_ff=4864 (SwiGLU),
vocab=151655, 256 image-patch tokens prepended.

Frontend stub (the one allowed carve-out): ``input_specs()`` provides
precomputed patch embeddings [B, 256, 896]; the InternViT vision tower is
NOT implemented — only the MLP projector + language decoder that consume
its output.

Shape skip: long_500k skipped — pure full attention (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151_655,
    mlp="swiglu",
    rope="full",
    rope_theta=1.0e6,
    n_patches=256,
    tie_embeddings=True,
    source="arXiv:2404.16821",
)
