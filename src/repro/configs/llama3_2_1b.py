"""llama3.2-1b [dense] — small llama3.

Source: hf:meta-llama/Llama-3.2-1B (model card).
16L, d_model=2048, 32 heads (GQA kv=8, head_dim 64), d_ff=8192 (SwiGLU),
vocab=128256, rope theta 500k, tied embeddings.

Shape skip: long_500k skipped — pure full attention (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab=128_256,
    mlp="swiglu",
    rope="full",
    rope_theta=5.0e5,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)
