"""llama3-405b [dense] — the frontier-scale dense config.

Source: The Llama 3 Herd of Models [arXiv:2407.21783].
126L, d_model=16384, 128 heads (GQA kv=8, head_dim 128), d_ff=53248
(SwiGLU), vocab=128256, rope theta 500k.

bf16 params + remat: at 405B params the fp32 master copy would not fit the
2 TB/pod HBM budget alongside Adam state; dist/optim shards fp32 moments
over the full mesh (ZeRO-3 style) and keeps bf16 params (documented in
DESIGN.md hardware-adaptation notes).

Shape skip: long_500k skipped — pure full attention (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128_256,
    mlp="swiglu",
    rope="full",
    rope_theta=5.0e5,
    param_dtype="bfloat16",
    source="arXiv:2407.21783",
)
