"""whisper-tiny [audio] — encoder-decoder with a stubbed conv/mel frontend.

Source: Whisper [arXiv:2212.04356].
4 decoder layers + 4 encoder layers, d_model=384, 6 heads (kv=6, head_dim
64), d_ff=1536 (GELU MLP), vocab=51865, learned decoder positions,
sinusoidal encoder positions, 1500 encoder frames.

Frontend stub (the one allowed carve-out): ``input_specs()`` provides
precomputed 1500-frame encoder embeddings of shape [B, 1500, 384]; the
mel-spectrogram + 2xConv1d feature extractor is NOT implemented.

Shape skips (DESIGN.md): long_500k skipped — the full-attention decoder has
no sub-quadratic variant and a 500k text context is outside this family's
scope.  train_4k/decode_32k exercise the decoder at the assigned lengths
(structurally longer than Whisper's 448-token context; documented).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51_865,
    mlp="gelu",
    rope="none",
    learned_pos=True,
    enc_frames=1500,
    source="arXiv:2212.04356",
)
