"""xlstm-350m [ssm] — sLSTM + mLSTM blocks, no attention, no KV cache.

Source: xLSTM [arXiv:2405.04517].
24L, d_model=1024, 4 heads, vocab=50304 (GPT-NeoX tokenizer), d_ff=0 (the
feed-forward lives inside the LSTM blocks: mLSTM up-projection factor 2,
sLSTM post-MLP factor 4/3).  Block mix: 3 mLSTM : 1 sLSTM per super-block
(slstm_every=4 -> 18 mLSTM + 6 sLSTM), following the paper's
mostly-mLSTM recipe at this scale; head_dim = proj_factor*d / heads = 512.

long_500k runs: recurrent state is sequence-length independent.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,                    # mLSTM head dim = (pf * d) / heads
    d_ff=0,
    vocab=50_304,
    slstm_every=4,
    mlstm_proj_factor=2.0,
    rope="none",
    source="arXiv:2405.04517",
)
