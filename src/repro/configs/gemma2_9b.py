"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.

Source: Gemma 2 technical report [arXiv:2408.00118].
42L, d_model=3584, 16 heads (GQA kv=8, head_dim 256), d_ff=14336 (GeGLU),
vocab=256000, sliding window 4096 on local layers, attn softcap 50.0,
final softcap 30.0, tied embeddings.

long_500k note (DESIGN.md §Arch-applicability): served in the
sliding-window variant — the rolling KV cache holds the last ``window``
positions, so global layers also attend within the window.  This is the
documented deviation that makes the long-context decode shape sub-quadratic
(in cache memory) for this otherwise full-attention arch.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256_000,
    mlp="geglu",
    layer_pattern="local_global",
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope="full",
    rope_theta=1.0e4,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
