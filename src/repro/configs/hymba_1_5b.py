"""hymba-1.5b [hybrid] — parallel attention + Mamba heads in every block.

Source: Hymba [arXiv:2411.13676].
32L, d_model=1600, 25 heads (GQA kv=5, head_dim 64), d_ff=5504,
vocab=32001, ssm_state=16.  Attention is sliding-window (1024) everywhere
except the first / middle / last layers, which stay global — Hymba's
meta-token mechanism is omitted (not part of the assigned config).

long_500k runs: the Mamba branch is O(1)/token and the attention branch
rolls a window-sized cache, so decode state is bounded.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32_001,
    mlp="swiglu",
    window=1024,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    rope="full",
    source="arXiv:2411.13676",
)
