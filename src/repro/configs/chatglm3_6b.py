"""chatglm3-6b [dense] — 2d RoPE (half-dim rotation), extreme GQA (kv=2).

Source: ChatGLM family report [arXiv:2406.12793].
28L, d_model=4096, 32 heads (GQA kv=2, head_dim 128), d_ff=13696 (SwiGLU),
vocab=65024.

Shape skip: long_500k skipped — pure full attention (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=65_024,
    mlp="swiglu",
    rope="half",                     # GLM 2d rope: only half the head dim rotates
    rope_theta=1.0e4,
    source="arXiv:2406.12793",
)
