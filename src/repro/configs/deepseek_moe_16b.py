"""deepseek-moe-16b [moe] — fine-grained expert segmentation + shared experts.

Source: DeepSeekMoE [arXiv:2401.06066].
28L, d_model=2048, 16 heads (kv=16, head_dim 128), vocab=102400.
MoE: 64 routed experts (d_expert=1408, top-6) + 2 shared experts; the first
layer is a dense FFN (d_ff=10944), per the released model.

Expert-parallel: the expert dim of [E, d, d_e] weights shards over the
``model`` mesh axis; dispatch/combine lower to all-to-all-class collectives.

Shape skip: long_500k skipped — pure full attention (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab=102_400,
    mlp="swiglu",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_expert=1408,
    n_dense_layers=1,
    dense_d_ff=10944,
    rope="full",
    source="arXiv:2401.06066",
)
