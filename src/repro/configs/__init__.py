"""Architecture registry: the 10 assigned configs + shape support matrix.

``get_config(arch)`` returns the exact assigned ModelConfig;
``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct stand-ins for
every model input of that (arch, shape) pair — weak-type-correct,
shardable, and allocation-free (the dry-run lowers against these);
``supported_shapes(cfg)`` applies the DESIGN.md skip rules (long_500k only
for sub-quadratic-decode families).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.chatglm3_6b import CONFIG as CHATGLM3_6B
from repro.configs.hymba_1_5b import CONFIG as HYMBA_1_5B
from repro.configs.llama3_405b import CONFIG as LLAMA3_405B
from repro.configs.llama3_2_1b import CONFIG as LLAMA3_2_1B
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.internvl2_1b import CONFIG as INTERNVL2_1B
from repro.configs.deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from repro.configs.kimi_k2_1t_a32b import CONFIG as KIMI_K2

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        GEMMA2_9B, WHISPER_TINY, CHATGLM3_6B, HYMBA_1_5B, LLAMA3_405B,
        LLAMA3_2_1B, XLSTM_350M, INTERNVL2_1B, DEEPSEEK_MOE_16B, KIMI_K2,
    )
}

# long_500k support: SSM/hybrid (O(1) decode state) + gemma2's documented
# sliding-window variant.  All other archs are pure full attention — skipped
# per DESIGN.md §Arch-applicability.
LONG_CONTEXT_OK = {"xlstm-350m", "hymba-1.5b", "gemma2-9b"}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def supported_shapes(cfg: ModelConfig) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.name in LONG_CONTEXT_OK:
        names.append("long_500k")
    return names


def cache_slots(cfg: ModelConfig, shape: InputShape) -> int:
    """KV-cache slot count for a decode shape.  long_500k rolls a
    window-sized cache (sliding-window serving); decode_32k keeps the full
    context."""
    if shape.name == "long_500k" and cfg.window:
        return cfg.window
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the model inputs of (cfg, shape).

    train/prefill -> the batch dict consumed by loss_fn/prefill;
    decode       -> {"tok": [B], "pos": [B]} (the cache is built separately
    via Model.init_cache under eval_shape)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.is_decode:
        return {"tok": sds((B,), i32), "pos": sds((B,), i32)}
    if cfg.family == "vlm":
        return {"tokens": sds((B, S - cfg.n_patches), i32),
                "patches": sds((B, cfg.n_patches, cfg.d_model), f32)}
    if cfg.family == "audio":
        return {"frames": sds((B, cfg.enc_frames, cfg.d_model), f32),
                "tokens": sds((B, S), i32)}
    return {"tokens": sds((B, S), i32)}


__all__ = ["ARCHS", "LONG_CONTEXT_OK", "get_config", "supported_shapes",
           "cache_slots", "input_specs", "INPUT_SHAPES"]
