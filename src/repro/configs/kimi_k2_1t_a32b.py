"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 32B active (paper-table).

Source: Kimi K2 [arXiv:2501.kimi2].
61L, d_model=7168, 64 heads (GQA kv=8, head_dim 128), vocab=163840.
MoE: 384 routed experts (d_expert=2048, top-8) + 1 shared expert; first
layer dense (d_ff=18432), per the K2 card.

bf16 params + remat (1T fp32 would be 4 TB); fp32 Adam moments shard over
the full mesh.  Expert-parallel over ``model`` axis: 384 experts / 16 = 24
experts per device column.

Shape skip: long_500k skipped — full attention (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab=163_840,
    mlp="swiglu",
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    d_expert=2048,
    n_dense_layers=1,
    dense_d_ff=18432,
    rope="full",
    rope_theta=5.0e4,
    param_dtype="bfloat16",
    source="arXiv:2501.kimi2",
)
