"""Explicit ring-all-reduce collectives (paper §3, Fig. 1).

A ring of ``w`` workers exchanges a ``d``-sized gradient in two phases of
``w - 1`` steps each, built here from :func:`jax.lax.ppermute` so the
compiled HLO contains exactly ``2(w - 1)`` collective-permutes:

* **Share-Reduce** (:func:`ring_reduce_scatter`) — each worker ends up
  owning the fully reduced ``1/w`` chunk with its own index;
* **Share-Only** (:func:`ring_all_gather`) — the reduced chunks circulate
  until every worker holds the full result.

Per iteration each worker sends/receives ``2 d (w - 1) / w`` bytes
(:func:`exchange_bytes_per_worker`) — asymptotically independent of ``w``,
the bandwidth-optimality argument of §3 that makes RAR the substrate worth
scheduling (contrast the server-worker architecture's ``2 w d`` per server).

All three collectives are meant to be called *inside* ``jax.shard_map``
over a 1-D mesh axis (conventionally ``"data"``); chunking flattens the
input and zero-pads it to a multiple of ``w``, so arbitrary tensor sizes
work.  ``w == 1`` degenerates to the identity (no communication).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def axis_size(axis_name: str) -> int:
    """Static size ``w`` of the mapped ring axis (shard_map body scope)."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    import jax.core as jcore  # pragma: no cover - pre-shim fallback

    return int(jcore.axis_frame(axis_name))


def exchange_bytes_per_worker(d: float, w: int) -> float:
    """Bytes each worker sends per RAR iteration for a ``d``-byte gradient.

    §3: ``2 d (w - 1) / w`` — each of the ``2(w - 1)`` ring steps moves a
    ``d / w`` chunk.  The degenerate single-worker ring exchanges nothing.
    """
    if w < 1:
        raise ValueError(f"ring width must be >= 1, got {w}")
    if w == 1:
        return 0.0
    return 2.0 * d * (w - 1) / w


def _ring_chunks(x: jax.Array, w: int) -> jax.Array:
    """Flatten ``x`` and split into ``w`` equal chunks, zero-padding the
    tail when ``x.size`` is not a multiple of ``w``.  Returns ``[w, m]``."""
    flat = x.reshape(-1)
    m = -(-flat.size // w)
    if m * w != flat.size:
        flat = jnp.pad(flat, (0, m * w - flat.size))
    return flat.reshape(w, m)


def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Share-Reduce phase: ``w - 1`` ppermute steps around the ring.

    Each worker contributes its local ``x``; worker ``i`` returns the fully
    reduced chunk ``i`` of the (zero-padded) flattened sum — a 1-D array of
    ``ceil(x.size / w)`` elements.
    """
    w = axis_size(axis_name)
    chunks = _ring_chunks(x, w)
    if w == 1:
        return chunks[0]
    i = jax.lax.axis_index(axis_name)
    # send "left" (j -> j-1): the partial for chunk c starts at worker c-1
    # and accumulates one local contribution per hop until worker c owns it.
    left = [(j, (j - 1) % w) for j in range(w)]

    def local_chunk(c):
        """This worker's contribution for (traced) chunk index ``c``."""
        return jnp.take(chunks, c % w, axis=0)

    partial = local_chunk(i + 1)
    for t in range(w - 1):
        partial = jax.lax.ppermute(partial, axis_name, left)
        partial = partial + local_chunk(i + t + 2)
    return partial


def ring_all_gather(chunk: jax.Array, axis_name: str) -> jax.Array:
    """Share-Only phase: ``w - 1`` ppermute steps circulate reduced chunks.

    Worker ``i`` holds logical chunk ``i`` (the :func:`ring_reduce_scatter`
    convention); every worker returns the concatenation of all ``w`` chunks
    in index order, shape ``[w * chunk.shape[0], ...]``.
    """
    w = axis_size(axis_name)
    if w == 1:
        return chunk
    i = jax.lax.axis_index(axis_name)
    left = [(j, (j - 1) % w) for j in range(w)]
    out = jnp.zeros((w,) + chunk.shape, chunk.dtype)
    out = out.at[i % w].set(chunk)
    buf = chunk
    for t in range(w - 1):
        buf = jax.lax.ppermute(buf, axis_name, left)
        out = out.at[(i + t + 1) % w].set(buf)
    return out.reshape((w * chunk.shape[0],) + chunk.shape[1:])


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Full RAR: Share-Reduce then Share-Only, ``2(w - 1)`` ppermutes total.

    Returns the elementwise sum of ``x`` across the ring — numerically a
    ring-ordered reassociation of :func:`jax.lax.psum` — with the input's
    shape and dtype.
    """
    w = axis_size(axis_name)
    if w == 1:
        return x
    chunk = ring_reduce_scatter(x, axis_name)
    full = ring_all_gather(chunk, axis_name)
    return full[: x.size].reshape(x.shape)
