"""Mesh / PartitionSpec rules for the pjit (GSPMD) production path.

The launch drivers lower every (arch x input-shape) pair against the
production meshes (``launch/mesh.py``) using three declarative rule sets:

* :func:`param_specs`  — params and optimizer moments: tensor-parallel over
  ``"model"`` on the largest divisible dim, then ZeRO-3-style over
  ``"data"`` on the largest remaining divisible dim (moments shard exactly
  like their params, which is what fits the per-chip HBM budget);
* :func:`batch_specs`  — inputs: leading (batch) dim over the data-parallel
  axes ``("pod", "data")``;
* :func:`cache_specs`  — decode caches: batch dim over the data axes, KV
  heads (or, for ``seq_shard`` long-context serving, the slot axis) over
  ``"model"``.

Every rule only applies an axis when it exists in the mesh and divides the
dim, so the same code serves the 512-chip dry-run and a 2-device host mesh.
``REPRO_NAIVE_SHARDING=1`` drops param/cache sharding to fully replicated —
the baseline the dry-run compares against.  :func:`named` converts a spec
pytree into :class:`~jax.sharding.NamedSharding` leaves for ``jax.jit``.
"""
from __future__ import annotations

import os
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"
ZERO_AXIS = "data"          # ZeRO-3 shards params/moments over "data" only:
                            # "pod" crosses DCN, too slow for weight gathers


def _is_spec(x: Any) -> bool:
    return isinstance(x, P)


def _naive() -> bool:
    return bool(os.environ.get("REPRO_NAIVE_SHARDING"))


def _axis_sizes(mesh) -> dict[str, int]:
    return {name: int(size) for name, size in
            zip(mesh.axis_names, mesh.devices.shape)}


def _largest_divisible(shape, size: int, used: set[int]) -> int | None:
    """Index of the largest dim divisible by ``size`` (ties -> first),
    excluding ``used``; None when nothing qualifies or ``size`` is 1."""
    if size <= 1:
        return None
    best, best_dim = None, 0
    for i, d in enumerate(shape):
        if i in used or d % size != 0 or d < size:
            continue
        if d > best_dim:
            best, best_dim = i, d
    return best


def leaf_spec(shape, mesh) -> P:
    """Model-then-ZeRO spec for one parameter/moment leaf."""
    sizes = _axis_sizes(mesh)
    spec: list = [None] * len(shape)
    used: set[int] = set()
    mi = _largest_divisible(shape, sizes.get(MODEL_AXIS, 1), used)
    if mi is not None:
        spec[mi] = MODEL_AXIS
        used.add(mi)
    zi = _largest_divisible(shape, sizes.get(ZERO_AXIS, 1), used)
    if zi is not None:
        spec[zi] = ZERO_AXIS
    return P(*spec)


def param_specs(tree: Any, mesh, cfg=None) -> Any:
    """PartitionSpec pytree for a params / optimizer-state pytree.

    ``cfg`` is accepted for future per-arch overrides; the current rules
    are purely shape-driven.  Under ``REPRO_NAIVE_SHARDING`` everything is
    replicated (the dry-run baseline).
    """
    del cfg
    if _naive():
        return jax.tree.map(lambda leaf: P(), tree)
    return jax.tree.map(lambda leaf: leaf_spec(leaf.shape, mesh), tree)


def _batch_axes_for(dim: int, mesh) -> tuple[str, ...]:
    """The prefix of ("pod", "data") present in the mesh whose product
    divides ``dim`` (the largest usable data-parallel group)."""
    sizes = _axis_sizes(mesh)
    axes = [a for a in BATCH_AXES if sizes.get(a, 1) > 1]
    while axes:
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if prod <= dim and dim % prod == 0:
            return tuple(axes)
        axes.pop(0)          # drop "pod" first: keep intra-pod parallelism
    return ()


def batch_specs(tree: Any, mesh) -> Any:
    """Shard the leading (global-batch) dim of every input leaf over the
    data-parallel axes.  Works for train/prefill batch dicts and for the
    decode ``{"tok": [B], "pos": [B]}`` pair alike."""

    def spec(leaf):
        """Batch-dim spec for one input leaf."""
        axes = _batch_axes_for(leaf.shape[0], mesh) if leaf.ndim else ()
        if not axes:
            return P()
        first = axes if len(axes) > 1 else axes[0]
        return P(first, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, tree)


def _cache_leaf_spec(shape, mesh, *, seq_shard: bool) -> P:
    """Spec for one stacked decode-cache leaf ``[L, B, ...rest]``.

    dim 0 is the scanned layer axis (never sharded), dim 1 the batch; for
    KV-shaped leaves dim 2 is the slot axis and dim 3 the KV heads.  The
    ``"model"`` axis goes on the slot axis when ``seq_shard`` (long-context
    rolling windows) else on the heads when they divide.
    """
    sizes = _axis_sizes(mesh)
    spec: list = [None] * len(shape)
    if len(shape) >= 2:
        axes = _batch_axes_for(shape[1], mesh)
        if axes:
            spec[1] = axes if len(axes) > 1 else axes[0]
    ms = sizes.get(MODEL_AXIS, 1)
    if ms > 1:
        if seq_shard and len(shape) >= 3 and shape[2] % ms == 0:
            spec[2] = MODEL_AXIS
        elif len(shape) >= 4 and shape[3] % ms == 0 and shape[3] >= ms:
            spec[3] = MODEL_AXIS
    return P(*spec)


def cache_specs(cache: Any, mesh, *, seq_shard: bool = False) -> Any:
    """PartitionSpec pytree for a ``Model.init_cache`` pytree.

    Handles the stacked-layer subtrees (``"kv"``, ``"kv_dense"``, ``"ssm"``)
    and the unstacked audio ``"enc_out"`` ``[B, frames, d]`` buffer.
    """
    if _naive():
        return jax.tree.map(lambda leaf: P(), cache)

    out = {}
    for key, sub in cache.items():
        if key == "enc_out":
            axes = _batch_axes_for(sub.shape[0], mesh)
            first = axes if len(axes) > 1 else (axes[0] if axes else None)
            out[key] = P(first, *([None] * (sub.ndim - 1)))
        else:
            out[key] = jax.tree.map(
                lambda leaf: _cache_leaf_spec(leaf.shape, mesh,
                                              seq_shard=seq_shard), sub)
    return out


def named(spec_tree: Any, mesh) -> Any:
    """Convert a PartitionSpec pytree into NamedSharding leaves on ``mesh``
    (the form ``jax.jit``'s in/out_shardings consume)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_spec)
