"""Train / serve step factories wiring models + optimizer + collectives.

* :func:`make_train_step`     — single-program step (the pjit production
  path: gradient sync is implicit in GSPMD), with optional gradient
  accumulation from ``AdamWConfig.grad_accum_steps``;
* :func:`make_rar_train_step` — the paper-faithful data-parallel step: the
  batch splits over a 1-D ``"data"`` mesh, each worker takes grads on its
  shard, and the full flattened gradient is exchanged with the explicit
  ring-all-reduce of :mod:`repro.dist.rar` (one ``d``-sized ring per
  iteration, exactly the exchange §3 models) before a replicated AdamW
  update.  Equivalent to :func:`make_train_step` on the concatenated batch
  up to ring-order float reassociation;
* :func:`make_serve_step`     — one greedy decode step against the cache.

All returned functions are pure and jit-ready; metrics are scalar dicts
(``loss``/``grad_norm``/``lr`` at minimum).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import PartitionSpec as P

from repro.dist.rar import ring_all_reduce
from repro.models.model import Model
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

RING_AXIS = "data"


def _grads_and_loss(model: Model, ocfg: AdamWConfig,
                    params, batch) -> tuple:
    """(grads, loss) on one batch, honouring ``grad_accum_steps``.

    Accumulation scans over A microbatches (axis-0 splits) and averages —
    peak activation memory scales ~1/A while the averaged gradient matches
    the full-batch one up to float reassociation.
    """
    grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)
    A = max(int(ocfg.grad_accum_steps), 1)
    if A == 1:
        (loss, _aux), grads = grad_fn(params, batch)
        return grads, loss

    def split(leaf):
        B = leaf.shape[0]
        if B % A != 0:
            raise ValueError(
                f"global batch {B} must be divisible by "
                f"grad_accum_steps={A}")
        return leaf.reshape((A, B // A) + leaf.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(carry, mb):
        gsum, lsum = carry
        (loss, _aux), g = grad_fn(params, mb)
        return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                   micro)
    return jax.tree.map(lambda g: g / A, gsum), lsum / A


def make_train_step(model: Model, ocfg: AdamWConfig) -> Callable:
    """``(params, opt, batch) -> (params, opt, metrics)``, single program.

    Under pjit the data/model parallelism comes from the argument shardings
    (``repro.dist.sharding``); XLA inserts the gradient collectives.
    """

    def step(params, opt, batch):
        """One optimizer step on one global batch."""
        grads, loss = _grads_and_loss(model, ocfg, params, batch)
        new_params, new_opt, om = adamw.apply(ocfg, grads, params, opt)
        return new_params, new_opt, {"loss": loss, **om}

    return step


def make_rar_train_step(model: Model, ocfg: AdamWConfig, mesh) -> Callable:
    """Explicit ring-all-reduce data-parallel step over ``mesh``.

    ``mesh`` must be 1-D over axis ``"data"`` (any device subset — the
    scheduler launcher builds it from exactly the GPUs a placement
    assigned).  Params and optimizer state are replicated; the batch's
    leading dim must be divisible by the ring width ``w``.  Per step each worker
    ring-exchanges the full flattened gradient — ``2 d (w-1)/w`` bytes,
    the §3 exchange volume — then applies an identical AdamW update, so
    parameters stay bitwise replicated without a broadcast.
    """
    if RING_AXIS not in mesh.axis_names:
        raise ValueError(f"mesh must carry a {RING_AXIS!r} axis, "
                         f"got {mesh.axis_names}")
    w = int(dict(zip(mesh.axis_names, mesh.devices.shape))[RING_AXIS])

    def local_step(params, opt, batch):
        """Per-worker body: local grads, ring exchange, replicated update."""
        grads, loss = _grads_and_loss(model, ocfg, params, batch)
        if w > 1:
            gvec, unravel = ravel_pytree(grads)
            grads = unravel(ring_all_reduce(gvec, RING_AXIS) / w)
            loss = jax.lax.psum(loss, RING_AXIS) / w
        new_params, new_opt, om = adamw.apply(ocfg, grads, params, opt)
        return new_params, new_opt, {"loss": loss, **om}

    # check_rep=False: the replication of the ppermute-built update is by
    # construction (identical inputs -> identical arithmetic on every
    # worker), which shard_map's conservative rep analysis cannot prove.
    mapped = jax.shard_map(local_step, mesh=mesh,
                           in_specs=(P(), P(), P(RING_AXIS)),
                           out_specs=(P(), P(), P()),
                           check_rep=False)
    return jax.jit(mapped)


def make_serve_step(model: Model) -> Callable:
    """``(params, cache, tok, pos) -> (next_tok, logits, cache)``: one
    greedy decode step (argmax sampling, deterministic)."""

    def serve(params, cache, tok, pos):
        """Decode one token per sequence and write it into the cache."""
        logits, new_cache = model.decode_step(params, cache, tok, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve
