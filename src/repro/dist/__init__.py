"""Distributed RAR training substrate (paper §3 made executable).

* :mod:`repro.dist.rar`      — ring collectives on ``jax.lax.ppermute``
  (the Share-Reduce / Share-Only phases of Fig. 1) + the §3 exchange-volume
  formula;
* :mod:`repro.dist.sharding` — mesh/PartitionSpec rules for the pjit path
  (params/batch/cache specs consumed by ``launch/dryrun.py``);
* :mod:`repro.dist.steps`    — train/serve step factories, including the
  explicit RAR data-parallel step the scheduler launcher executes on each
  placement.
"""
from repro.dist.rar import (exchange_bytes_per_worker, ring_all_gather,
                            ring_all_reduce, ring_reduce_scatter)
from repro.dist.steps import (make_rar_train_step, make_serve_step,
                              make_train_step)

__all__ = [
    "exchange_bytes_per_worker",
    "ring_all_gather",
    "ring_all_reduce",
    "ring_reduce_scatter",
    "make_rar_train_step",
    "make_serve_step",
    "make_train_step",
]
