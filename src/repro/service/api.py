"""Public facade of the scheduler service.

:class:`SchedulerService` is the narrow, stable surface a client sees:
``submit`` / ``cancel`` / ``status`` / ``step`` / ``drain`` / ``recover``.
It composes the pieces underneath -- :class:`~repro.service.queue.QueueManager`,
:class:`~repro.service.daemon.Daemon`, a journal store from
:mod:`repro.service.store` -- and is layered strictly on
:mod:`repro.core.api`: policies and choosers are resolved through the core
registries, placements go through the shared
:class:`~repro.core.api.PlacementState`, and ``drain`` returns the exact
:class:`~repro.core.api.ScheduleResult` shape every registered policy
emits.  No new scheduling entrypoints are introduced; for any trace, ::

    svc = SchedulerService(cluster, policy="sjf-bco")
    handles = [svc.submit(SubmitRequest(job, arrival)) for ...]
    schedule, sim = svc.drain()

yields a ``schedule`` identical (assignment, starts, finishes) to ::

    get_policy("sjf-bco")(ScheduleRequest(cluster, jobs, arrivals=...))

because both run the same chooser over the same state in the same order
(``bench_service.py --quick`` hard-asserts this, including across a
simulated crash/recovery).
"""
from __future__ import annotations

import dataclasses

from repro.core.api import ScheduleResult
from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.simulator import SimResult
from repro.service.daemon import Daemon, VirtualClock
from repro.service.queue import QueueManager, TenantConfig
from repro.service.state import JobState
from repro.service.store import open_store

__all__ = ["SubmitRequest", "JobHandle", "JobStatus", "SchedulerService"]


@dataclasses.dataclass(frozen=True)
class SubmitRequest:
    """One submission: the job spec (its ``jid`` is ignored -- the service
    assigns daemon-wide ids), its arrival slot, and the owning tenant."""

    job: Job
    arrival: int = 0
    tenant: str = "default"


@dataclasses.dataclass(frozen=True)
class JobHandle:
    """Opaque ticket returned by :meth:`SchedulerService.submit`."""

    jid: int
    tenant: str


@dataclasses.dataclass(frozen=True)
class JobStatus:
    """Point-in-time view of one job's lifecycle and placement."""

    jid: int
    tenant: str
    state: JobState
    arrival: int
    gpus: "tuple[int, ...] | None"
    start: "float | None"
    finish: "float | None"


class SchedulerService:
    """Long-running scheduling service over one cluster.

    ``policy``/``params`` configure the default tenant; ``tenants`` maps
    tenant names to their own :class:`~repro.service.queue.TenantConfig`.
    ``store_path=None`` keeps the journal in memory; a path gets a durable
    stdlib-sqlite journal that :meth:`recover` can replay after a crash.
    Remaining keyword arguments (``u``, ``horizon``, ``engine``,
    ``feedback``, ``monitor_every``, ``clock``) flow to
    :class:`~repro.service.daemon.Daemon`.
    """

    def __init__(self, cluster: Cluster, *, policy: str = "sjf-bco",
                 params: "dict | None" = None,
                 tenants: "dict[str, TenantConfig] | None" = None,
                 store_path: "str | None" = None,
                 round_slots: int = 1, max_batch: "int | None" = None,
                 _store=None, **daemon_kwargs):
        default = TenantConfig(policy=policy,
                               params=tuple(sorted((params or {}).items())))
        queue = QueueManager(default, tenants, round_slots=round_slots,
                             max_batch=max_batch)
        store = _store if _store is not None else open_store(store_path)
        self.daemon = Daemon(cluster, store, queue, **daemon_kwargs)

    # -- client surface ---------------------------------------------------

    def submit(self, request: SubmitRequest) -> JobHandle:
        """Admit one job; it is journaled and queued for the next round."""
        record = self.daemon.admit(request.job, request.arrival,
                                   request.tenant)
        return JobHandle(jid=record.jid, tenant=record.tenant)

    def cancel(self, handle: "JobHandle | int") -> bool:
        """Withdraw a job that has not been placed yet; False otherwise."""
        jid = handle.jid if isinstance(handle, JobHandle) else int(handle)
        return self.daemon.cancel(jid)

    def status(self, handle: "JobHandle | int",
               refresh: bool = True) -> JobStatus:
        """The job's current lifecycle state and placement.

        ``refresh=True`` first runs the monitor loop up to the current
        virtual clock, so completions that already happened in virtual
        time are reflected (``RUNNING -> DONE``)."""
        if refresh:
            self.daemon.monitor()
        jid = handle.jid if isinstance(handle, JobHandle) else int(handle)
        record = self.daemon.records[jid]
        return JobStatus(
            jid=record.jid, tenant=record.tenant, state=record.state,
            arrival=record.arrival,
            gpus=None if record.gpus is None
            else tuple(int(g) for g in record.gpus),
            start=record.start, finish=record.finish)

    def step(self) -> bool:
        """Run one scheduling round; False when the queue is empty."""
        return self.daemon.step()

    def drain(self, sim_horizon: int = 10**7
              ) -> "tuple[ScheduleResult, SimResult]":
        """Schedule everything queued, run virtual-time execution to
        completion, and return ``(schedule, sim)`` -- the same result pair
        a one-shot policy call plus :func:`~repro.core.simulator.simulate`
        would produce for the identical trace."""
        return self.daemon.drain(sim_horizon=sim_horizon)

    def table(self) -> str:
        """Human-readable state table (jid, tenant, state, placement)."""
        rows = ["  jid tenant     state      gpus                start"
                "      finish"]
        for jid in sorted(self.daemon.records):
            r = self.daemon.records[jid]
            gpus = ("-" if r.gpus is None
                    else ",".join(str(int(g)) for g in r.gpus[:6])
                    + ("..." if len(r.gpus) > 6 else ""))
            start = "-" if r.start is None else f"{r.start:.1f}"
            finish = "-" if r.finish is None else f"{r.finish:.1f}"
            rows.append(f"  {jid:3d} {r.tenant:<10.10s} {r.state.value:<10s} "
                        f"{gpus:<19s} {start:>10s} {finish:>11s}")
        return "\n".join(rows)

    def close(self) -> None:
        """Close the journal store (flushes a sqlite WAL)."""
        self.daemon.store.close()

    # -- recovery ---------------------------------------------------------

    @classmethod
    def recover(cls, cluster: "Cluster | None", store_path: str, *,
                policy: str = "sjf-bco", params: "dict | None" = None,
                tenants: "dict[str, TenantConfig] | None" = None,
                round_slots: int = 1, max_batch: "int | None" = None,
                _store=None, **daemon_kwargs) -> "SchedulerService":
        """Rebuild a service from a journal left by a dead daemon.

        Replays the journal (see :meth:`repro.service.daemon.Daemon.recover`),
        re-enqueues in-flight work, and returns a service ready to
        ``step``/``drain`` -- with placements and busy-time clocks
        bit-identical to the crashed process's.  ``cluster`` may be
        ``None``: the journal's opening ``cluster`` record reconstructs
        it exactly, heterogeneous speed/link arrays included."""
        service = cls.__new__(cls)
        default = TenantConfig(policy=policy,
                               params=tuple(sorted((params or {}).items())))
        queue = QueueManager(default, tenants, round_slots=round_slots,
                             max_batch=max_batch)
        store = _store if _store is not None else open_store(store_path)
        service.daemon = Daemon.recover(cluster, store, queue,
                                        **daemon_kwargs)
        return service

    @property
    def clock(self) -> VirtualClock:
        """The daemon's virtual clock."""
        return self.daemon.clock
