"""Queue manager: batches pending arrivals into scheduling rounds.

Queued jobs are held in a min-heap keyed ``(arrival, G_j, jid)`` -- the
visit order of :func:`repro.core.api.schedule_arrivals` -- so however the
daemon slices rounds (one arrival slot at a time, wider windows via
``round_slots``, or hard caps via ``max_batch``), the concatenation of all
rounds processes jobs in exactly the order the one-shot epoch loop would.
That invariant is what makes the daemon path result-identical to a direct
``schedule_arrivals`` call (asserted by ``bench_service.py --quick``).

Per-tenant scheduling configuration lives here too: each tenant maps to a
:class:`TenantConfig` naming a registered policy and its params; the
daemon resolves the tenant's online chooser through
:func:`repro.core.api.get_chooser` -- the same registry every policy's own
``arrivals`` branch uses.
"""
from __future__ import annotations

import dataclasses
import heapq

from repro.service.state import JobRecord

__all__ = ["TenantConfig", "QueueManager"]


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's scheduling knobs: a registered policy name plus the
    ``params`` its chooser factory understands (``seed`` for RAND, ...;
    the contention ``engine`` is daemon-wide, since all tenants share one
    :class:`~repro.core.api.PlacementState`)."""

    policy: str = "sjf-bco"
    params: tuple[tuple[str, object], ...] = ()

    def param_dict(self) -> dict:
        """``params`` as the dict the chooser factories expect."""
        return dict(self.params)


class QueueManager:
    """Pending-arrival queue + per-tenant config.

    ``round_slots`` bounds how many distinct arrival slots one round may
    span (default 1: a round is one arrival slot's batch); ``max_batch``
    caps the round size in jobs.  Neither affects the processing order,
    only how much work each :meth:`next_batch` hands the daemon."""

    def __init__(self, default: TenantConfig | None = None,
                 tenants: "dict[str, TenantConfig] | None" = None,
                 round_slots: int = 1,
                 max_batch: "int | None" = None):
        self.default = default or TenantConfig()
        self.tenants = dict(tenants or {})
        if round_slots < 1:
            raise ValueError("round_slots must be >= 1")
        self.round_slots = round_slots
        self.max_batch = max_batch
        self._heap: list[tuple[int, int, int]] = []   # (arrival, G, jid)
        self._records: dict[int, JobRecord] = {}
        self._cancelled: set[int] = set()

    def config_for(self, tenant: str) -> TenantConfig:
        """The tenant's config (the default for unknown tenants)."""
        return self.tenants.get(tenant, self.default)

    def push(self, record: JobRecord) -> None:
        """Enqueue a QUEUED record for a future scheduling round."""
        self._records[record.jid] = record
        self._cancelled.discard(record.jid)
        heapq.heappush(self._heap,
                       (record.arrival, record.job.num_gpus, record.jid))

    def cancel(self, jid: int) -> bool:
        """Lazily drop ``jid`` from the queue; True if it was queued."""
        if jid not in self._records or jid in self._cancelled:
            return False
        self._cancelled.add(jid)
        return True

    def __len__(self) -> int:
        return len(self._records) - len(self._cancelled)

    def peek_arrival(self) -> "int | None":
        """Arrival slot of the earliest queued job, or None if empty."""
        self._drop_cancelled()
        return self._heap[0][0] if self._heap else None

    def _drop_cancelled(self) -> None:
        while self._heap and self._heap[0][2] in self._cancelled:
            jid = heapq.heappop(self._heap)[2]
            self._cancelled.discard(jid)
            del self._records[jid]

    def next_batch(self) -> list[JobRecord]:
        """Pop the next scheduling round, in ``(arrival, G_j, jid)`` order.

        The round covers queued jobs whose arrival slot falls within
        ``round_slots`` slots of the earliest pending arrival, capped at
        ``max_batch`` jobs; empty list when nothing is queued."""
        self._drop_cancelled()
        if not self._heap:
            return []
        cutoff = self._heap[0][0] + self.round_slots
        batch: list[JobRecord] = []
        while self._heap and self._heap[0][0] < cutoff:
            if self.max_batch is not None and len(batch) >= self.max_batch:
                break
            _, _, jid = heapq.heappop(self._heap)
            batch.append(self._records.pop(jid))
            self._drop_cancelled()
        return batch
