"""Write-ahead journal stores for the scheduler daemon.

The daemon journals every externally-visible step -- submissions, state
transitions (with the exact placement floats), virtual-clock advances --
as an append-only sequence of :class:`JournalEntry` records.  Recovery is
pure replay: :meth:`repro.service.daemon.Daemon.recover` folds the journal
back into job records and re-commits journaled placements into a fresh
:class:`~repro.core.api.PlacementState` in journal order, which reproduces
the busy-time clocks bit-for-bit (same float operands, same order).

Two backends share the interface:

  * :class:`MemoryStore` -- a list; for tests (its :meth:`MemoryStore.prefix`
    powers the fault-injection loop that crashes the daemon after every
    journaled event) and for benchmarks that isolate scheduling cost.
  * :class:`SqliteStore` -- stdlib ``sqlite3`` in WAL mode, one row per
    entry; survives process death, so a daemon pointed at the same path
    picks up exactly where the last one crashed.

Payload floats (``rho``, ``start``, ``finish``) must round-trip exactly:
JSON via ``repr`` and SQLite ``REAL`` columns both preserve IEEE-754
doubles bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json
import sqlite3

__all__ = ["JournalEntry", "MemoryStore", "SqliteStore", "open_store"]


@dataclasses.dataclass(frozen=True)
class JournalEntry:
    """One journaled event.

    ``kind`` is ``"cluster"`` (entry 1 of every fresh journal: the
    :meth:`~repro.core.cluster.Cluster.to_payload` description, so
    recovery can rebuild heterogeneous clusters without out-of-band
    state), ``"submit"`` (payload: tenant, arrival, job fields),
    ``"transition"`` (payload: ``to`` state plus, for RUNNING, the exact
    ``gpus``/``rho``/``start``; for DONE, ``finish``; for outcomes of a
    stateful chooser, its post-decision ``rng`` generator state),
    ``"advance"`` (payload: the virtual-clock slot ``t`` of a round),
    ``"decided"`` (empty payload: closes a chooser decision's
    PLACING..decided bracket, making its replay all-or-nothing), or a
    preemption record -- ``"evict"`` / ``"resize"`` (payload: the exact
    eviction instant ``t`` plus the residual's ``iters``/``num_gpus``;
    see :mod:`repro.core.preempt`) -- journaled inside the preempting
    arrival's decision bracket."""

    seq: int
    ts: float                  # virtual-clock stamp (deterministic tests)
    kind: str
    jid: int                   # -1 for job-less entries (advance)
    payload: dict

    def to_json(self) -> str:
        """Payload as canonical JSON (floats via repr: exact round-trip)."""
        return json.dumps(self.payload, sort_keys=True)


class MemoryStore:
    """In-memory journal: a list of entries, no durability."""

    def __init__(self, entries: "list[JournalEntry] | None" = None):
        self._entries: list[JournalEntry] = list(entries or [])

    def append(self, kind: str, jid: int, payload: dict,
               ts: float = 0.0) -> JournalEntry:
        """Append one entry; returns it with its assigned sequence number."""
        entry = JournalEntry(seq=len(self._entries) + 1, ts=ts, kind=kind,
                             jid=jid, payload=payload)
        self._entries.append(entry)
        return entry

    def entries(self) -> list[JournalEntry]:
        """The whole journal, in append order."""
        return list(self._entries)

    def prefix(self, n: int) -> "MemoryStore":
        """A copy holding only the first ``n`` entries -- a simulated
        crash snapshot for the fault-injection recovery tests."""
        return MemoryStore(self._entries[:n])

    def __len__(self) -> int:
        return len(self._entries)

    def close(self) -> None:
        """No-op (symmetry with :class:`SqliteStore`)."""


class SqliteStore:
    """Durable journal on stdlib ``sqlite3``.

    WAL journaling keeps appends atomic under crashes; each ``append``
    commits, so an entry either exists completely or not at all -- the
    property the recovery replay relies on."""

    def __init__(self, path: str):
        self.path = path
        self._db = sqlite3.connect(path)
        self._db.execute("PRAGMA journal_mode=WAL")
        self._db.execute(
            "CREATE TABLE IF NOT EXISTS journal ("
            " seq INTEGER PRIMARY KEY AUTOINCREMENT,"
            " ts REAL NOT NULL,"
            " kind TEXT NOT NULL,"
            " jid INTEGER NOT NULL,"
            " payload TEXT NOT NULL)")
        self._db.commit()

    def append(self, kind: str, jid: int, payload: dict,
               ts: float = 0.0) -> JournalEntry:
        """Append + commit one entry; returns it with its sequence number."""
        cur = self._db.execute(
            "INSERT INTO journal (ts, kind, jid, payload) VALUES (?,?,?,?)",
            (ts, kind, jid, json.dumps(payload, sort_keys=True)))
        self._db.commit()
        return JournalEntry(seq=cur.lastrowid, ts=ts, kind=kind, jid=jid,
                            payload=payload)

    def entries(self) -> list[JournalEntry]:
        """The whole journal, in sequence order."""
        rows = self._db.execute(
            "SELECT seq, ts, kind, jid, payload FROM journal ORDER BY seq")
        return [JournalEntry(seq=s, ts=ts, kind=k, jid=j,
                             payload=json.loads(p))
                for s, ts, k, j, p in rows]

    def __len__(self) -> int:
        return int(self._db.execute(
            "SELECT COUNT(*) FROM journal").fetchone()[0])

    def close(self) -> None:
        """Close the connection (flushes the WAL)."""
        self._db.close()


def open_store(path: "str | None" = None):
    """``None`` -> :class:`MemoryStore`, else :class:`SqliteStore` at path."""
    return MemoryStore() if path is None else SqliteStore(path)
