"""Write-ahead journal stores for the scheduler daemon.

The daemon journals every externally-visible step -- submissions, state
transitions (with the exact placement floats), virtual-clock advances --
as an append-only sequence of :class:`JournalEntry` records.  Recovery is
pure replay: :meth:`repro.service.daemon.Daemon.recover` folds the journal
back into job records and re-commits journaled placements into a fresh
:class:`~repro.core.api.PlacementState` in journal order, which reproduces
the busy-time clocks bit-for-bit (same float operands, same order).

Two backends share the interface:

  * :class:`MemoryStore` -- a list; for tests (its :meth:`MemoryStore.prefix`
    powers the fault-injection loop that crashes the daemon after every
    journaled event) and for benchmarks that isolate scheduling cost.
  * :class:`SqliteStore` -- stdlib ``sqlite3`` in WAL mode, one row per
    entry; survives process death, so a daemon pointed at the same path
    picks up exactly where the last one crashed.

Payload floats (``rho``, ``start``, ``finish``) must round-trip exactly:
JSON via ``repr`` and SQLite ``REAL`` columns both preserve IEEE-754
doubles bit-for-bit.

Both stores also support **snapshot compaction**: a long-running daemon's
journal grows by ~6 entries per job, so :meth:`MemoryStore.snapshot` /
:meth:`SqliteStore.snapshot` fold the longest quiescent prefix (every
closed PLACING..decided bracket) into one ``"snapshot"`` record via
:func:`compact_entries`.  The snapshot keeps exactly what replay needs --
the submitted jobs, final lifecycle states, and the ordered stream of
placement-state mutations with their journaled floats -- so
:meth:`repro.service.daemon.Daemon.recover` over ``cluster + snapshot +
tail`` rebuilds busy-time clocks bit-identical to replaying the
uncompacted journal.
"""
from __future__ import annotations

import dataclasses
import json
import sqlite3

__all__ = ["JournalEntry", "MemoryStore", "SqliteStore", "compact_entries",
           "open_store"]


@dataclasses.dataclass(frozen=True)
class JournalEntry:
    """One journaled event.

    ``kind`` is ``"cluster"`` (entry 1 of every fresh journal: the
    :meth:`~repro.core.cluster.Cluster.to_payload` description, so
    recovery can rebuild heterogeneous clusters without out-of-band
    state), ``"submit"`` (payload: tenant, arrival, job fields),
    ``"transition"`` (payload: ``to`` state plus, for RUNNING, the exact
    ``gpus``/``rho``/``start``; for DONE, ``finish``; for outcomes of a
    stateful chooser, its post-decision ``rng`` generator state),
    ``"advance"`` (payload: the virtual-clock slot ``t`` of a round),
    ``"decided"`` (empty payload: closes a chooser decision's
    PLACING..decided bracket, making its replay all-or-nothing), or a
    preemption record -- ``"evict"`` / ``"resize"`` (payload: the exact
    eviction instant ``t`` plus the residual's ``iters``/``num_gpus``;
    see :mod:`repro.core.preempt`) -- journaled inside the preempting
    arrival's decision bracket.  A compacted journal additionally holds
    one ``"snapshot"`` entry right after the cluster record: the folded
    prefix produced by :func:`compact_entries`."""

    seq: int
    ts: float                  # virtual-clock stamp (deterministic tests)
    kind: str
    jid: int                   # -1 for job-less entries (advance)
    payload: dict

    def to_json(self) -> str:
        """Payload as canonical JSON (floats via repr: exact round-trip)."""
        return json.dumps(self.payload, sort_keys=True)


def compact_entries(entries: "list[JournalEntry]"
                    ) -> "tuple[list[JournalEntry], list[JournalEntry]] | None":
    """Fold the longest quiescent journal prefix into one snapshot record.

    Returns ``(folded, tail)`` where ``folded`` is ``[cluster_entry,
    snapshot_entry]`` and ``tail`` is the unfolded suffix (entries inside
    a still-open PLACING..decided bracket, which replay must see verbatim
    to apply-or-drop atomically), or ``None`` when there is nothing to
    fold.  The walk mirrors :meth:`repro.service.daemon.Daemon.recover`
    exactly: brackets fold only once their closing ``decided`` record is
    present, and an abandoned bracket's entries are dropped (recovery
    drops them too, so the compacted journal replays to the same state).

    The snapshot payload is what replay needs and nothing more:

    * ``jobs`` -- every submission in jid order (tenant, arrival, the
      *original* job fields) plus its final lifecycle state;
    * ``ops`` -- the ordered placement-state mutations: ``adv`` (the
      real-time clock advance journaled by each PLACING), ``commit``
      (the exact ``gpus``/``rho``/``start`` floats -- U += charges are
      float-order-sensitive, so order is preserved), ``evict``/``resize``
      (replayed through :func:`repro.core.preempt.evict`, residual
      cross-checked), and ``done`` (observed finishes, replayed into the
      engines under ``feedback="actual"``);
    * ``rounds`` / ``t`` -- the round counter and final virtual-clock
      slot the dropped ``advance`` entries had accumulated;
    * ``rng`` -- each tenant's last journaled chooser generator state.

    A prefix that already starts with a snapshot is re-folded: the old
    snapshot seeds the walk, so compaction composes.
    """
    if len(entries) < 2 or entries[0].kind != "cluster":
        return None
    jobs: list[dict] = []
    ops: list[dict] = []
    rounds, t = 0, 0.0
    rng: dict = {}
    start = 1
    if entries[1].kind == "snapshot":
        prev = entries[1].payload
        jobs = [dict(j) for j in prev["jobs"]]
        ops = list(prev["ops"])
        rounds, t = int(prev["rounds"]), float(prev["t"])
        rng = dict(prev["rng"])
        start = 2

    def fold(entry: JournalEntry) -> None:
        nonlocal rounds, t
        if entry.kind == "submit":
            if entry.jid != len(jobs):
                raise ValueError(f"journal gap: submit jid {entry.jid} != "
                                 f"next jid {len(jobs)}")
            jobs.append({"tenant": entry.payload["tenant"],
                         "arrival": int(entry.payload["arrival"]),
                         "job": entry.payload["job"], "state": "PENDING"})
        elif entry.kind == "advance":
            rounds += 1
            t = max(t, float(entry.payload["t"]))
        elif entry.kind == "transition":
            rec = jobs[entry.jid]
            to = entry.payload["to"]
            rec["state"] = to
            if to == "PLACING":
                ops.append({"op": "adv", "t": float(rec["arrival"])})
            elif to == "RUNNING":
                ops.append({"op": "commit", "jid": entry.jid,
                            "gpus": entry.payload["gpus"],
                            "rho": entry.payload["rho"],
                            "start": entry.payload["start"]})
            elif to == "DONE":
                rec["finish"] = entry.payload["finish"]
                ops.append({"op": "done", "jid": entry.jid,
                            "finish": entry.payload["finish"]})
            if "rng" in entry.payload:
                rng[rec["tenant"]] = entry.payload["rng"]
        elif entry.kind in ("evict", "resize"):
            ops.append({"op": entry.kind, "jid": entry.jid,
                        "t": entry.payload["t"],
                        "iters": entry.payload["iters"],
                        "num_gpus": entry.payload["num_gpus"]})
        elif entry.kind != "decided":      # decided: pure bracket delimiter
            raise ValueError(
                f"cannot fold journal entry kind {entry.kind!r}")

    safe = start                # index just past the last folded entry
    buf: "tuple[int, list] | None" = None
    i = start
    while i < len(entries):
        entry = entries[i]
        if buf is not None:
            jid0, pending = buf
            abandoned = entry.kind in ("advance", "submit") or (
                entry.kind == "transition"
                and (entry.payload["to"] == "DONE"
                     or (entry.jid == jid0
                         and entry.payload["to"] == "PLACING")))
            if not abandoned:
                pending.append(entry)
                if entry.kind == "decided" and entry.jid == jid0:
                    for buffered in pending:
                        fold(buffered)
                    buf = None
                    safe = i + 1
                i += 1
                continue
            buf = None          # fall through: fold `entry` normally
        if entry.kind == "transition" and \
                entry.payload["to"] == "PLACING":
            buf = (entry.jid, [entry])
            i += 1
            continue
        fold(entry)
        safe = i + 1
        i += 1
    if safe <= start:
        return None
    last = entries[safe - 1]
    snap = JournalEntry(seq=last.seq, ts=last.ts, kind="snapshot", jid=-1,
                        payload={"jobs": jobs, "ops": ops, "rounds": rounds,
                                 "t": t, "rng": rng})
    return [entries[0], snap], entries[safe:]


class MemoryStore:
    """In-memory journal: a list of entries, no durability."""

    def __init__(self, entries: "list[JournalEntry] | None" = None):
        self._entries: list[JournalEntry] = list(entries or [])
        # Sequence numbers survive compaction (a snapshot replaces many
        # entries by one), so the counter is persistent, not len+1.
        self._next_seq = self._entries[-1].seq + 1 if self._entries else 1

    def append(self, kind: str, jid: int, payload: dict,
               ts: float = 0.0) -> JournalEntry:
        """Append one entry; returns it with its assigned sequence number."""
        entry = JournalEntry(seq=self._next_seq, ts=ts, kind=kind,
                             jid=jid, payload=payload)
        self._next_seq += 1
        self._entries.append(entry)
        return entry

    def entries(self) -> list[JournalEntry]:
        """The whole journal, in append order."""
        return list(self._entries)

    def prefix(self, n: int) -> "MemoryStore":
        """A copy holding only the first ``n`` entries -- a simulated
        crash snapshot for the fault-injection recovery tests."""
        return MemoryStore(self._entries[:n])

    def snapshot(self) -> int:
        """Compact via :func:`compact_entries`; returns entries saved."""
        folded = compact_entries(self._entries)
        if folded is None:
            return 0
        kept, tail = folded
        saved = len(self._entries) - len(kept) - len(tail)
        self._entries = kept + tail
        return saved

    def __len__(self) -> int:
        return len(self._entries)

    def close(self) -> None:
        """No-op (symmetry with :class:`SqliteStore`)."""


class SqliteStore:
    """Durable journal on stdlib ``sqlite3``.

    WAL journaling keeps appends atomic under crashes; each ``append``
    commits, so an entry either exists completely or not at all -- the
    property the recovery replay relies on."""

    def __init__(self, path: str):
        self.path = path
        self._db = sqlite3.connect(path)
        self._db.execute("PRAGMA journal_mode=WAL")
        self._db.execute(
            "CREATE TABLE IF NOT EXISTS journal ("
            " seq INTEGER PRIMARY KEY AUTOINCREMENT,"
            " ts REAL NOT NULL,"
            " kind TEXT NOT NULL,"
            " jid INTEGER NOT NULL,"
            " payload TEXT NOT NULL)")
        self._db.commit()

    def append(self, kind: str, jid: int, payload: dict,
               ts: float = 0.0) -> JournalEntry:
        """Append + commit one entry; returns it with its sequence number."""
        cur = self._db.execute(
            "INSERT INTO journal (ts, kind, jid, payload) VALUES (?,?,?,?)",
            (ts, kind, jid, json.dumps(payload, sort_keys=True)))
        self._db.commit()
        return JournalEntry(seq=cur.lastrowid, ts=ts, kind=kind, jid=jid,
                            payload=payload)

    def entries(self) -> list[JournalEntry]:
        """The whole journal, in sequence order."""
        rows = self._db.execute(
            "SELECT seq, ts, kind, jid, payload FROM journal ORDER BY seq")
        return [JournalEntry(seq=s, ts=ts, kind=k, jid=j,
                             payload=json.loads(p))
                for s, ts, k, j, p in rows]

    def snapshot(self) -> int:
        """Compact via :func:`compact_entries`; returns rows saved.

        The folded rows are replaced by one ``snapshot`` row carrying the
        last folded sequence number, in a single transaction; AUTOINCREMENT
        keeps later appends above every seq ever issued, so compaction
        never reuses a sequence number."""
        entries = self.entries()
        folded = compact_entries(entries)
        if folded is None:
            return 0
        (cluster, snap), tail = folded
        self._db.execute("DELETE FROM journal WHERE seq > ? AND seq <= ?",
                         (cluster.seq, snap.seq))
        self._db.execute(
            "INSERT INTO journal (seq, ts, kind, jid, payload) "
            "VALUES (?,?,?,?,?)",
            (snap.seq, snap.ts, snap.kind, snap.jid,
             json.dumps(snap.payload, sort_keys=True)))
        self._db.commit()
        return len(entries) - 2 - len(tail)

    def __len__(self) -> int:
        return int(self._db.execute(
            "SELECT COUNT(*) FROM journal").fetchone()[0])

    def close(self) -> None:
        """Close the connection (flushes the WAL)."""
        self._db.close()


def open_store(path: "str | None" = None):
    """``None`` -> :class:`MemoryStore`, else :class:`SqliteStore` at path."""
    return MemoryStore() if path is None else SqliteStore(path)
