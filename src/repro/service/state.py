"""Explicit job state machine of the scheduler service.

Every job a :class:`~repro.service.api.SchedulerService` accepts walks a
validated lifecycle::

    PENDING -> QUEUED -> PLACING -> RUNNING -> DONE
       |          ^         |        |  \\-> FAILED
       |          |         +-> FAILED (no feasible placement)
       |          |         +-> QUEUED (crash recovery re-enqueue)
       |          +-----------------/   (preemption: evicted mid-run)
       \\-> CANCELLED (cancel only before placement)

``PENDING`` is the instant between journaling a submission and admitting
it to the queue manager; ``PLACING`` brackets exactly the window in which
the daemon runs the policy chooser, so a journal whose last word on a job
is ``PLACING`` identifies work lost to a crash (recovery re-enqueues it
and the deterministic chooser re-derives the same placement).  Under the
paper's non-preemptive Eq. (3) setting ``RUNNING`` jobs are only observed
to ``DONE`` by the monitor loop; the preemptive policy family
(:mod:`repro.core.preempt`) adds ``RUNNING -> QUEUED``: an evicted job
re-enters the queue as its residual (checkpointed) remainder, journaled
as an ``evict``/``resize`` record so recovery replays the preemption
exactly.

Transitions not in :data:`TRANSITIONS` raise :class:`InvalidTransition`;
both the live daemon and journal replay go through
:meth:`JobRecord.advance`, so a corrupt or hand-edited journal fails loud
instead of reconstructing an impossible state.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.jobs import Job

__all__ = ["JobState", "TRANSITIONS", "TERMINAL", "InvalidTransition",
           "JobRecord"]


class JobState(str, enum.Enum):
    """Lifecycle states of a service-managed job."""

    PENDING = "PENDING"        # journaled, not yet admitted to the queue
    QUEUED = "QUEUED"          # waiting for a scheduling round
    PLACING = "PLACING"        # the chooser is deciding (crash window)
    RUNNING = "RUNNING"        # placement committed, executing
    DONE = "DONE"              # observed complete by the monitor
    CANCELLED = "CANCELLED"    # withdrawn before placement
    FAILED = "FAILED"          # no feasible placement within the budget


#: Validated transition relation; ``PLACING -> QUEUED`` is the crash
#: recovery re-enqueue and ``RUNNING -> QUEUED`` the preemptive eviction
#: (repro.core.preempt), everything else is the normal lifecycle.
TRANSITIONS: dict[JobState, frozenset[JobState]] = {
    JobState.PENDING: frozenset({JobState.QUEUED, JobState.CANCELLED}),
    JobState.QUEUED: frozenset({JobState.PLACING, JobState.CANCELLED}),
    JobState.PLACING: frozenset({JobState.RUNNING, JobState.FAILED,
                                 JobState.QUEUED}),
    JobState.RUNNING: frozenset({JobState.DONE, JobState.FAILED,
                                 JobState.QUEUED}),
    JobState.DONE: frozenset(),
    JobState.CANCELLED: frozenset(),
    JobState.FAILED: frozenset(),
}

#: States with no outgoing transitions.
TERMINAL: frozenset[JobState] = frozenset(
    s for s, outs in TRANSITIONS.items() if not outs)


class InvalidTransition(ValueError):
    """Raised on a lifecycle move outside :data:`TRANSITIONS`."""


@dataclasses.dataclass
class JobRecord:
    """One job's service-side record: identity, lifecycle, placement.

    ``rho`` and ``start`` keep the *exact* floats the placement was
    committed with (see :meth:`repro.core.api.PlacementState.commit`);
    journal replay re-commits them bit-for-bit, which is what makes a
    recovered daemon's busy-time clocks identical to the pre-crash ones.
    """

    jid: int
    tenant: str
    job: Job
    arrival: int
    state: JobState = JobState.PENDING
    gpus: np.ndarray | None = None     # placement (RUNNING and later)
    rho: float | None = None           # committed rho_hat(y^k) charge
    start: float | None = None         # committed est. gang start
    finish: float | None = None        # observed (simulated) finish

    def advance(self, to: JobState) -> None:
        """Validated transition; raises :class:`InvalidTransition`."""
        if to not in TRANSITIONS[self.state]:
            raise InvalidTransition(
                f"job {self.jid}: {self.state.value} -> {to.value} is not "
                f"a legal transition (allowed: "
                f"{sorted(s.value for s in TRANSITIONS[self.state])})")
        self.state = to
        if to is JobState.QUEUED:      # (re-)enqueued: placement is void
            self.gpus = None
            self.rho = None
            self.start = None
