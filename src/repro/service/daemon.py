"""The scheduler daemon: a crash-recoverable event loop over the online
scheduling path.

One :class:`Daemon` owns the persistent pieces a long-running scheduler
needs -- a live :class:`~repro.core.api.PlacementState`, the write-ahead
journal (:mod:`repro.service.store`), the queue manager, a virtual clock
-- and drives *scheduling rounds*: pop the next arrival batch, advance the
clocks, run each tenant's registered online chooser
(:func:`repro.core.api.get_chooser`), journal every transition.  Because
the chooser, the visit order ``(arrival, G_j, jid)`` and the busy-time
accounting are literally the same code
:func:`repro.core.api.schedule_arrivals` runs, the daemon's placements are
decision-for-decision identical to a one-shot ``schedule_arrivals`` call
on the same trace -- the service is a recoverable shell around the
paper's online path, not a fork of its semantics (asserted by
``benchmarks/bench_service.py --quick``).

Execution is virtual-time: the *monitor loop* runs
:func:`repro.core.simulator.simulate` over the committed assignment up to
the current clock and folds completions back (``RUNNING -> DONE``).  With
``feedback="actual"`` each completion is also fed into the incremental
engines via :meth:`~repro.core.api.PlacementState.observe_finish`, so
later placements price contention against observed finishes instead of
the rho-hat estimates (an opt-in extension: it deliberately changes
decisions, so the identity guarantee holds only for the default
``feedback="estimate"``).

Crash recovery (:meth:`Daemon.recover`) is pure journal replay: rebuild
the job records, re-commit journaled placements -- with the exact
``(gpus, rho, start)`` floats, in journal order, so U/R clocks come back
bit-for-bit -- and re-enqueue anything caught mid-``PLACING``; the
chooser then re-derives the same placement the crashed process was about
to make.  Stateful choosers (RAND) journal their rng state inside every
outcome transition, and replay restores it, so even stochastic policies
recover decision-for-decision.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.api import (PlacementState, ScheduleResult, finalize,
                            get_chooser)
from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.preempt import evict as apply_evict
from repro.core.simulator import SimResult, simulate
from repro.service.queue import QueueManager
from repro.service.state import TERMINAL, JobRecord, JobState
from repro.service.store import MemoryStore

__all__ = ["VirtualClock", "Daemon", "FEEDBACK_MODES"]

FEEDBACK_MODES = ("estimate", "actual")


class VirtualClock:
    """Injectable monotone clock in simulator slots.

    The daemon advances it to each round's arrival slot; journal
    timestamps come from it, so tests (and the fault-injection loop) see
    fully deterministic journals.  Inject a wall-clock adapter (anything
    with ``now()``/``advance(t)``) to stamp real time instead."""

    def __init__(self, t0: float = 0.0):
        self._now = float(t0)

    def now(self) -> float:
        """Current virtual time (slots)."""
        return self._now

    def advance(self, t: float) -> None:
        """Move forward to ``t`` (never backwards)."""
        self._now = max(self._now, float(t))


class Daemon:
    """Event loop + journal + recovery for one cluster's scheduler."""

    def __init__(self, cluster: Cluster, store=None,
                 queue: "QueueManager | None" = None, *,
                 u: float = 1.5, horizon: int = 1200,
                 engine: "str | None" = None,
                 feedback: str = "estimate",
                 monitor_every: int = 0,
                 clock: "VirtualClock | None" = None):
        if feedback not in FEEDBACK_MODES:
            raise ValueError(f"unknown feedback mode {feedback!r}; "
                             f"choose from {FEEDBACK_MODES}")
        self.cluster = cluster
        self.store = store if store is not None else MemoryStore()
        # NB: not ``queue or ...`` -- an empty QueueManager is falsy (len 0).
        self.queue = queue if queue is not None else QueueManager()
        self.u = float(u)
        self.horizon = int(horizon)
        self.feedback = feedback
        # 0 = lazy (monitor only on status/drain); k = every k rounds.
        # feedback="actual" needs completions before each round to act on
        # them, so it forces per-round monitoring.
        self.monitor_every = 1 if feedback == "actual" else int(monitor_every)
        self.clock = clock or VirtualClock()
        if len(self.store) == 0:
            # A fresh journal opens with the cluster description, so
            # recover() can rebuild heterogeneous clusters (per-GPU
            # speeds, per-server link classes) exactly from the journal
            # alone instead of being handed the object out-of-band.
            self.store.append("cluster", -1, cluster.to_payload(),
                              ts=self.clock.now())
        self.state = PlacementState(cluster, engine=engine)
        self.state.commit_hook = self._capture_commit
        self.state.evict_hook = self._capture_evict
        self.records: dict[int, JobRecord] = {}
        self.jobs: list[Job] = []          # jid-indexed (jid == list index)
        self.arrivals: list[int] = []
        self.rounds = 0
        self.decision_latencies: list[float] = []   # seconds, per chooser run
        self._choosers: dict[str, object] = {}
        # One chooser decision may mutate the state several times (a
        # preemptive chooser evicts, re-places the residual, then places
        # the arrival); the hooks record every mutation in order so step()
        # can journal the whole decision as one PLACING..RUNNING bracket.
        self._events: list[tuple] = []
        self._mutations = 0                # total state mutations ever
        self._sim_cache: "tuple | None" = None      # ((mutations, limit), sim)

    # -- submission -------------------------------------------------------

    def admit(self, job: Job, arrival: int = 0,
              tenant: str = "default") -> JobRecord:
        """Journal + enqueue one submission; the job is renumbered so its
        jid is the daemon-wide submission index (the invariant simulator
        indexing and ``schedule_arrivals`` identity both rely on)."""
        if arrival < 0:
            raise ValueError("arrival slot must be >= 0")
        jid = len(self.jobs)
        job = dataclasses.replace(job, jid=jid)
        record = JobRecord(jid=jid, tenant=tenant, job=job,
                           arrival=int(arrival))
        self.jobs.append(job)
        self.arrivals.append(int(arrival))
        self.records[jid] = record
        self.store.append("submit", jid,
                          {"tenant": tenant, "arrival": int(arrival),
                           "job": dataclasses.asdict(job)},
                          ts=self.clock.now())
        self._transition(record, JobState.QUEUED)
        self.queue.push(record)
        return record

    def cancel(self, jid: int) -> bool:
        """Withdraw a not-yet-placed job; False once it is beyond QUEUED
        (gang scheduling is non-preemptive, Eq. 3)."""
        record = self.records.get(jid)
        if record is None or record.state not in (JobState.PENDING,
                                                  JobState.QUEUED):
            return False
        self.queue.cancel(jid)
        self._transition(record, JobState.CANCELLED)
        return True

    # -- the event loop ---------------------------------------------------

    def step(self) -> bool:
        """Run one scheduling round; False when nothing is queued.

        A round pops the queue manager's next arrival batch, journals an
        ``advance`` to the batch's latest arrival slot, and for each job
        (already in ``schedule_arrivals``'s visit order) journals
        ``PLACING``, advances the real-time clocks to its arrival, runs
        the tenant's chooser against the shared placement state, and
        journals the outcome (``RUNNING`` with the exact committed
        placement, or ``FAILED``)."""
        batch = self.queue.next_batch()
        if not batch:
            return False
        self.rounds += 1
        t_round = max(r.arrival for r in batch)
        self.store.append("advance", -1, {"t": t_round}, ts=self.clock.now())
        self.clock.advance(t_round)
        theta = float(self.horizon)
        for record in batch:
            chooser = self._chooser_for(record.tenant)
            self._transition(record, JobState.PLACING)
            self.state.advance_to(record.arrival)
            self._events = []
            t0 = time.perf_counter()
            ok = chooser(self.state, record.job, theta)
            self.decision_latencies.append(time.perf_counter() - t0)
            # Stateful choosers (RAND) snapshot their post-decision rng
            # state INSIDE the outcome transition: one atomic append, so
            # there is no crash window between the outcome and the state
            # the next decision must start from.
            get_state = getattr(chooser, "get_state", None)
            extra = {} if get_state is None else {"rng": get_state()}
            if not ok:
                if self._events:
                    raise RuntimeError(
                        f"chooser mutated the placement state while failing "
                        f"to place job {record.jid} (trial preemption must "
                        "run on a clone)")
                self._transition(record, JobState.FAILED, **extra)
                self.store.append("decided", record.jid, {},
                                  ts=self.clock.now())
                continue
            events = self._events
            if sum(1 for ev in events
                   if ev[0] == "commit" and ev[1] == record.jid) != 1:
                raise RuntimeError(
                    f"chooser must commit job {record.jid} exactly once "
                    f"while placing it (got events "
                    f"{[(e[0], getattr(e[1], 'jid', e[1])) for e in events]})")
            # Journal the decision's event stream in journal == commit
            # order (U += charges are float-order-sensitive, so replay
            # must re-commit in the live order); the closing ``decided``
            # record makes the bracket atomic: replay applies all of it
            # or none of it (_replay buffers between PLACING and the
            # ``decided``).
            for ev in events:
                if ev[0] == "evict":
                    _, vjob, t_ev, residual = ev
                    vrec = self.records[vjob.jid]
                    if vrec.state is not JobState.RUNNING:
                        raise RuntimeError(
                            f"chooser evicted job {vjob.jid} in state "
                            f"{vrec.state.value}; preemptive policies need "
                            "est-consistent completion feedback (run with "
                            'monitor_every=0 or feedback="actual")')
                    kind = "resize" \
                        if residual.num_gpus != vjob.num_gpus else "evict"
                    self.store.append(kind, vjob.jid,
                                      {"t": t_ev,
                                       "iters": residual.iters,
                                       "num_gpus": residual.num_gpus},
                                      ts=self.clock.now())
                    self._transition(vrec, JobState.QUEUED)
                    vrec.job = residual
                elif ev[1] == record.jid:       # the arrival itself
                    _, jid, gpus, rho, start = ev
                    record.gpus, record.rho, record.start = gpus, rho, start
                    self._transition(record, JobState.RUNNING,
                                     gpus=[int(g) for g in gpus],
                                     rho=rho, start=start, **extra)
                else:         # the victim's residual re-placement
                    _, jid2, gpus2, rho2, start2 = ev
                    vrec = self.records[jid2]
                    self._transition(vrec, JobState.PLACING)
                    vrec.gpus, vrec.rho, vrec.start = gpus2, rho2, start2
                    self._transition(vrec, JobState.RUNNING,
                                     gpus=[int(g) for g in gpus2],
                                     rho=rho2, start=start2)
            self.store.append("decided", record.jid, {},
                              ts=self.clock.now())
        if self.monitor_every and self.rounds % self.monitor_every == 0:
            self.monitor()
        return True

    def drain(self, sim_horizon: int = 10**7
              ) -> "tuple[ScheduleResult, SimResult]":
        """Run rounds until the queue is empty, then let the virtual-time
        execution run to completion; returns the frozen schedule (the
        same :func:`~repro.core.api.finalize` shape every policy emits)
        and the final simulation."""
        while self.step():
            pass
        sim = self.monitor(at=sim_horizon)
        schedule = finalize(self.state, len(self.jobs), float(self.horizon),
                            None, self.queue.default.policy.upper())
        return schedule, sim

    # -- the monitor loop -------------------------------------------------

    def monitor(self, at: "int | None" = None) -> SimResult:
        """Execute the committed assignment in virtual time up to ``at``
        (default: the clock's now) and fold completions back: RUNNING jobs
        whose simulated finish lands within the window advance to DONE
        (journaled), and with ``feedback="actual"`` their observed
        finishes are pushed into the placement state's incremental
        engines via :meth:`~repro.core.api.PlacementState.observe_finish`."""
        limit = int(at if at is not None else self.clock.now())
        key = (self._mutations, limit)
        if self._sim_cache is not None and self._sim_cache[0] == key:
            sim = self._sim_cache[1]
        else:
            sim = simulate(self.cluster, self.jobs, self.state.assignment,
                           horizon=limit,
                           arrivals=np.asarray(self.arrivals, dtype=np.int64)
                           if self.jobs else None,
                           quotas=np.asarray(self.state.seg_quota)
                           if self.state.preempted else None)
            self._sim_cache = (key, sim)
        for record in self.records.values():
            if record.state is not JobState.RUNNING:
                continue
            finish = int(sim.finish[record.jid])
            if finish < 0:
                continue
            record.finish = float(finish)
            self._transition(record, JobState.DONE, finish=finish)
            if self.feedback == "actual":
                self.state.observe_finish(record.job, record.gpus,
                                          float(finish))
        return sim

    # -- crash recovery ---------------------------------------------------

    @classmethod
    def recover(cls, cluster: "Cluster | None", store,
                queue: "QueueManager | None" = None, **kwargs) -> "Daemon":
        """Rebuild a daemon from its journal.

        ``cluster`` may be ``None``: journals opened by this daemon start
        with a ``cluster`` record, from which the exact cluster --
        heterogeneous speed/link arrays included -- is reconstructed.  A
        cluster passed alongside such a journal is cross-checked against
        the record (replaying a journal onto a different cluster would
        silently reprice every placement).

        Replays every entry in sequence order: submissions recreate the
        job records, ``RUNNING`` transitions re-commit the journaled
        ``(gpus, rho, start)`` into a fresh placement state (same float
        operands, same order -- the recovered U/R clocks are bit-identical
        to the crashed daemon's), and jobs whose last word is ``QUEUED``
        or ``PLACING`` are re-enqueued (the latter via a journaled
        recovery transition).  Stateful choosers (RAND's rng) restore the
        generator state snapshotted in each outcome transition, so a job
        caught mid-``PLACING`` is re-decided from exactly the pre-decision
        rng state -- recovery is decision-for-decision exact for every
        registered policy, stochastic ones included.

        A compacted journal (see
        :func:`repro.service.store.compact_entries`) starts with a
        ``snapshot`` record; :meth:`_load_snapshot` rebuilds the folded
        prefix's records and clocks bit-identically, then the tail
        replays through the same bracket-buffered loop as ever."""
        entries = store.entries()
        journaled = None
        if entries and entries[0].kind == "cluster":
            journaled = Cluster.from_payload(entries[0].payload)
        if cluster is None:
            if journaled is None:
                raise ValueError(
                    "journal has no cluster record (pre-heterogeneity "
                    "journal); pass the cluster explicitly")
            cluster = journaled
        daemon = cls(cluster, store, queue, **kwargs)
        # A chooser decision is journaled as a PLACING..decided bracket
        # (possibly containing evict/resize records, the victim's
        # re-placement, and the arrival's own RUNNING mid-bracket -- the
        # preempting arrival commits BEFORE the residual).  Replay
        # buffers each bracket and applies it only when its closing
        # ``decided`` record is present: a journal truncated mid-decision
        # leaves the state exactly pre-decision (victim still RUNNING on
        # its original placement), the job re-enqueues as QUEUED, and the
        # deterministic chooser re-derives the identical decision.
        buf: "tuple[int, list] | None" = None
        for entry in entries:
            if buf is not None:
                jid0, pending = buf
                # Entries a live bracket can never contain mark the open
                # one as abandoned (a crash cut it short and a recovered
                # daemon wrote on): a new round's advance, a submission,
                # a monitor completion, or the same job PLACING again.
                # Its pending entries were never applied pre-crash either,
                # so dropping them reproduces that daemon's state.
                abandoned = entry.kind in ("advance", "submit") or (
                    entry.kind == "transition"
                    and (entry.payload["to"] == JobState.DONE.value
                         or (entry.jid == jid0 and entry.payload["to"]
                             == JobState.PLACING.value)))
                if not abandoned:
                    pending.append(entry)
                    if entry.kind == "decided" and entry.jid == jid0:
                        for buffered in pending:
                            daemon._replay(buffered)
                        buf = None
                    continue
                buf = None          # fall through: replay `entry` normally
            if entry.kind == "transition" and \
                    entry.payload["to"] == JobState.PLACING.value:
                buf = (entry.jid, [entry])
                continue
            daemon._replay(entry)
        requeue = [r for r in daemon.records.values()
                   if r.state in (JobState.QUEUED, JobState.PLACING,
                                  JobState.PENDING)]
        for record in sorted(requeue, key=lambda r: r.jid):
            if record.state is not JobState.QUEUED:
                daemon._transition(record, JobState.QUEUED)
            daemon.queue.push(record)
        return daemon

    def _replay(self, entry) -> None:
        """Fold one journal entry back into records / state / clock."""
        if entry.kind == "cluster":
            if Cluster.from_payload(entry.payload) != self.cluster:
                raise ValueError(
                    "journal cluster record disagrees with the daemon's "
                    "cluster; replay the journal onto the journaled cluster")
            return
        if entry.kind == "snapshot":
            self._load_snapshot(entry.payload)
            return
        if entry.kind == "submit":
            if entry.jid != len(self.jobs):
                raise ValueError(
                    f"journal gap: submit jid {entry.jid} != next jid "
                    f"{len(self.jobs)}")
            job = Job(**entry.payload["job"])
            self.jobs.append(job)
            self.arrivals.append(int(entry.payload["arrival"]))
            self.records[entry.jid] = JobRecord(
                jid=entry.jid, tenant=entry.payload["tenant"], job=job,
                arrival=int(entry.payload["arrival"]))
        elif entry.kind == "advance":
            self.rounds += 1
            self.clock.advance(entry.payload["t"])
        elif entry.kind == "transition":
            record = self.records[entry.jid]
            to = JobState(entry.payload["to"])
            record.advance(to)
            if to is JobState.PLACING:
                # The live daemon advanced the real-time clocks right
                # after journaling PLACING; replay does too (idempotent
                # if the job is later re-placed: advance_to is a max).
                self.state.advance_to(record.arrival)
            elif to is JobState.RUNNING:
                gpus = np.asarray(entry.payload["gpus"], dtype=np.int64)
                rho = float(entry.payload["rho"])
                start = float(entry.payload["start"])
                self.state.advance_to(record.arrival)
                self.state.commit(record.job, gpus, rho, start, self.u)
                record.gpus, record.rho, record.start = gpus, rho, start
            elif to is JobState.DONE:
                record.finish = float(entry.payload["finish"])
                if self.feedback == "actual":
                    self.state.observe_finish(record.job, record.gpus,
                                              record.finish)
            snapshot = entry.payload.get("rng")
            if snapshot is not None:
                self._chooser_for(record.tenant).set_state(snapshot)
        elif entry.kind in ("evict", "resize"):
            # Re-run the checkpoint-restart surgery with the journaled
            # operands; evict() is float-exact over the committed state,
            # so the replayed residual must equal the journaled one
            # bit-for-bit (anything else means the journal diverged from
            # the placements replayed so far).
            record = self.records[entry.jid]
            residual = apply_evict(self.state, entry.jid,
                                   float(entry.payload["t"]), self.u,
                                   num_gpus=int(entry.payload["num_gpus"]))
            if residual is None or \
                    residual.iters != float(entry.payload["iters"]):
                raise ValueError(
                    f"journal divergence replaying {entry.kind} of job "
                    f"{entry.jid}: residual iters "
                    f"{None if residual is None else residual.iters} != "
                    f"journaled {entry.payload['iters']}")
            record.job = residual
        elif entry.kind == "decided":
            pass    # pure bracket delimiter; the entries it closed did the work
        else:
            raise ValueError(f"unknown journal entry kind {entry.kind!r}")

    def _load_snapshot(self, payload: dict) -> None:
        """Rebuild records and placement state from a compacted journal
        prefix (:func:`repro.service.store.compact_entries`).

        The ops stream replays the exact placement-state mutations the
        folded entries would have replayed -- same float operands, same
        order -- so the rebuilt U/R clocks are bit-identical to a full
        replay of the uncompacted journal.  Lifecycle states are assigned
        directly (the snapshot was folded from a journal that already
        passed :meth:`JobRecord.advance` validation entry by entry)."""
        if self.jobs:
            raise ValueError("snapshot record must precede all submissions")
        for jid, jp in enumerate(payload["jobs"]):
            job = Job(**jp["job"])
            self.jobs.append(job)
            self.arrivals.append(int(jp["arrival"]))
            self.records[jid] = JobRecord(jid=jid, tenant=jp["tenant"],
                                          job=job, arrival=int(jp["arrival"]))
        for op in payload["ops"]:
            kind = op["op"]
            if kind == "adv":
                self.state.advance_to(float(op["t"]))
            elif kind == "commit":
                record = self.records[op["jid"]]
                gpus = np.asarray(op["gpus"], dtype=np.int64)
                rho, start = float(op["rho"]), float(op["start"])
                self.state.advance_to(record.arrival)
                self.state.commit(record.job, gpus, rho, start, self.u)
                record.gpus, record.rho, record.start = gpus, rho, start
            elif kind in ("evict", "resize"):
                record = self.records[op["jid"]]
                residual = apply_evict(self.state, op["jid"],
                                       float(op["t"]), self.u,
                                       num_gpus=int(op["num_gpus"]))
                if residual is None or \
                        residual.iters != float(op["iters"]):
                    raise ValueError(
                        f"snapshot divergence replaying {kind} of job "
                        f"{op['jid']}: residual iters "
                        f"{None if residual is None else residual.iters} "
                        f"!= snapshotted {op['iters']}")
                record.job = residual
                record.gpus = record.rho = record.start = None
            elif kind == "done":
                record = self.records[op["jid"]]
                record.finish = float(op["finish"])
                if self.feedback == "actual":
                    self.state.observe_finish(record.job, record.gpus,
                                              record.finish)
            else:
                raise ValueError(f"unknown snapshot op kind {kind!r}")
        for jid, jp in enumerate(payload["jobs"]):
            record = self.records[jid]
            record.state = JobState(jp["state"])
            if record.state in (JobState.PENDING, JobState.QUEUED):
                record.gpus = record.rho = record.start = None
        self.rounds = int(payload["rounds"])
        self.clock.advance(float(payload["t"]))
        for tenant, snap in payload["rng"].items():
            self._chooser_for(tenant).set_state(snap)

    # -- internals --------------------------------------------------------

    def _capture_commit(self, job, gpus, rho, start) -> None:
        """PlacementState.commit_hook: capture the exact committed floats
        (journaling est_finish - est_start would not round-trip rho)."""
        self._mutations += 1
        self._events.append(("commit", job.jid, np.asarray(gpus),
                             float(rho), float(start)))

    def _capture_evict(self, job, t_ev, residual) -> None:
        """PlacementState.evict_hook: capture a preemption so step() can
        journal it (an ``evict``/``resize`` record plus the victim's
        RUNNING -> QUEUED transition) inside the decision bracket."""
        self._mutations += 1
        self._events.append(("evict", job, float(t_ev), residual))

    def _chooser_for(self, tenant: str):
        """The tenant's online chooser (built once per tenant via the
        core chooser registry)."""
        if tenant not in self._choosers:
            cfg = self.queue.config_for(tenant)
            factory = get_chooser(cfg.policy)
            self._choosers[tenant] = factory(self.cluster, self.u,
                                             cfg.param_dict())
        return self._choosers[tenant]

    def _transition(self, record: JobRecord, to: JobState,
                    **payload) -> None:
        """Validate, apply, then journal one lifecycle transition."""
        record.advance(to)
        self.store.append("transition", record.jid,
                          {"to": to.value, **payload}, ts=self.clock.now())

    @property
    def active(self) -> int:
        """Jobs not yet in a terminal state."""
        return sum(1 for r in self.records.values()
                   if r.state not in TERMINAL)
