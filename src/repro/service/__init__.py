"""repro.service: a long-running scheduler daemon over the paper's online
path, with a validated job lifecycle, a write-ahead journal (in-memory or
stdlib sqlite) and crash recovery by replay.

The service adds *operability*, not new scheduling semantics: every
placement decision flows through the same chooser registry and
:class:`~repro.core.api.PlacementState` that
:func:`repro.core.api.schedule_arrivals` uses, so a drained service
reproduces the one-shot online schedule decision-for-decision (asserted
by ``benchmarks/bench_service.py --quick``).  Start with
:class:`~repro.service.api.SchedulerService`.
"""
from repro.service.api import (JobHandle, JobStatus, SchedulerService,
                               SubmitRequest)
from repro.service.daemon import Daemon, VirtualClock
from repro.service.queue import QueueManager, TenantConfig
from repro.service.state import (TERMINAL, TRANSITIONS, InvalidTransition,
                                 JobRecord, JobState)
from repro.service.store import (JournalEntry, MemoryStore, SqliteStore,
                                 compact_entries, open_store)

__all__ = [
    "SchedulerService", "SubmitRequest", "JobHandle", "JobStatus",
    "Daemon", "VirtualClock",
    "QueueManager", "TenantConfig",
    "JobState", "JobRecord", "TRANSITIONS", "TERMINAL", "InvalidTransition",
    "JournalEntry", "MemoryStore", "SqliteStore", "compact_entries",
    "open_store",
]
