"""Forward-compat shims for the jax API surface this repo targets.

The substrate and its tests are written against the modern spelling
(``jax.shard_map``, ``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``,
``jax.lax.axis_size``); the container pins an older jax where those names
live elsewhere or do not exist.  Importing :mod:`repro` backfills each
missing name onto jax — the same pattern as :mod:`repro.kernels.compat`
for the ``pltpu.CompilerParams`` rename.  Every shim is guarded with
``hasattr``, so on a jax that already provides the name this module is a
no-op and the native implementation wins.

When jax is not installed at all (the numpy-only scheduler-core install:
``pip install rar-sched`` without the ``[jax]`` extra), this module is a
silent no-op so ``repro.core`` keeps working.
"""
from __future__ import annotations

import contextlib

try:
    import jax
    import jax.sharding
except ImportError:                       # numpy-only install
    jax = None


def _active_mesh():
    """The mesh made ambient by ``with mesh:`` / ``jax.set_mesh`` (or None)."""
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:  # pragma: no cover - internal layout drift
        return None


def _apply_shims() -> None:
    """Backfill the missing modern names onto the imported jax."""
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        jax.shard_map = _shard_map

    if not hasattr(jax.sharding, "get_abstract_mesh"):
        def _get_abstract_mesh():
            """Old-jax stand-in: the context mesh doubles as the abstract
            mesh (same ``axis_names`` / ``shape`` surface the in-model
            sharding hints consult)."""
            return _active_mesh()

        jax.sharding.get_abstract_mesh = _get_abstract_mesh

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def _set_mesh(mesh):
            """Context manager making ``mesh`` ambient, so bare
            ``PartitionSpec`` sharding constraints (and
            :func:`get_abstract_mesh`) resolve."""
            with mesh:
                yield mesh

        jax.set_mesh = _set_mesh

    if not hasattr(jax.lax, "axis_size"):
        def _axis_size(axis_name) -> int:
            """Static size of a named mapped axis (shard_map/pmap body)."""
            import jax.core as jcore

            return int(jcore.axis_frame(axis_name))

        jax.lax.axis_size = _axis_size


if jax is not None:
    _apply_shims()
