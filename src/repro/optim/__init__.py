from repro.optim.adamw import AdamWConfig, apply, global_norm, init, schedule

__all__ = ["AdamWConfig", "apply", "global_norm", "init", "schedule"]
