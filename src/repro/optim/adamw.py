"""AdamW with decoupled weight decay, cosine schedule, global-norm clip.

Implemented on raw pytrees (no optax in this environment).  Moment dtype is
configurable: the frontier configs (llama3-405b, kimi-k2) keep bf16 params
with fp32 moments sharded like the params (ZeRO-3-style via the sharding
rules), which is what fits the 16 GB/chip v5e budget (DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"
    grad_accum_steps: int = 1          # microbatching: peak activation
                                       # memory scales ~1/accum_steps


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params: Any) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(cfg: AdamWConfig, grads: Any, params: Any, state: dict
          ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.float32(1.0)
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, p, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree.map(upd, grads, params, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
