"""Unified Model API + family dispatch.

``build_model(cfg, max_seq)`` returns a ``Model`` whose five functions are
pure (params in, arrays out) and jit/pjit-ready.  ``max_seq`` sizes learned
position tables (whisper) only; every other family is length-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    config: ModelConfig
    init: Callable[..., Any]
    loss_fn: Callable[..., Any]          # (params, batch) -> (loss, metrics)
    prefill: Callable[..., Any]          # (params, batch) -> logits
    init_cache: Callable[..., Any]       # (batch, max_slots) -> cache
    decode_step: Callable[..., Any]      # (params, cache, tok, pos) -> (logits, cache)
    encode: Callable[..., Any] | None = None   # audio: (params, frames) -> enc_out


def build_model(cfg: ModelConfig, max_seq: int = 4096) -> Model:
    from repro.models import transformer, xlstm
    if cfg.family in ("dense", "vlm"):
        fns = transformer.build_dense(cfg, max_seq)
    elif cfg.family == "moe":
        fns = transformer.build_moe(cfg, max_seq)
    elif cfg.family == "hybrid":
        fns = transformer.build_hybrid(cfg, max_seq)
    elif cfg.family == "audio":
        fns = transformer.build_audio(cfg, max_seq)
    elif cfg.family == "ssm":
        fns = xlstm.build_xlstm(cfg, max_seq)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return Model(cfg, *fns)
