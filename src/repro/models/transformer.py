"""Family builders: dense / moe / hybrid / vlm / audio transformers.

Every builder returns the same functional ``Model`` API (see model.py):

  init(rng)                          -> params pytree
  loss_fn(params, batch)             -> (loss, metrics)        [train_*]
  prefill(params, batch)             -> logits                 [prefill_*]
  init_cache(batch, max_slots)       -> decode cache pytree
  decode_step(params, cache, tok, pos) -> (logits, new cache)  [decode_*]

Layers are stacked with ``lax.scan`` over param pytrees whose leaves carry a
leading ``[L]`` dim (compile time is O(1) in depth -- llama3-405B's 126
layers lower as one scanned block).  Per-layer heterogeneity (gemma2's
local/global alternation, hymba's global-attention islands) rides along the
scan as a ``windows[L]`` array consumed inside the mask, so no unrolling or
lax.cond is needed.  ``cfg.remat`` wraps the block in jax.checkpoint
(full recompute policy) for the big training configs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (KVCache, attention, init_attn, init_embedding,
                                 init_kv_cache, init_mlp, init_rms_norm, mlp,
                                 rms_norm, sinusoidal_positions,
                                 softmax_cross_entropy)
from repro.models.moe import init_moe, moe_apply
from repro.models.ssm import (init_mamba, init_mamba_state, mamba_seq,
                              mamba_step)

# ---------------------------------------------------------------------------
# per-layer window schedule
# ---------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """windows[L]: 0 = full/global attention, >0 = sliding window."""
    L = cfg.n_layers
    if cfg.layer_pattern == "local_global" and cfg.window:
        w = [cfg.window if (i % 2 == 0) else 0 for i in range(L)]
    elif cfg.family == "hybrid" and cfg.window:
        # Hymba: global attention at first, middle and last layer only.
        glob = {0, L // 2, L - 1}
        w = [0 if i in glob else cfg.window for i in range(L)]
    elif cfg.window:
        w = [cfg.window] * L
    else:
        w = [0] * L
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# decoder block (dense / moe / hybrid)
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, dtype, *, kind: str, d_ff: int = 0):
    """kind: dense | moe | hybrid | cross (audio decoder)."""
    r = jax.random.split(rng, 6)
    p = {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attn(r[0], cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
    }
    if kind == "moe":
        p["moe"] = init_moe(r[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(r[1], cfg.d_model, d_ff or cfg.d_ff, cfg.mlp, dtype)
    if kind == "hybrid":
        p["mamba"] = init_mamba(r[2], cfg, dtype)
    if kind == "cross":
        p["ln_x"] = init_rms_norm(cfg.d_model, dtype)
        p["xattn"] = init_attn(r[3], cfg, dtype)
    return p


def block_apply(cfg: ModelConfig, p, x, q_pos, window, *, kind: str,
                cache: KVCache | None = None, ssm_state=None,
                enc_out=None, causal: bool = True):
    """Returns (x, new_cache, new_ssm_state, aux_loss)."""
    from repro.models.layers import BATCH_AXES, shard_hint
    sp = cfg.seq_shard_blocks and cache is None

    def _sp_resid(t):   # residual stream: sequence-sharded over "model"
        return shard_hint(t, BATCH_AXES, "model", None) if sp else t

    def _pin(t):        # stop XLA hoisting fp32 converts across this value
        return jax.lax.optimization_barrier(t) if cfg.barrier_block_inputs \
            else t

    # Megatron-SP: norms/residual/remat-saves live S-sharded (1/16 size).
    x = _sp_resid(x)
    h = _pin(rms_norm(x, p["ln1"], cfg.norm_eps, cfg.norm_cast_early))
    attn_out, new_cache = attention(
        cfg, p["attn"], h, q_pos, window=window, cache=cache,
        rope=cfg.rope != "none", causal=causal)
    if kind == "hybrid":
        if ssm_state is None:
            m_out = mamba_seq(cfg, p["mamba"], h)
            new_ssm = None
        else:
            m_out, new_ssm = mamba_step(cfg, p["mamba"], ssm_state, h[:, 0])
            m_out = m_out[:, None, :]
        attn_out = 0.5 * (attn_out + m_out)          # Hymba parallel fusion
    else:
        new_ssm = None
    x = _sp_resid(x + _sp_resid(attn_out))
    if kind == "cross":
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps, cfg.norm_cast_early)
        x_out, _ = attention(cfg, p["xattn"], hx, q_pos, enc_out=enc_out,
                             rope=False)
        x = x + x_out
    h2 = _pin(rms_norm(x, p["ln2"], cfg.norm_eps, cfg.norm_cast_early))
    if kind == "moe":
        ff, aux = moe_apply(cfg, p["moe"], h2)
    else:
        ff, aux = mlp(p["mlp"], h2, cfg.mlp), jnp.zeros((), jnp.float32)
    return _sp_resid(x + _sp_resid(ff)), new_cache, new_ssm, aux


# ---------------------------------------------------------------------------
# stack runners (scan over layers)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def run_stack(cfg: ModelConfig, stacked, x, q_pos, windows, *, kind: str,
              enc_out=None, causal: bool = True):
    """Train/prefill pass over L scanned layers.  Returns (x, aux_sum)."""

    def body_fn(p, x, w):
        y, _, _, aux = block_apply(cfg, p, x, q_pos, w, kind=kind,
                                   enc_out=enc_out, causal=causal)
        return y, aux

    body = _maybe_remat(body_fn, cfg)

    def step(carry, per):
        x, aux = carry
        p, w = per
        y, a = body(p, x, w)
        return (y, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               (stacked, windows))
    return x, aux


def run_stack_decode(cfg: ModelConfig, stacked, x, q_pos, windows, caches,
                     *, kind: str, ssm_states=None, enc_out=None):
    """One-token decode across L scanned layers; carries updated caches."""

    def step(x, per):
        if kind == "hybrid":
            p, w, cache, sstate = per
        else:
            p, w, cache = per
            sstate = None
        y, new_cache, new_sstate, _ = block_apply(
            cfg, p, x, q_pos, w, kind=kind, cache=cache, ssm_state=sstate,
            enc_out=enc_out)
        ys = (new_cache, new_sstate) if kind == "hybrid" else new_cache
        return y, ys

    xs = (stacked, windows, caches) if kind != "hybrid" else \
         (stacked, windows, caches, ssm_states)
    x, new = jax.lax.scan(step, x, xs)
    return x, new


# ---------------------------------------------------------------------------
# shared model scaffolding
# ---------------------------------------------------------------------------


def _embed_in(params, cfg, tokens):
    cd = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cd)
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), cd)   # gemma2 embeds scaled
    from repro.models.layers import BATCH_AXES, shard_hint
    return shard_hint(x, BATCH_AXES, None, None)


def _padded_vocab(cfg) -> int:
    return -(-cfg.vocab // 256) * 256


def _unembed(params, cfg, x):
    """Project to (padded) vocabulary.  Returns [..., Vp] with the padded
    tail pinned to -1e30 (invisible to softmax/argmax); callers on the
    public API slice back to cfg.vocab via _public_logits.  Padding to a
    multiple of 256 keeps the logits slab model-axis shardable for the
    odd-sized vocabs (whisper 51865, internvl 151655)."""
    from repro.models.layers import BATCH_AXES, shard_hint
    cd = x.dtype
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    V, Vp = cfg.vocab, _padded_vocab(cfg)
    if Vp != V:
        table = jnp.pad(table, ((0, 0), (0, Vp - V)))
    logits = x @ table.astype(cd)
    # keep the [B, S, V] slab batch- AND vocab-sharded: at 128k-256k vocabs
    # an unsharded logits tensor alone would overflow HBM
    logits = shard_hint(logits, BATCH_AXES, None, "model")
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)
    if Vp != V:
        pad_mask = jax.lax.broadcasted_iota(jnp.int32, (Vp,), 0) >= V
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return shard_hint(logits, BATCH_AXES, None, "model")


def _public_logits(cfg, logits):
    return logits[..., : cfg.vocab] if _padded_vocab(cfg) != cfg.vocab \
        else logits


def _init_common(rng, cfg: ModelConfig, dtype):
    r = jax.random.split(rng, 3)
    p = {"embed": init_embedding(r[0], cfg.vocab, cfg.d_model, dtype),
         "ln_f": init_rms_norm(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(r[1], (cfg.d_model, cfg.vocab))
                        / jnp.sqrt(cfg.d_model)).astype(dtype)
    return p


def _positions(batch: int, seq: int):
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))


# ---------------------------------------------------------------------------
# DENSE (gemma2 / chatglm3 / llama3) and VLM (internvl2 backbone)
# ---------------------------------------------------------------------------


def build_dense(cfg: ModelConfig, max_seq: int):
    dtype = jnp.dtype(cfg.param_dtype)
    windows = layer_windows(cfg)
    is_vlm = cfg.family == "vlm"

    def init(rng):
        r = jax.random.split(rng, 3)
        p = _init_common(r[0], cfg, dtype)
        p["layers"] = jax.vmap(
            lambda k: init_block(k, cfg, dtype, kind="dense")
        )(jax.random.split(r[1], cfg.n_layers))
        if is_vlm:
            p["projector"] = (jax.random.normal(r[2], (cfg.d_model, cfg.d_model))
                              / jnp.sqrt(cfg.d_model)).astype(dtype)
        return p

    def _forward(params, batch):
        tokens = batch["tokens"]
        x = _embed_in(params, cfg, tokens)
        if is_vlm:
            cd = x.dtype
            patches = batch["patches"].astype(cd) @ params["projector"].astype(cd)
            x = jnp.concatenate([patches, x], axis=1)
        q_pos = _positions(x.shape[0], x.shape[1])
        x, aux = run_stack(cfg, params["layers"], x, q_pos, windows,
                           kind="dense")
        return _unembed(params, cfg, x), aux

    def loss_fn(params, batch):
        logits, aux = _forward(params, batch)
        tokens = batch["tokens"]
        n_txt = tokens.shape[1]
        logits = logits[:, -n_txt:-1] if not is_vlm else logits[:, -n_txt - 1:-1]
        labels = tokens[:, 1:] if not is_vlm else tokens
        loss = softmax_cross_entropy(logits, labels) + aux
        return loss, {"loss": loss, "aux": aux}

    def prefill(params, batch):
        logits, _ = _forward(params, batch)
        return _public_logits(cfg, logits)

    def init_cache(batch_size: int, max_slots: int):
        cd = jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)
        kv = jax.vmap(lambda _: init_kv_cache(
            batch_size, max_slots, cfg.n_kv_heads, cfg.head_dim, cd)
        )(jnp.arange(cfg.n_layers))
        return {"kv": kv}

    def decode_step(params, cache, tok, pos):
        x = _embed_in(params, cfg, tok[:, None])
        q_pos = pos[:, None].astype(jnp.int32)
        x, new_kv = run_stack_decode(cfg, params["layers"], x, q_pos, windows,
                                     cache["kv"], kind="dense")
        logits = _public_logits(cfg, _unembed(params, cfg, x))
        return logits[:, 0], {"kv": new_kv}

    return init, loss_fn, prefill, init_cache, decode_step


# ---------------------------------------------------------------------------
# MOE (deepseek-moe-16b / kimi-k2): leading dense layer(s) + scanned MoE stack
# ---------------------------------------------------------------------------


def build_moe(cfg: ModelConfig, max_seq: int):
    dtype = jnp.dtype(cfg.param_dtype)
    n_moe = cfg.n_layers - cfg.n_dense_layers
    windows = layer_windows(cfg)[cfg.n_dense_layers:]

    def init(rng):
        r = jax.random.split(rng, 3)
        p = _init_common(r[0], cfg, dtype)
        if cfg.n_dense_layers:
            p["dense_layers"] = jax.vmap(
                lambda k: init_block(k, cfg, dtype, kind="dense",
                                     d_ff=cfg.dense_d_ff)
            )(jax.random.split(r[1], cfg.n_dense_layers))
        p["layers"] = jax.vmap(
            lambda k: init_block(k, cfg, dtype, kind="moe")
        )(jax.random.split(r[2], n_moe))
        return p

    def _run_dense_prefix(params, x, q_pos):
        if not cfg.n_dense_layers:
            return x, jnp.zeros((), jnp.float32)
        return run_stack(cfg, params["dense_layers"], x, q_pos,
                         jnp.zeros((cfg.n_dense_layers,), jnp.int32),
                         kind="dense")

    def _forward(params, batch):
        tokens = batch["tokens"]
        x = _embed_in(params, cfg, tokens)
        q_pos = _positions(*tokens.shape)
        x, aux0 = _run_dense_prefix(params, x, q_pos)
        x, aux = run_stack(cfg, params["layers"], x, q_pos, windows, kind="moe")
        return _unembed(params, cfg, x), aux0 + aux

    def loss_fn(params, batch):
        logits, aux = _forward(params, batch)
        tokens = batch["tokens"]
        loss = softmax_cross_entropy(logits[:, :-1], tokens[:, 1:]) + aux
        return loss, {"loss": loss, "aux": aux}

    def prefill(params, batch):
        return _public_logits(cfg, _forward(params, batch)[0])

    def init_cache(batch_size: int, max_slots: int):
        cd = jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)
        mk = lambda n: jax.vmap(lambda _: init_kv_cache(
            batch_size, max_slots, cfg.n_kv_heads, cfg.head_dim, cd)
        )(jnp.arange(n))
        cache = {"kv": mk(n_moe)}
        if cfg.n_dense_layers:
            cache["kv_dense"] = mk(cfg.n_dense_layers)
        return cache

    def decode_step(params, cache, tok, pos):
        x = _embed_in(params, cfg, tok[:, None])
        q_pos = pos[:, None].astype(jnp.int32)
        new_cache = dict(cache)
        if cfg.n_dense_layers:
            x, new_dense = run_stack_decode(
                cfg, params["dense_layers"], x, q_pos,
                jnp.zeros((cfg.n_dense_layers,), jnp.int32),
                cache["kv_dense"], kind="dense")
            new_cache["kv_dense"] = new_dense
        x, new_kv = run_stack_decode(cfg, params["layers"], x, q_pos, windows,
                                     cache["kv"], kind="moe")
        new_cache["kv"] = new_kv
        logits = _public_logits(cfg, _unembed(params, cfg, x))
        return logits[:, 0], new_cache

    return init, loss_fn, prefill, init_cache, decode_step


# ---------------------------------------------------------------------------
# HYBRID (hymba: parallel attention + mamba heads)
# ---------------------------------------------------------------------------


def build_hybrid(cfg: ModelConfig, max_seq: int):
    dtype = jnp.dtype(cfg.param_dtype)
    windows = layer_windows(cfg)

    def init(rng):
        r = jax.random.split(rng, 2)
        p = _init_common(r[0], cfg, dtype)
        p["layers"] = jax.vmap(
            lambda k: init_block(k, cfg, dtype, kind="hybrid")
        )(jax.random.split(r[1], cfg.n_layers))
        return p

    def _forward(params, batch):
        tokens = batch["tokens"]
        x = _embed_in(params, cfg, tokens)
        q_pos = _positions(*tokens.shape)
        x, aux = run_stack(cfg, params["layers"], x, q_pos, windows,
                           kind="hybrid")
        return _unembed(params, cfg, x), aux

    def loss_fn(params, batch):
        logits, aux = _forward(params, batch)
        tokens = batch["tokens"]
        loss = softmax_cross_entropy(logits[:, :-1], tokens[:, 1:]) + aux
        return loss, {"loss": loss, "aux": aux}

    def prefill(params, batch):
        return _public_logits(cfg, _forward(params, batch)[0])

    def init_cache(batch_size: int, max_slots: int):
        cd = jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)
        kv = jax.vmap(lambda _: init_kv_cache(
            batch_size, max_slots, cfg.n_kv_heads, cfg.head_dim, cd)
        )(jnp.arange(cfg.n_layers))
        ssm = jax.vmap(lambda _: init_mamba_state(cfg, batch_size, cd)
                       )(jnp.arange(cfg.n_layers))
        return {"kv": kv, "ssm": ssm}

    def decode_step(params, cache, tok, pos):
        x = _embed_in(params, cfg, tok[:, None])
        q_pos = pos[:, None].astype(jnp.int32)
        x, new = run_stack_decode(cfg, params["layers"], x, q_pos, windows,
                                  cache["kv"], kind="hybrid",
                                  ssm_states=cache["ssm"])
        new_kv, new_ssm = new
        logits = _public_logits(cfg, _unembed(params, cfg, x))
        return logits[:, 0], {"kv": new_kv, "ssm": new_ssm}

    return init, loss_fn, prefill, init_cache, decode_step


# ---------------------------------------------------------------------------
# AUDIO (whisper-tiny): stub-frontend encoder + cross-attending decoder
# ---------------------------------------------------------------------------


def build_audio(cfg: ModelConfig, max_seq: int):
    dtype = jnp.dtype(cfg.param_dtype)
    dec_windows = layer_windows(cfg)

    def init(rng):
        r = jax.random.split(rng, 4)
        p = _init_common(r[0], cfg, dtype)
        p["enc_layers"] = jax.vmap(
            lambda k: init_block(k, cfg, dtype, kind="dense")
        )(jax.random.split(r[1], cfg.n_enc_layers))
        p["enc_ln_f"] = init_rms_norm(cfg.d_model, dtype)
        p["dec_layers"] = jax.vmap(
            lambda k: init_block(k, cfg, dtype, kind="cross")
        )(jax.random.split(r[2], cfg.n_layers))
        p["pos_emb"] = (jax.random.normal(r[3], (max_seq, cfg.d_model))
                        * 0.01).astype(dtype)
        return p

    def encode(params, frames):
        cd = jnp.dtype(cfg.compute_dtype)
        x = frames.astype(cd)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model, cd)[None]
        q_pos = _positions(x.shape[0], x.shape[1])
        x, _ = run_stack(cfg, params["enc_layers"], x, q_pos,
                         jnp.zeros((cfg.n_enc_layers,), jnp.int32),
                         kind="dense", causal=False)
        return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)

    def _decode_seq(params, enc_out, tokens):
        x = _embed_in(params, cfg, tokens)
        S = tokens.shape[1]
        x = x + params["pos_emb"][:S].astype(x.dtype)[None]
        q_pos = _positions(*tokens.shape)
        x, aux = run_stack(cfg, params["dec_layers"], x, q_pos, dec_windows,
                           kind="cross", enc_out=enc_out)
        return _unembed(params, cfg, x), aux

    def loss_fn(params, batch):
        enc_out = encode(params, batch["frames"])
        logits, aux = _decode_seq(params, enc_out, batch["tokens"])
        loss = softmax_cross_entropy(logits[:, :-1], batch["tokens"][:, 1:]) + aux
        return loss, {"loss": loss, "aux": aux}

    def prefill(params, batch):
        enc_out = encode(params, batch["frames"])
        return _public_logits(cfg, _decode_seq(params, enc_out,
                                               batch["tokens"])[0])

    def init_cache(batch_size: int, max_slots: int):
        cd = jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)
        kv = jax.vmap(lambda _: init_kv_cache(
            batch_size, max_slots, cfg.n_kv_heads, cfg.head_dim, cd)
        )(jnp.arange(cfg.n_layers))
        enc_out = jnp.zeros((batch_size, cfg.enc_frames, cfg.d_model),
                            jnp.dtype(cfg.compute_dtype))
        return {"kv": kv, "enc_out": enc_out}

    def decode_step(params, cache, tok, pos):
        x = _embed_in(params, cfg, tok[:, None])
        x = x + params["pos_emb"][pos].astype(x.dtype)[:, None, :]
        q_pos = pos[:, None].astype(jnp.int32)
        x, new_kv = run_stack_decode(cfg, params["dec_layers"], x, q_pos,
                                     dec_windows, cache["kv"], kind="cross",
                                     enc_out=cache["enc_out"])
        logits = _public_logits(cfg, _unembed(params, cfg, x))
        return logits[:, 0], {"kv": new_kv, "enc_out": cache["enc_out"]}

    return init, loss_fn, prefill, init_cache, decode_step, encode
