"""xLSTM language model (sLSTM + mLSTM blocks) — arXiv:2405.04517.

The stack is organised in super-blocks of ``slstm_every`` layers:
(slstm_every - 1) mLSTM blocks followed by one sLSTM block, scanned over
``G = n_layers // slstm_every`` groups (outer scan) with an inner scan over
the mLSTM blocks.  Decode state is sequence-length independent (matrix
memory C/n/m per mLSTM, scalar memories per sLSTM), which is what makes the
long_500k decode shape tractable for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, softmax_cross_entropy
from repro.models.ssm import (init_mlstm, init_mlstm_state, init_slstm,
                              init_slstm_state, mlstm_seq, mlstm_step,
                              slstm_seq, slstm_step)
from repro.models.transformer import (_init_common, _positions,
                                       _public_logits, _unembed)


def build_xlstm(cfg: ModelConfig, max_seq: int):
    dtype = jnp.dtype(cfg.param_dtype)
    k = cfg.slstm_every
    if k <= 0 or cfg.n_layers % k:
        raise ValueError("xlstm needs slstm_every | n_layers")
    G, n_m = cfg.n_layers // k, k - 1

    def init(rng):
        r = jax.random.split(rng, 3)
        p = _init_common(r[0], cfg, dtype)
        p["mlstm"] = jax.vmap(lambda kg: jax.vmap(
            lambda kk: init_mlstm(kk, cfg, dtype))(jax.random.split(kg, n_m))
        )(jax.random.split(r[1], G))
        p["slstm"] = jax.vmap(lambda kg: init_slstm(kg, cfg, dtype)
                              )(jax.random.split(r[2], G))
        return p

    def _group_seq(mp, sp, x):
        def mbody(x, pm):
            return mlstm_seq(cfg, pm, x), None
        x, _ = jax.lax.scan(mbody, x, mp)
        return slstm_seq(cfg, sp, x)

    group_seq = jax.checkpoint(_group_seq) if cfg.remat else _group_seq

    def _forward(params, batch):
        tokens = batch["tokens"]
        cd = jnp.dtype(cfg.compute_dtype)
        x = params["embed"][tokens].astype(cd)

        def body(x, per):
            mp, sp = per
            return group_seq(mp, sp, x), None

        x, _ = jax.lax.scan(body, x, (params["mlstm"], params["slstm"]))
        return _unembed(params, cfg, x)

    def loss_fn(params, batch):
        logits = _forward(params, batch)
        tokens = batch["tokens"]
        loss = softmax_cross_entropy(logits[:, :-1], tokens[:, 1:])
        return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}

    def prefill(params, batch):
        return _public_logits(cfg, _forward(params, batch))

    def init_cache(batch_size: int, max_slots: int):
        m = jax.vmap(lambda _: jax.vmap(
            lambda __: init_mlstm_state(cfg, batch_size))(jnp.arange(n_m))
        )(jnp.arange(G))
        s = jax.vmap(lambda _: init_slstm_state(cfg, batch_size)
                     )(jnp.arange(G))
        return {"mlstm": m, "slstm": s}

    def decode_step(params, cache, tok, pos):
        cd = jnp.dtype(cfg.compute_dtype)
        x = params["embed"][tok].astype(cd)                 # [B, d]

        def group_step(x, per):
            mp, sp, mstate, sstate = per

            def mstep(x, inp):
                pm, st = inp
                y, st2 = mlstm_step(cfg, pm, st, x)
                return y, st2

            x, new_m = jax.lax.scan(mstep, x, (mp, mstate))
            x, new_s = slstm_step(cfg, sp, sstate, x)
            return x, (new_m, new_s)

        x, (new_m, new_s) = jax.lax.scan(
            group_step, x,
            (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"]))
        logits = _public_logits(cfg, _unembed(params, cfg, x[:, None, :]))[:, 0]
        return logits, {"mlstm": new_m, "slstm": new_s}

    return init, loss_fn, prefill, init_cache, decode_step
