"""State-space & recurrent sequence mixers: Mamba head (Hymba) and the
xLSTM cells (mLSTM / sLSTM).

Design notes (TPU adaptation):
  * The selective-SSM recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
    ``lax.associative_scan`` over the sequence axis for train/prefill
    (log-depth, VPU-friendly) and as a one-step recurrence for decode.
  * The mLSTM's parallel form is computed attention-style with an additive
    log-decay bias matrix (quadratic in S -- used for train/prefill); decode
    uses the O(1) matrix-memory recurrence (C, n, m), which is what makes
    long_500k tractable for xlstm/hymba.
  * sLSTM is inherently sequential; train/prefill use ``lax.scan`` over time.
All state is seq-length independent => decode shapes carry tiny state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# Mamba-style selective SSM head (Hymba's parallel-to-attention branch)
# ---------------------------------------------------------------------------

_CONV_K = 4  # depthwise causal conv width


def init_mamba(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    Hs, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    inner = Hs * P
    r = jax.random.split(rng, 7)
    s = 1.0 / jnp.sqrt(d)
    return {
        "w_in": (jax.random.normal(r[0], (d, 2 * inner)) * s).astype(dtype),
        "w_conv": (jax.random.normal(r[1], (_CONV_K, inner)) * 0.2).astype(dtype),
        "w_B": (jax.random.normal(r[2], (Hs, P, N)) * P**-0.5).astype(dtype),
        "w_C": (jax.random.normal(r[3], (Hs, P, N)) * P**-0.5).astype(dtype),
        "w_dt": (jax.random.normal(r[4], (Hs, P)) * P**-0.5).astype(dtype),
        "dt_bias": jnp.zeros((Hs,), jnp.float32),
        "A_log": (jax.random.uniform(r[5], (Hs, P, N), minval=0.0, maxval=1.0)
                  ).astype(jnp.float32),
        "D": jnp.ones((Hs, P), dtype),
        "w_out": (jax.random.normal(r[6], (inner, d)) / jnp.sqrt(inner)).astype(dtype),
    }


def _mamba_gates(cfg, p, u):
    """Shared discretisation math.  u: [..., Hs, P] -> a, b coefficients."""
    dt = jax.nn.softplus(
        jnp.einsum("...hp,hp->...h", u.astype(jnp.float32), p["w_dt"].astype(jnp.float32))
        + p["dt_bias"])                                            # [..., Hs]
    A = -jnp.exp(p["A_log"])                                       # [Hs,P,N]
    Bmat = jnp.einsum("...hp,hpn->...hn", u, p["w_B"])             # [..., Hs,N]
    a = jnp.exp(dt[..., None, None] * A)                           # [..., Hs,P,N]
    b = (dt[..., None] * Bmat)[..., None, :] * u[..., None]        # [..., Hs,P,N]
    return a, b.astype(jnp.float32)


def mamba_seq(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Parallel (train/prefill) pass.  x: [B,S,d] -> [B,S,d]."""
    cd = x.dtype
    B, S, d = x.shape
    Hs, P = cfg.ssm_heads, cfg.ssm_head_dim
    inner = Hs * P
    uz = x @ p["w_in"].astype(cd)
    u, z = uz[..., :inner], uz[..., inner:]
    # depthwise causal conv over the sequence axis
    upad = jnp.pad(u, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    u = sum(upad[:, i:i + S] * p["w_conv"][i].astype(cd)
            for i in range(_CONV_K))
    u = jax.nn.silu(u).reshape(B, S, Hs, P)

    a, b = _mamba_gates(cfg, p, u)

    def comb(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(comb, (a.astype(jnp.float32), b), axis=1)
    C = jnp.einsum("bshp,hpn->bshn", u, p["w_C"]).astype(jnp.float32)
    y = jnp.einsum("bshpn,bshn->bshp", h, C).astype(cd) \
        + p["D"].astype(cd) * u
    y = (y.reshape(B, S, inner) * jax.nn.silu(z))
    return y @ p["w_out"].astype(cd)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    Hs, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "h": jnp.zeros((batch, Hs, P, N), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, Hs * P), dtype),
    }


def mamba_step(cfg: ModelConfig, p, state, x_t: jax.Array):
    """One decode step.  x_t: [B,d] -> ([B,d], new_state)."""
    cd = x_t.dtype
    B, d = x_t.shape
    Hs, P = cfg.ssm_heads, cfg.ssm_head_dim
    inner = Hs * P
    uz = x_t @ p["w_in"].astype(cd)
    u, z = uz[..., :inner], uz[..., inner:]
    hist = jnp.concatenate([state["conv"], u[:, None, :]], axis=1)  # [B,K,inner]
    u_c = sum(hist[:, i] * p["w_conv"][i].astype(cd) for i in range(_CONV_K))
    u_c = jax.nn.silu(u_c).reshape(B, Hs, P)

    a, b = _mamba_gates(cfg, p, u_c)
    h = a.astype(jnp.float32) * state["h"] + b
    C = jnp.einsum("bhp,hpn->bhn", u_c, p["w_C"]).astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", h, C).astype(cd) + p["D"].astype(cd) * u_c
    y = (y.reshape(B, inner) * jax.nn.silu(z)) @ p["w_out"].astype(cd)
    return y, {"h": h, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory)
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg: ModelConfig, dtype):
    """mLSTM block: pre-norm, up-projection (factor pf), q/k/v + i/f/o gates,
    matrix-memory mixing, gated down-projection."""
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.head_dim
    dp = int(cfg.mlstm_proj_factor * d)
    r = jax.random.split(rng, 6)
    s = 1.0 / jnp.sqrt(d)
    sp = 1.0 / jnp.sqrt(dp)
    return {
        "norm": jnp.ones((d,), dtype),
        "w_up": (jax.random.normal(r[0], (d, 2 * dp)) * s).astype(dtype),
        "w_qkv": (jax.random.normal(r[1], (dp, 3 * H * hd)) * sp).astype(dtype),
        "w_if": (jax.random.normal(r[2], (dp, 2 * H)) * sp).astype(jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "w_og": (jax.random.normal(r[3], (dp, H * hd)) * sp).astype(dtype),
        "w_down": (jax.random.normal(r[4], (H * hd, d)) / jnp.sqrt(H * hd)).astype(dtype),
    }


def _mlstm_qkvif(cfg, p, xe):
    H, hd = cfg.n_heads, cfg.head_dim
    qkv = xe @ p["w_qkv"].astype(xe.dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shape = xe.shape[:-1] + (H, hd)
    q, k, v = (t.reshape(shape) for t in (q, k, v))
    i_f = xe.astype(jnp.float32) @ p["w_if"] + p["if_bias"]
    i_pre, f_pre = jnp.split(i_f, 2, axis=-1)                      # [..., H]
    return q, k, v / jnp.sqrt(hd), i_pre, f_pre


def _mlstm_parallel_block(q_c, F_c, k, v, F, i_pre, t0, chunk):
    """One query-chunk of the mLSTM parallel form (fp32 in/out).

    q_c: [B,c,H,hd] queries for rows [t0, t0+c); F_c their cumulative
    log-forget; k/v/F/i_pre: full-sequence tensors.  The [c, S] decay slab
    is transient — the full [S, S] matrix never materialises (same shape
    trick as the q-chunked attention path).  Query rows context-parallelise
    over the "model" axis (4 mLSTM heads never tile it)."""
    import os
    from repro.models.layers import BATCH_AXES, shard_hint
    if not os.environ.get("REPRO_NAIVE_SHARDING"):
        q_c = shard_hint(q_c, BATCH_AXES, "model", None, None)
        F_c = shard_hint(F_c, BATCH_AXES, "model", None)
    B, S, H, hd = k.shape
    # D[b,h,t,s] = F_t - F_s + i_s  for s <= t   (log decay matrix)
    Dmat = F_c.transpose(0, 2, 1)[:, :, :, None] \
        - F.transpose(0, 2, 1)[:, :, None, :] \
        + i_pre.transpose(0, 2, 1)[:, :, None, :]                 # [B,H,c,S]
    t_idx = t0 + jnp.arange(q_c.shape[1])
    causal = t_idx[:, None] >= jnp.arange(S)[None, :]
    Dmat = jnp.where(causal[None, None], Dmat, -jnp.inf)
    m = jnp.max(Dmat, axis=-1, keepdims=True)                     # stabiliser
    w = jnp.exp(Dmat - m)
    scores = jnp.einsum("bthd,bshd->bhts", q_c, k) * w
    norm = jnp.maximum(jnp.abs(scores.sum(-1, keepdims=True)), jnp.exp(-m))
    return jnp.einsum("bhts,bshd->bthd", scores / norm, v)        # [B,c,H,hd]


def mlstm_seq(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Parallel form over the full sequence, query-chunked.  x: [B,S,d]."""
    from repro.models.layers import rms_norm
    cd = x.dtype
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    xe, zg = jnp.split(rms_norm(x, p["norm"], cfg.norm_eps) @ p["w_up"].astype(cd),
                       2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, xe)
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))

    logf = jax.nn.log_sigmoid(f_pre)                               # [B,S,H]
    F = jnp.cumsum(logf, axis=1)

    chunk = cfg.q_chunk if (cfg.q_chunk and S > cfg.q_chunk
                            and S % cfg.q_chunk == 0) else S
    if chunk == S:
        y = _mlstm_parallel_block(q, F, k, v, F, i_pre, 0, S)
    else:
        nc = S // chunk
        qs = jnp.moveaxis(q.reshape(B, nc, chunk, H, hd), 1, 0)
        Fs = jnp.moveaxis(F.reshape(B, nc, chunk, H), 1, 0)
        t0s = jnp.arange(nc) * chunk

        def body(_, inp):
            qc, Fc, t0 = inp
            return None, _mlstm_parallel_block(qc, Fc, k, v, F, i_pre,
                                               t0, chunk)

        _, ys = jax.lax.scan(jax.checkpoint(body), None, (qs, Fs, t0s))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
    y = y.reshape(B, S, H * hd).astype(cd)
    y = y * jax.nn.silu(zg @ p["w_og"].astype(cd))     # z-branch output gate
    return x + y @ p["w_down"].astype(cd)


def init_mlstm_state(cfg: ModelConfig, batch: int):
    H, hd = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_step(cfg: ModelConfig, p, state, x_t: jax.Array):
    """O(1) decode recurrence.  x_t: [B,d]."""
    from repro.models.layers import rms_norm
    cd = x_t.dtype
    B, d = x_t.shape
    H, hd = cfg.n_heads, cfg.head_dim
    xe, zg = jnp.split(rms_norm(x_t, p["norm"], cfg.norm_eps) @ p["w_up"].astype(cd),
                       2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, xe)
    logf = jax.nn.log_sigmoid(f_pre)                               # [B,H]
    m_new = jnp.maximum(logf + state["m"], i_pre)
    f_s = jnp.exp(logf + state["m"] - m_new)
    i_s = jnp.exp(i_pre - m_new)
    C = f_s[..., None, None] * state["C"] \
        + i_s[..., None, None] * jnp.einsum("bhk,bhv->bhkv",
                                            k.astype(jnp.float32),
                                            v.astype(jnp.float32))
    n = f_s[..., None] * state["n"] + i_s[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", C, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, H * hd).astype(cd)
    y = y * jax.nn.silu(zg @ p["w_og"].astype(cd))     # z-branch output gate
    out = x_t + y @ p["w_down"].astype(cd)
    return out, {"C": C, "n": n, "m": m_new}


def init_slstm(rng, cfg: ModelConfig, dtype):
    """sLSTM block: recurrent scalar-memory cell + post up/down MLP (pf 4/3)."""
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dff = int(d * 4 / 3)
    r = jax.random.split(rng, 4)
    s = 1.0 / jnp.sqrt(d)
    return {
        "norm": jnp.ones((d,), dtype),
        "w_x": (jax.random.normal(r[0], (d, 4 * d)) * s).astype(dtype),
        "r_h": (jax.random.normal(r[1], (H, dh, 4 * dh)) / jnp.sqrt(dh)).astype(dtype),
        "w_up": (jax.random.normal(r[2], (d, dff)) * s).astype(dtype),
        "w_down": (jax.random.normal(r[3], (dff, d)) / jnp.sqrt(dff)).astype(dtype),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, dh), -1e30)}


def _slstm_cell(cfg: ModelConfig, p, state, gx):
    """gx: [B, 4*d] pre-activations from the input path."""
    B = gx.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    rh = jnp.einsum("bhd,hdk->bhk", state["h"].astype(p["r_h"].dtype), p["r_h"])
    g = gx.reshape(B, H, 4 * dh).astype(jnp.float32) + rh.astype(jnp.float32)
    i_pre, f_pre, z_pre, o_pre = jnp.split(g, 4, axis=-1)          # [B,H,dh]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(logf + state["m"] - m_new)
    c = f_s * state["c"] + i_s * jnp.tanh(z_pre)
    n = f_s * state["n"] + i_s
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_seq(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Sequential scan over time.  x: [B,S,d]."""
    from repro.models.layers import rms_norm
    cd = x.dtype
    B, S, d = x.shape
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    gx = xn @ p["w_x"].astype(cd)                                  # [B,S,4d]
    state0 = init_slstm_state(cfg, B)

    def step(state, g_t):
        new = _slstm_cell(cfg, p, state, g_t)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, gx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(cd)
    y = jax.nn.gelu(y @ p["w_up"].astype(cd), approximate=True) @ p["w_down"].astype(cd)
    return x + y


def slstm_step(cfg: ModelConfig, p, state, x_t: jax.Array):
    from repro.models.layers import rms_norm
    cd = x_t.dtype
    B, d = x_t.shape
    xn = rms_norm(x_t, p["norm"], cfg.norm_eps)
    gx = xn @ p["w_x"].astype(cd)
    new = _slstm_cell(cfg, p, state, gx)
    y = new["h"].reshape(B, d).astype(cd)
    y = jax.nn.gelu(y @ p["w_up"].astype(cd), approximate=True) @ p["w_down"].astype(cd)
    return x_t + y, new
