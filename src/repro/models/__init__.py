"""Model zoo: composable JAX modules covering the six assigned families."""
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.model import Model, build_model

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "Model", "build_model"]
