"""Model configuration shared by every assigned architecture.

One frozen dataclass covers the six arch families (dense / moe / ssm /
hybrid / vlm / audio); each ``src/repro/configs/<id>.py`` instantiates it
with the exact assigned numbers and cites its source.  ``reduced()`` yields
the CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts) required by
the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- attention flavour ---------------------------------------------------
    rope: str = "full"                 # full|half|none (half = chatglm 2d rope)
    rope_theta: float = 1.0e4
    window: int = 0                    # sliding-window size for "local" layers
    layer_pattern: str = "global"      # "global" | "local_global" alternation
    attn_softcap: float = 0.0          # gemma2 attn-logit softcap (0 = off)
    final_softcap: float = 0.0         # gemma2 final-logit softcap (0 = off)
    learned_pos: bool = False          # whisper decoder absolute positions

    # --- mlp -------------------------------------------------------------------
    mlp: str = "swiglu"                # swiglu|geglu|gelu

    # --- moe -------------------------------------------------------------------
    n_experts: int = 0                 # routed experts
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                  # per-expert hidden dim
    n_dense_layers: int = 0            # leading dense layers (deepseek/kimi)
    dense_d_ff: int = 0                # their FFN width
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- ssm / hybrid -----------------------------------------------------------
    ssm_state: int = 0                 # N, per-head state size (mamba)
    ssm_heads: int = 0                 # parallel mamba heads (hymba)
    ssm_head_dim: int = 0
    slstm_every: int = 0               # xlstm: every k-th block is sLSTM
    mlstm_proj_factor: float = 2.0

    # --- enc-dec / modality stubs -----------------------------------------------
    n_enc_layers: int = 0
    enc_frames: int = 0                # audio: precomputed frame embeddings
    n_patches: int = 0                 # vlm: precomputed patch embeddings

    # --- norm / embedding / numerics ----------------------------------------------
    norm_eps: float = 1.0e-6
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    use_flash_kernel: bool = False     # Pallas path (TPU target; ref on CPU)
    q_chunk: int = 512                 # query-chunked attention (0 = off):
                                       # never materialises the SxS matrix
    # --- §Perf hillclimb knobs (beyond-paper optimisations) -----------------
    seq_shard_blocks: bool = False     # Megatron-SP: shard the residual's
                                       # sequence axis over "model" between
                                       # blocks (norms/saves 1/16 the size)
    norm_cast_early: bool = False      # cast to compute dtype before the
                                       # norm's scale-mul so only bf16
                                       # crosses op/collective boundaries
    barrier_block_inputs: bool = False  # optimization_barrier on the bf16
                                        # matmul inputs: stops XLA hoisting
                                        # fp32 converts across collectives
    kv_cache_dtype: str = ""            # "" = compute dtype; "int8" halves
                                        # decode cache residency (quantised
                                        # with per-slot-head scales)

    source: str = ""                   # citation for the assigned config

    # ------------------------------------------------------------------------

    def __post_init__(self) -> None:
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(f"unknown family {self.family}")
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads (GQA)")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError("moe family needs n_experts and top_k")
        if self.layer_pattern not in ("global", "local_global"):
            raise ValueError(f"unknown layer_pattern {self.layer_pattern}")

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def attn_param_count(self) -> int:
        d, h, k, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        return d * h * hd + 2 * d * k * hd + h * hd * d

    def param_count(self) -> int:
        """Approximate total parameter count N (used for 6·N·D roofline)."""
        d = self.d_model
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # xlstm
            n_s = self.n_layers // self.slstm_every if self.slstm_every else 0
            n_m = self.n_layers - n_s
            dk = self.head_dim
            m_blk = d * 2 * int(self.mlstm_proj_factor * d) \
                + 3 * int(self.mlstm_proj_factor * d) * self.n_heads * dk \
                + self.n_heads * dk * d
            s_blk = 4 * d * d + int(d * 4 / 3) * d * 2
            return emb + n_m * m_blk + n_s * s_blk
        per_layer = self.attn_param_count
        if self.family in ("moe",):
            moe_layers = self.n_layers - self.n_dense_layers
            ff_moe = 3 * d * self.d_expert * (self.n_experts + self.n_shared_experts) \
                + d * self.n_experts
            ff_dense = 3 * d * self.dense_d_ff
            ff_total = moe_layers * ff_moe + self.n_dense_layers * ff_dense
            return emb + self.n_layers * per_layer + ff_total
        gate = 2 if self.mlp in ("swiglu", "geglu") else 1
        ff = (gate + 1) * d * self.d_ff
        total = emb + self.n_layers * (per_layer + ff)
        if self.family == "hybrid":
            # mamba branch params per layer
            P, N, Hs = self.ssm_head_dim, self.ssm_state, self.ssm_heads
            inner = Hs * P
            total += self.n_layers * (2 * d * inner + inner * N * 2 + inner * d)
        if self.family == "audio":
            enc_ff = (1 + 1) * d * self.d_ff
            total += self.n_enc_layers * (per_layer + enc_ff)
            total += self.n_layers * per_layer  # cross-attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        moe_layers = self.n_layers - self.n_dense_layers
        ff_act = 3 * d * self.d_expert * (self.top_k + self.n_shared_experts) \
            + d * self.n_experts
        ff_dense = 3 * d * self.dense_d_ff
        return emb + self.n_layers * self.attn_param_count \
            + moe_layers * ff_act + self.n_dense_layers * ff_dense

    def reduced(self) -> "ModelConfig":
        """Smoke variant: <=2 layers (x2 for pattern/super-blocks), small dims."""
        d = min(self.d_model, 256)
        hd = min(self.head_dim, 32)
        n_kv = min(self.n_kv_heads, 2)
        n_h = n_kv * min(self.q_per_kv, 2)
        layers = 2 if self.layer_pattern == "global" else 2
        if self.slstm_every:
            layers = max(2, min(self.slstm_every, 4))
        return dataclasses.replace(
            self,
            n_layers=layers,
            d_model=d,
            n_heads=n_h,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_expert=min(self.d_expert, 128) if self.d_expert else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            dense_d_ff=min(self.dense_d_ff, 256) if self.dense_d_ff else 0,
            # no-drop capacity (C >= T) so decode == prefill exactly in the
            # smoke equivalence test; full configs keep realistic 1.25
            capacity_factor=float(max(self.n_experts, 8)),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 2) if self.ssm_heads else 0,
            ssm_head_dim=min(self.ssm_head_dim, 16) if self.ssm_head_dim else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_frames=min(self.enc_frames, 16) if self.enc_frames else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            window=min(self.window, 32) if self.window else 0,
            remat=False,
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train|prefill|decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
