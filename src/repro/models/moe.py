"""Fine-grained mixture-of-experts layer (DeepSeekMoE / Kimi-K2 style).

Token-choice top-k routing with capacity-factor dropping, implemented the
TPU-native way: sort token-expert pairs by expert id, scatter into a dense
[E, C, d] buffer, run all experts as one batched einsum (MXU-friendly,
expert dim shardable over the ``model`` mesh axis = expert parallelism),
gather back, combine with normalised router weights.  No per-expert Python
loops, no ragged shapes -- everything is static for jit/scan.

Shared experts (DeepSeekMoE's "2 shared + 64 routed") are fused into one
always-on dense MLP of width n_shared * d_expert.

Returns the Switch-style load-balance auxiliary loss alongside the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_mlp, mlp


def init_moe(rng, cfg: ModelConfig, dtype):
    E, d, de = cfg.n_experts, cfg.d_model, cfg.d_expert
    r = jax.random.split(rng, 5)
    s = 1.0 / jnp.sqrt(d)
    p = {
        "router": (jax.random.normal(r[0], (d, E)) * s).astype(jnp.float32),
        "we_gate": (jax.random.normal(r[1], (E, d, de)) * s).astype(dtype),
        "we_up": (jax.random.normal(r[2], (E, d, de)) * s).astype(dtype),
        "we_down": (jax.random.normal(r[3], (E, de, d)) / jnp.sqrt(de)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(r[4], d, cfg.n_shared_experts * de, "swiglu", dtype)
    return p


def moe_apply(cfg: ModelConfig, p, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    cd = x.dtype
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    gate_logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                          # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- dispatch: sort token-expert pairs by expert --------------------------
    capacity = int(max(k, round(T * k / E * cfg.capacity_factor)))
    flat_e = top_i.reshape(-1)                                      # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    tok_of = order // k
    # rank of each entry within its expert's group
    first_of = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * k) - first_of
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, E * capacity)

    buf = jnp.zeros((E * capacity + 1, d), cd).at[slot].set(xt[tok_of].astype(cd))
    h = buf[: E * capacity].reshape(E, capacity, d)

    # ---- all experts as one batched matmul ------------------------------------
    g = jnp.einsum("ecd,edf->ecf", h, p["we_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", h, p["we_up"].astype(cd))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["we_down"].astype(cd))

    # ---- combine ---------------------------------------------------------------
    y_flat = jnp.concatenate([y.reshape(E * capacity, d),
                              jnp.zeros((1, d), cd)], axis=0)
    gathered = y_flat[slot]                                         # [T*k, d]
    weight = top_p.reshape(-1)[order] * keep.astype(jnp.float32)
    out = jnp.zeros((T, d), cd).at[tok_of].add(
        gathered * weight[:, None].astype(cd))

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt, "swiglu")

    # ---- Switch-style load-balance loss -----------------------------------------
    frac = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    mean_p = probs.mean(axis=0)
    aux = E * jnp.sum(frac * mean_p) * cfg.router_aux_coef
    return out.reshape(B, S, d), aux
