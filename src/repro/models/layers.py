"""Composable transformer building blocks (pure-function + pytree params).

Everything is shape-polymorphic and jit/scan/shard_map friendly:

  * ``rms_norm``          -- RMSNorm (ref path; Pallas kernel in kernels/)
  * ``apply_rope``        -- rotary embeddings, "full" (llama) or "half"
                             (chatglm 2d-rope: only the first half of the
                             head dim rotates)
  * ``attention``         -- GQA attention with optional sliding window,
                             logit softcap (gemma2), KV cache with absolute
                             slot positions (supports rolling caches), and
                             cross-attention (whisper)
  * ``mlp``               -- swiglu / geglu / gelu feed-forward
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# activation-sharding hints
# ---------------------------------------------------------------------------


def shard_hint(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint that degrades gracefully: each entry of
    ``axes`` is None | axis-name | tuple-of-names; an axis is applied only
    if it exists in the ambient (abstract) mesh and divides the dim.  On an
    un-meshed trace (CPU smoke tests) this is the identity, so models stay
    mesh-agnostic."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:                                   # pragma: no cover
        return x
    if mesh is None or not mesh.axis_names:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        cand = tuple(a for a in ((ax,) if isinstance(ax, str) else ax)
                     if a in mesh.axis_names)
        size = 1
        for a in cand:
            size *= mesh.shape[a]
        if cand and size > 1 and dim % size == 0:
            spec.append(cand if len(cand) > 1 else cand[0])
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


BATCH_AXES = ("pod", "data")


@jax.custom_vjp
def bf16_grad_barrier(x: jax.Array) -> jax.Array:
    """Identity forward; casts the cotangent to bf16 on the way back.

    Placed at block boundaries it pins the backward residual stream (and
    therefore the gradient all-reduces XLA inserts around model-sharded
    matmul transposes) to bf16 instead of the fp32 that loss-side upcasts
    otherwise propagate — halving backward collective and HBM bytes
    (§Perf hillclimb, llama3-405b x train_4k)."""
    return x


def _bf16_barrier_fwd(x):
    return x, None


def _bf16_barrier_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_grad_barrier.defvjp(_bf16_barrier_fwd, _bf16_barrier_bwd)


# ---------------------------------------------------------------------------
# norms & embeddings
# ---------------------------------------------------------------------------


def init_rms_norm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype=dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float,
             cast_early: bool = False) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    if cast_early:
        # normalise in fp32 but cross op boundaries in compute dtype: the
        # scale-mul (and any downstream collective) sees bf16, halving the
        # bytes XLA moves when it hoists converts across gathers (§Perf)
        y = (x32 * jax.lax.rsqrt(var + eps)).astype(dt)
        return y * scale.astype(dt)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def init_embedding(rng, vocab: int, d: int, dtype) -> jax.Array:
    return jax.random.normal(rng, (vocab, d), dtype=jnp.float32).astype(dtype) * 0.02


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style sinusoidal position embeddings [seq, d]."""
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def _rope_rotate(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate all of the last dim of x [..., S, H, D] at ``positions`` [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mode: str) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] absolute token positions."""
    if mode == "none":
        return x
    if mode == "full":
        return _rope_rotate(x, positions, theta)
    if mode == "half":                           # chatglm 2d rope
        d = x.shape[-1]
        rotated = _rope_rotate(x[..., : d // 2], positions, theta)
        return jnp.concatenate([rotated, x[..., d // 2:]], axis=-1)
    raise ValueError(f"unknown rope mode {mode}")


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVCache:
    """Decode-time cache with absolute slot positions (rolling-capable).

    ``k``/``v``: [B, Smax, K, hd]; ``pos``: [B, Smax] absolute position held
    in each slot, -1 when the slot is empty.  A rolling cache (long-context
    sliding window) simply writes at slot ``position % Smax``.

    int8 mode (beyond-paper, §Perf decode-memory lever): k/v stored int8
    with per-(batch, slot, head) symmetric scales — halves cache residency
    vs bf16 at <1% relative dequant error per entry."""
    k: jax.Array
    v: jax.Array
    pos: jax.Array
    k_scale: jax.Array | None = None     # [B, Smax, K] fp32, int8 mode only
    v_scale: jax.Array | None = None

    def tree_flatten(self):
        return (self.k, self.v, self.pos, self.k_scale, self.v_scale), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


jax.tree_util.register_pytree_node(
    KVCache, KVCache.tree_flatten, KVCache.tree_unflatten)


def init_kv_cache(batch: int, max_slots: int, n_kv: int, head_dim: int,
                  dtype) -> KVCache:
    dt = jnp.dtype(dtype)
    quant = dt == jnp.int8
    shape = (batch, max_slots, n_kv, head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype=dt),
        v=jnp.zeros(shape, dtype=dt),
        pos=jnp.full((batch, max_slots), -1, dtype=jnp.int32),
        k_scale=jnp.zeros((batch, max_slots, n_kv), jnp.float32)
        if quant else None,
        v_scale=jnp.zeros((batch, max_slots, n_kv), jnp.float32)
        if quant else None,
    )


def _quantize_kv(x):
    """x: [B, S, K, hd] -> (int8 values, per-[B,S,K] scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-9)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / safe[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_attn(rng, cfg: ModelConfig, dtype, *, n_heads=None, n_kv=None):
    h = n_heads or cfg.n_heads
    k = n_kv or cfg.n_kv_heads
    d, hd = cfg.d_model, cfg.head_dim
    r = jax.random.split(rng, 4)
    s = 1.0 / jnp.sqrt(d)
    return {
        "wq": (jax.random.normal(r[0], (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(r[1], (d, k * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(r[2], (d, k * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(r[3], (h * hd, d)) * s).astype(dtype),
    }


def _sdpa(q, k, v, q_pos, k_pos, *, causal: bool, window, softcap: float,
          compute_dtype) -> jax.Array:
    """Reference scaled-dot-product attention with GQA + masks.

    q: [B,Sq,H,hd]; k/v: [B,Skv,Kh,hd]; q_pos: [B,Sq]; k_pos: [B,Skv]
    (absolute positions; k_pos = -1 marks invalid slots).
    ``window`` may be a python int or a traced scalar (0 = unlimited).
    """
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)

    valid = (k_pos >= 0)[:, None, :]                           # [B,1,Skv]
    if causal:
        rel = q_pos[:, :, None] - k_pos[:, None, :]            # [B,Sq,Skv]
        valid = valid & (rel >= 0)
        window = jnp.asarray(window)
        valid = valid & ((window <= 0) | (rel < window))
    big_neg = jnp.asarray(-1e30, jnp.float32)
    logits = jnp.where(valid[:, None, None, :, :], logits, big_neg)
    p = jax.nn.softmax(logits, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(B, Sq, H * hd)


def _model_axis_size() -> int:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:                                   # pragma: no cover
        return 1
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return 1
    return int(mesh.shape["model"])


def _sdpa_q_chunked(q, k, v, q_pos, k_pos, *, causal, window, softcap,
                    compute_dtype, q_chunk, cp=False):
    """Flash-style memory shape without Pallas: scan over query chunks so the
    [Sq, Skv] score matrix never materialises whole (the per-chunk
    [q_chunk, Skv] slab is transient and rematerialised in the backward).
    Numerically identical to _sdpa — used for long sequences in the pjit
    path; the Pallas kernel (kernels/flash_attention.py) is the TPU
    fast path."""
    B, Sq, H, hd = q.shape
    nc = Sq // q_chunk
    qs = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, hd), 1, 0)
    qp = jnp.moveaxis(q_pos.reshape(B, nc, q_chunk), 1, 0)

    def body(_, inp):
        qc, qpc = inp
        if cp:
            # context-parallel fallback (heads don't tile the model axis):
            # split this chunk's query rows over "model"; k/v replicated.
            qc = shard_hint(qc, BATCH_AXES, "model", None, None)
        out = _sdpa(qc, k, v, qpc, k_pos, causal=causal, window=window,
                    softcap=softcap, compute_dtype=compute_dtype)
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, qp))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H * hd)


def _sdpa_auto(q, k, v, q_pos, k_pos, *, causal, window, softcap,
               compute_dtype, q_chunk, n_heads=0):
    Sq = q.shape[1]
    # heads that don't tile the model axis can't head-shard the einsum;
    # shard the query sequence instead (each q row attends the full kv)
    import os
    ms = _model_axis_size()
    cp = bool(ms > 1 and n_heads and n_heads % ms != 0
              and not os.environ.get("REPRO_NAIVE_SHARDING"))
    if cp:
        k = shard_hint(k, BATCH_AXES, None, None, None)
        v = shard_hint(v, BATCH_AXES, None, None, None)
    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        return _sdpa_q_chunked(q, k, v, q_pos, k_pos, causal=causal,
                               window=window, softcap=softcap,
                               compute_dtype=compute_dtype, q_chunk=q_chunk,
                               cp=cp)
    if cp:
        q = shard_hint(q, BATCH_AXES, "model", None, None)
    out = _sdpa(q, k, v, q_pos, k_pos, causal=causal, window=window,
                softcap=softcap, compute_dtype=compute_dtype)
    return out if not cp else shard_hint(
        out.reshape(out.shape), BATCH_AXES, None, None)


def attention(cfg: ModelConfig, p, x, q_pos, *, window=0, cache: KVCache | None = None,
              enc_out: jax.Array | None = None, rope: bool = True,
              causal: bool = True) -> tuple:
    """Self- or cross-attention.

    Returns (output, new_cache).  ``cache`` given => decode: x holds the new
    token(s); K/V are written into the cache at slot ``q_pos % Smax``.
    ``enc_out`` given => cross-attention (no mask, no rope, no cache).
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(cd)).reshape(B, S, h, hd)
    kv_src = enc_out if enc_out is not None else x
    Skv = kv_src.shape[1]
    k = (kv_src @ p["wk"].astype(cd)).reshape(B, Skv, kh, hd)
    v = (kv_src @ p["wv"].astype(cd)).reshape(B, Skv, kh, hd)

    if enc_out is not None:
        k_pos = jnp.zeros((B, Skv), jnp.int32)                 # all valid
        out = _sdpa_auto(q, k, v, q_pos, k_pos, causal=False, window=0,
                         softcap=cfg.attn_softcap, compute_dtype=cd,
                         q_chunk=cfg.q_chunk, n_heads=cfg.n_heads)
        return out @ p["wo"].astype(cd), None

    if rope:
        q = apply_rope(q, q_pos, cfg.rope_theta, cfg.rope)
        k = apply_rope(k, q_pos, cfg.rope_theta, cfg.rope)

    if cache is None:
        if cfg.use_flash_kernel and S >= 128 and not isinstance(
                window, jax.core.Tracer):
            # Pallas fast path (TPU target; interpret mode on CPU).  The
            # window must be static for the kernel; traced per-layer
            # windows (gemma2/hymba scans) use the jnp path.
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=causal,
                                       window=int(window),
                                       softcap=cfg.attn_softcap)
            return out.reshape(B, S, h * hd) @ p["wo"].astype(cd), None
        out = _sdpa_auto(q, k, v, q_pos, q_pos, causal=causal, window=window,
                         softcap=cfg.attn_softcap, compute_dtype=cd,
                         q_chunk=cfg.q_chunk, n_heads=cfg.n_heads)
        return out @ p["wo"].astype(cd), None

    # decode: write S new token(s) into slots q_pos % Smax, attend over cache
    smax = cache.k.shape[1]
    slots = q_pos % smax                                       # [B,S]
    bidx = jnp.arange(B)[:, None]
    new_pos = cache.pos.at[bidx, slots].set(q_pos.astype(jnp.int32))
    if cache.quantized:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_k = cache.k.at[bidx, slots].set(kq)
        new_v = cache.v.at[bidx, slots].set(vq)
        new_ks = cache.k_scale.at[bidx, slots].set(ks)
        new_vs = cache.v_scale.at[bidx, slots].set(vs)
        k_full = _dequantize_kv(new_k, new_ks, cd)
        v_full = _dequantize_kv(new_v, new_vs, cd)
        new_cache = KVCache(new_k, new_v, new_pos, new_ks, new_vs)
    else:
        new_k = cache.k.at[bidx, slots].set(k.astype(cache.k.dtype))
        new_v = cache.v.at[bidx, slots].set(v.astype(cache.v.dtype))
        k_full, v_full = new_k.astype(cd), new_v.astype(cd)
        new_cache = KVCache(new_k, new_v, new_pos)
    out = _sdpa(q, k_full, v_full, q_pos, new_pos,
                causal=True, window=window, softcap=cfg.attn_softcap,
                compute_dtype=cd)
    return out @ p["wo"].astype(cd), new_cache


# ---------------------------------------------------------------------------
# feed-forward
# ---------------------------------------------------------------------------


def init_mlp(rng, d: int, d_ff: int, kind: str, dtype):
    r = jax.random.split(rng, 3)
    s = 1.0 / jnp.sqrt(d)
    p = {"w_up": (jax.random.normal(r[0], (d, d_ff)) * s).astype(dtype),
         "w_down": (jax.random.normal(r[1], (d_ff, d)) / jnp.sqrt(d_ff)).astype(dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(r[2], (d, d_ff)) * s).astype(dtype)
    return p


def mlp(p, x, kind: str) -> jax.Array:
    cd = x.dtype
    up = x @ p["w_up"].astype(cd)
    if kind == "swiglu":
        up = jax.nn.silu(x @ p["w_gate"].astype(cd)) * up
    elif kind == "geglu":
        up = jax.nn.gelu(x @ p["w_gate"].astype(cd), approximate=True) * up
    elif kind == "gelu":
        up = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(f"unknown mlp kind {kind}")
    return up @ p["w_down"].astype(cd)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE; logits [B,S,V] (any dtype, upcast), labels [B,S]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
