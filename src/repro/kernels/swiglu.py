"""Fused SwiGLU feed-forward gate for TPU (Pallas).

Computes silu(x @ w_gate) * (x @ w_up) with one kernel: both matmuls tile
the same [bm, bk] x-block through the MXU (k-axis innermost/sequential,
fp32 accumulators in VMEM scratch), and the silu-and-multiply epilogue runs
on the VPU when the k-loop finishes — so the gate tensor never round-trips
to HBM.  Blocks default to 128x128x512, MXU-aligned."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _swiglu_kernel(x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *,
                   n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...]
    accg_ref[...] += jax.lax.dot(x, wg_ref[...],
                                 preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot(x, wu_ref[...],
                                 preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finish():
        g = accg_ref[...]
        o_ref[...] = (g * jax.lax.logistic(g) * accu_ref[...]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret"))
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           block_m: int = 128, block_n: int = 128, block_k: int = 512,
           interpret: bool | None = None) -> jax.Array:
    """x: [M, K]; w_gate/w_up: [K, N] -> [M, N]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = x.shape
    N = w_gate.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(f"shape ({M},{K},{N}) not divisible by blocks")
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_gate, w_up)
