"""Jit'd public wrappers around the Pallas kernels.

These handle layout adaptation (GQA head repetition, sequence padding to
block multiples, [B,S,H,hd] <-> [B,H,S,hd]) so model code can call them
with natural shapes.  On CPU the kernels execute in interpret mode; on TPU
they compile to Mosaic."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import swiglu as _sg


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """Model-layout entry point: q [B,S,H,hd], k/v [B,S,K,hd] (GQA ok).

    Repeats kv heads to match q heads, pads S to block multiples (padded
    kv columns carry position > any real q so the causal mask kills them),
    and returns [B,S,H,hd]."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    if H != K:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block_q, 1 << (Sq - 1).bit_length())       # pow2 cap
    bk = min(block_k, 1 << (Skv - 1).bit_length())
    qt, pad_q = _pad_to(qt, 2, bq)
    kt, _ = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              softcap=softcap, block_q=bq, block_k=bk,
                              kv_len=Skv)
    if pad_q:
        out = out[:, :, :Sq]
    return out.transpose(0, 2, 1, 3)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """x: [..., d] any leading shape."""
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    br = 256
    while rows % br and br > 1:
        br //= 2
    out = _rn.rmsnorm(x2, scale, eps=eps, block_rows=br)
    return out.reshape(shape)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """x: [..., K]; w: [K, N]."""
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    bm = 128
    while rows % bm and bm > 1:
        bm //= 2
    bn = 128
    while w_gate.shape[1] % bn and bn > 1:
        bn //= 2
    bk = 512
    while shape[-1] % bk and bk > 1:
        bk //= 2
    out = _sg.swiglu(x2, w_gate, w_up, block_m=bm, block_n=bn, block_k=bk)
    return out.reshape(shape[:-1] + (w_gate.shape[1],))
