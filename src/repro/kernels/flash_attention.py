"""Flash attention for TPU (Pallas): blockwise online-softmax.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * tiling is chosen for VMEM and the 128x128 MXU — q/k blocks are
    multiples of 128 on the sequence axes and the full head dim rides along
    (head_dim <= 256 fits VMEM comfortably: bq*hd + 2*bk*hd + bq*bk floats);
  * the kv axis is the innermost *sequential* grid dimension
    ("arbitrary"), carrying the running max/denominator/accumulator in VMEM
    scratch across kv steps — the TPU grid is executed in order, which
    replaces the CUDA shared-memory + warp-shuffle reduction;
  * causal/sliding-window masking and the gemma2 logit softcap are fused
    into the block, so masked kv blocks cost one predicated VPU pass
    instead of a second kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, n_kv: int, causal: bool, window: int,
                  softcap: float, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                    # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    hd = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) / jnp.sqrt(
        jnp.float32(hd))                                # [bq, bk]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_idx < kv_len                               # kv padding
    if causal:
        rel = q_idx - k_idx
        mask &= rel >= 0
        if window:
            mask &= rel < window

    s = jnp.where(mask, s, -1e30)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new) * mask                       # masked rows stay 0
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "kv_len",
    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    kv_len: int = 0, interpret: bool | None = None) -> jax.Array:
    """q/k/v: [B, H, S, hd] with equal head counts.  Returns [B, H, Sq, hd].

    Sequence lengths must be multiples of the block sizes (ops.py pads);
    ``kv_len`` marks the number of *real* kv positions (0 = all)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq lens ({Sq},{Skv}) not divisible by blocks ({bq},{bk})")
    n_kv = Skv // bk
    qr = q.reshape(B * H, Sq, hd)
    kr = k.reshape(B * H, Skv, hd)
    vr = v.reshape(B * H, Skv, hd)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
        window=window, softcap=softcap, kv_len=kv_len or Skv)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, hd)
