"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0) -> jax.Array:
    """Reference attention.  q/k/v: [B, H, S, hd] (equal head counts —
    GQA repeat happens in ops).  Returns [B, H, S, hd]."""
    Sq, Skv = q.shape[2], k.shape[2]
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Skv)[None, :]
        rel = qi - ki
        mask = rel >= 0
        if window:
            mask &= rel < window
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """x: [rows, d]; scale: [d]."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def mlstm_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                   F: jax.Array, i_pre: jax.Array) -> jax.Array:
    """Reference mLSTM parallel form.  q/k/v: [BH, S, hd] (k pre-scaled);
    F/i_pre: [BH, S].  Mirrors ssm._mlstm_parallel_block at full S."""
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    S = q.shape[1]
    D = F[:, :, None] - F[:, None, :] + i_pre[:, None, :]     # [BH, t, s]
    causal = jnp.tril(jnp.ones((S, S), bool))
    D = jnp.where(causal[None], D, -jnp.inf)
    m = jnp.max(D, axis=-1, keepdims=True)
    w = jnp.exp(D - m)
    scores = jnp.einsum("btd,bsd->bts", q, k) * w
    norm = jnp.maximum(jnp.abs(scores.sum(-1, keepdims=True)), jnp.exp(-m))
    return jnp.einsum("bts,bsd->btd", scores / norm, v).astype(q.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """Fused silu(x @ w_gate) * (x @ w_up).  x: [M, K]; w: [K, N]."""
    g = x.astype(jnp.float32) @ w_gate.astype(jnp.float32)
    u = x.astype(jnp.float32) @ w_up.astype(jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)
