"""Jitted Pallas kernel for the Eq. (6)-(8) candidate-stack reduction.

The contention model's hot loop scores stacks of candidate placements
Y [C, J, S]: per candidate, the straddle matrix (Eq. 6), the per-server
straddler counts, each job's contention level p (a max over its straddled
servers), and the per-iteration RAR time tau (Eq. 8).  The NumPy pipeline
in :func:`repro.core.contention.stack_model` materialises several [C, J, S]
temporaries in host memory; this kernel fuses the whole reduction into one
VMEM pass per candidate -- one grid step per candidate row, straddle/count/
max/tau on the VPU, no host round-trips between the stages.

On CPU the kernel runs in Pallas interpret mode and exists for numerics
parity and TPU forward-compat, not speed (the interpreter is an emulator);
it is therefore opt-in via :func:`repro.core.contention.tau_backend`.  With
``jax_enable_x64`` the arithmetic is float64 in the same operation order as
the NumPy engines, so the results are bit-identical (pinned by
``tests/test_kernels.py``); without x64 jax computes in float32 and the
kernel is only approximately equal.

This kernel scores *given* candidate stacks; its sibling
:mod:`repro.kernels.placement` fuses the columnar placement engine's
per-step reductions (FA-FFP/LBSGF pick stats over branch rows, Eq.
(15)/(16) busy-time pools, refined-rho scoring) the same way -- same
grid-per-row layout, same x64 bit-identity contract, plus plain
``jax.jit`` variants that are the CPU fast path where the interpret-mode
Pallas lowering is the parity artifact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _tau_kernel_het(y_ref, g_ref, share_ref, compute_ref, spd_ref, sh_ref,
                    iso_ref, p_ref, n_ref, tau_ref, *, xi1: float,
                    xi2: float, alpha: float, b_intra: float):
    """Heterogeneous candidate: Y [1, J, S] + per-server device terms
    [1, S] -> p/n_srv/tau [1, J].

    ``spd_ref``/``sh_ref``/``iso_ref`` hold the cluster's server speed
    floors and shared/isolated uplink bandwidths (+inf where the class is
    absent); the kernel reduces each row's worst members in VMEM with the
    same masked-min selections as ``contention._hetero_mins`` and prices
    Eq. (8) with ``min(bw_iso, bw_sh / f)`` -- isolated uplinks skip the
    sharing divisor."""
    y = y_ref[0]                                     # [J, S]
    g = g_ref[0]                                     # [J]
    pos = y > 0
    straddle = pos & (y < g[:, None])                # Eq. (6) straddling
    per_server = jnp.sum(straddle.astype(y.dtype), axis=0)        # [S]
    p = jnp.max(jnp.where(straddle, per_server[None, :], 0), axis=1)
    n_srv = jnp.sum(pos.astype(y.dtype), axis=1)
    ftype = tau_ref.dtype
    inf = jnp.asarray(jnp.inf, dtype=ftype)
    speed = jnp.min(jnp.where(pos, spd_ref[0][None, :], inf), axis=1)
    bw_sh = jnp.min(jnp.where(pos, sh_ref[0][None, :], inf), axis=1)
    bw_iso = jnp.min(jnp.where(pos, iso_ref[0][None, :], inf), axis=1)
    k = jnp.maximum(xi1 * p.astype(ftype), 1.0)      # Eq. (7)
    f = k + alpha * (k - 1.0)                        # degradation f(a, k)
    bandwidth = jnp.where(n_srv > 1, jnp.minimum(bw_iso, bw_sh / f), b_intra)
    gamma = xi2 * n_srv.astype(ftype)
    exchange = 2.0 * share_ref[0] / bandwidth
    # Eq. (8), same left-to-right addition order as the NumPy engines.
    tau_ref[0] = exchange + share_ref[0] / speed + gamma + compute_ref[0]
    p_ref[0] = p
    n_ref[0] = n_srv


def _tau_kernel(y_ref, g_ref, share_ref, reduce_ref, compute_ref,
                p_ref, n_ref, tau_ref, *, xi1: float, xi2: float,
                alpha: float, b_inter: float, b_intra: float):
    """One candidate: Y [1, J, S] -> p/n_srv/tau [1, J]."""
    y = y_ref[0]                                     # [J, S]
    g = g_ref[0]                                     # [J]
    pos = y > 0
    straddle = pos & (y < g[:, None])                # Eq. (6) straddling
    per_server = jnp.sum(straddle.astype(y.dtype), axis=0)        # [S]
    p = jnp.max(jnp.where(straddle, per_server[None, :], 0), axis=1)
    n_srv = jnp.sum(pos.astype(y.dtype), axis=1)
    ftype = tau_ref.dtype
    k = jnp.maximum(xi1 * p.astype(ftype), 1.0)      # Eq. (7)
    f = k + alpha * (k - 1.0)                        # degradation f(a, k)
    bandwidth = jnp.where(n_srv > 1, b_inter / f, b_intra)
    gamma = xi2 * n_srv.astype(ftype)
    exchange = 2.0 * share_ref[0] / bandwidth
    # Eq. (8), same left-to-right addition order as the NumPy engines.
    tau_ref[0] = exchange + reduce_ref[0] + gamma + compute_ref[0]
    p_ref[0] = p
    n_ref[0] = n_srv


@functools.partial(jax.jit, static_argnames=(
    "xi1", "xi2", "alpha", "b_inter", "b_intra", "gpu_speed", "terms_2d",
    "interpret"))
def _tau_stack_jit(Y, G, share, compute, *, xi1, xi2, alpha, b_inter,
                   b_intra, gpu_speed, terms_2d, interpret):
    C, J, S = Y.shape
    ftype = share.dtype
    itype = Y.dtype
    reduce_t = share / gpu_speed
    # Shared [J] terms pin every grid step to block (0, 0); per-candidate
    # [C, J] terms ride the same grid axis as the Y stack -- the branch
    # axis of the columnar placement engine IS the kernel grid dimension.
    term_idx = (lambda c: (c, 0)) if terms_2d else (lambda c: (0, 0))
    return pl.pallas_call(
        functools.partial(_tau_kernel, xi1=xi1, xi2=xi2, alpha=alpha,
                          b_inter=b_inter, b_intra=b_intra),
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, J, S), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, J), term_idx),
            pl.BlockSpec((1, J), term_idx),
            pl.BlockSpec((1, J), term_idx),
            pl.BlockSpec((1, J), term_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, J), lambda c: (c, 0)),
            pl.BlockSpec((1, J), lambda c: (c, 0)),
            pl.BlockSpec((1, J), lambda c: (c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, J), itype),     # p
            jax.ShapeDtypeStruct((C, J), itype),     # n_srv
            jax.ShapeDtypeStruct((C, J), ftype),     # tau
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(Y, G if terms_2d else G[None, :],
      share if terms_2d else share[None, :],
      reduce_t if terms_2d else reduce_t[None, :],
      compute if terms_2d else compute[None, :])


@functools.partial(jax.jit, static_argnames=(
    "xi1", "xi2", "alpha", "b_intra", "terms_2d", "interpret"))
def _tau_stack_het_jit(Y, G, share, compute, spd, sh, iso, *, xi1, xi2,
                       alpha, b_intra, terms_2d, interpret):
    C, J, S = Y.shape
    ftype = share.dtype
    itype = Y.dtype
    term_idx = (lambda c: (c, 0)) if terms_2d else (lambda c: (0, 0))
    # The [1, S] device-term rows are grid-invariant: every candidate
    # reads block (0, 0).
    srv_idx = lambda c: (0, 0)  # noqa: E731 - BlockSpec index lambda
    return pl.pallas_call(
        functools.partial(_tau_kernel_het, xi1=xi1, xi2=xi2, alpha=alpha,
                          b_intra=b_intra),
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, J, S), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, J), term_idx),
            pl.BlockSpec((1, J), term_idx),
            pl.BlockSpec((1, J), term_idx),
            pl.BlockSpec((1, S), srv_idx),
            pl.BlockSpec((1, S), srv_idx),
            pl.BlockSpec((1, S), srv_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, J), lambda c: (c, 0)),
            pl.BlockSpec((1, J), lambda c: (c, 0)),
            pl.BlockSpec((1, J), lambda c: (c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, J), itype),     # p
            jax.ShapeDtypeStruct((C, J), itype),     # n_srv
            jax.ShapeDtypeStruct((C, J), ftype),     # tau
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(Y, G if terms_2d else G[None, :],
      share if terms_2d else share[None, :],
      compute if terms_2d else compute[None, :],
      spd[None, :], sh[None, :], iso[None, :])


def tau_stack(cluster, G: np.ndarray, share: np.ndarray,
              compute: np.ndarray, Y: np.ndarray,
              interpret: bool | None = None
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Kernel-backed Eq. (6)-(8) stack reduction: (p, n_srv, tau), [C, J].

    ``Y`` [C, J, S] is the (already masked) candidate stack; ``G``,
    ``share`` and ``compute`` are the placement-independent per-job terms
    (see ``repro.core.contention._job_terms``), either shared across the
    stack ([J]) or per-candidate ([C, J], the columnar branch-stack
    layout, in which case the candidate/branch axis becomes the kernel's
    grid dimension for the term blocks too).  ``interpret`` defaults to
    Pallas interpret mode on CPU backends.

    Heterogeneous clusters dispatch to a kernel variant that carries the
    per-server speed floors and shared/isolated uplink bandwidths as
    grid-invariant [1, S] operands and reduces each row's worst members
    in VMEM (see :func:`_tau_kernel_het`); homogeneous clusters keep the
    original static-scalar kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    G = np.asarray(G)
    if G.ndim not in (1, 2):
        raise ValueError(f"G must be [J] or [C, J], got shape {G.shape}")
    itype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    ftype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    if cluster.is_heterogeneous:
        p, n_srv, tau = _tau_stack_het_jit(
            jnp.asarray(Y, dtype=itype), jnp.asarray(G, dtype=itype),
            jnp.asarray(share, dtype=ftype), jnp.asarray(compute, dtype=ftype),
            jnp.asarray(cluster.server_speed_floor, dtype=ftype),
            jnp.asarray(cluster.uplink_shared_or_inf, dtype=ftype),
            jnp.asarray(cluster.uplink_isolated_or_inf, dtype=ftype),
            xi1=float(cluster.xi1), xi2=float(cluster.xi2),
            alpha=float(cluster.alpha), b_intra=float(cluster.b_intra),
            terms_2d=G.ndim == 2, interpret=bool(interpret))
    else:
        p, n_srv, tau = _tau_stack_jit(
            jnp.asarray(Y, dtype=itype), jnp.asarray(G, dtype=itype),
            jnp.asarray(share, dtype=ftype), jnp.asarray(compute, dtype=ftype),
            xi1=float(cluster.xi1), xi2=float(cluster.xi2),
            alpha=float(cluster.alpha), b_inter=float(cluster.b_inter),
            b_intra=float(cluster.b_intra), gpu_speed=float(cluster.gpu_speed),
            terms_2d=G.ndim == 2, interpret=bool(interpret))
    return (np.asarray(p, dtype=np.int64), np.asarray(n_srv, dtype=np.int64),
            np.asarray(tau, dtype=np.float64))
