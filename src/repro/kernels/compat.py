"""Pallas-TPU version compatibility.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across jax releases.  Resolve whichever name this jax
provides (preferring the new ``CompilerParams``) so the kernels build on
either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
