"""Fused mLSTM parallel-form kernel for TPU (Pallas).

The xLSTM mLSTM parallel form is attention-with-additive-decay:

    D[t,s]   = F_t - F_s + i_s           (s <= t; F = cumsum log forget)
    S[t,s]   = (q_t . k_s) * exp(D - m)  (m = running row max, stabiliser)
    y_t      = sum_s S[t,s] v_s / max(|sum_s S[t,s]|, exp(-m))

This kernel is the §Perf-identified fix for xlstm-350m's memory floor: the
jnp path streams the [chunk, S] fp32 decay/score slabs through HBM
(~3e14 B/step at train_4k); here they live in VMEM scratch only, exactly
like flash attention's probability block.  Same online-rescaling scheme as
flash, with a *signed* running denominator (mLSTM normalises by
|sum of scores|, not a softmax partition function).

Layout: q/k/v [BH, S, hd]; F/i [BH, S].  Grid (BH, S/bq, S/bk), kv-axis
innermost sequential with VMEM carries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _mlstm_kernel(q_ref, k_ref, v_ref, f_ref, fk_ref, i_ref, o_ref,
                  acc_ref, m_ref, den_ref, *, bq: int, bk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        den_ref[...] = jnp.zeros_like(den_ref)

    q = q_ref[0].astype(jnp.float32)                   # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                   # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    Fq = f_ref[0].astype(jnp.float32)                  # [bq]
    Fk = fk_ref[0].astype(jnp.float32)                 # [bk]
    ik = i_ref[0].astype(jnp.float32)                  # [bk]

    # decay matrix D[t,s] = F_t - F_s + i_s, causal-masked
    D = Fq[:, None] - Fk[None, :] + ik[None, :]        # [bq, bk]
    t_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    s_idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = t_idx >= s_idx
    D = jnp.where(mask, D, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, D.max(axis=-1, keepdims=True))
    w = jnp.exp(D - m_new) * mask
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * w
    alpha = jnp.exp(m_prev - m_new)
    den_ref[...] = den_ref[...] * alpha + scores.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(scores, v)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        norm = jnp.maximum(jnp.abs(den_ref[...]), jnp.exp(-m_ref[...]))
        o_ref[0] = (acc_ref[...] / norm).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def mlstm_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                   F: jax.Array, i_pre: jax.Array, *,
                   block_q: int = 128, block_k: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """q/k/v: [BH, S, hd] (k pre-scaled by 1/sqrt(hd));
    F: [BH, S] cumulative log-forget; i_pre: [BH, S] input-gate
    pre-activations.  Returns [BH, S, hd]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    BH, S, hd = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq or S % bk:
        raise ValueError(f"S={S} not divisible by blocks ({bq},{bk})")
    n_kv = S // bk
    kernel = functools.partial(_mlstm_kernel, bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, F, F, i_pre)   # F enters twice: q-row block and k-row block
