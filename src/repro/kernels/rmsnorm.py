"""Fused RMSNorm for TPU (Pallas).

One pass over a [rows, d] tile in VMEM: fp32 mean-of-squares reduction on
the VPU, rsqrt, scale — avoiding the three separate HBM round-trips XLA
sometimes emits for norm(x) when the producer/consumer don't fuse.  Rows
tile by ``block_rows``; the feature dim rides whole (d <= ~16k fits VMEM
at fp32 for 8+ rows)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool | None = None) -> jax.Array:
    """x: [rows, d]; scale: [d]."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    rows, d = x.shape
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows {rows} not divisible by block {br}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale[None, :])
