"""Jitted pick/check/score programs for the columnar placement engine.

The columnar engine (:class:`repro.core.columnar.ColumnarPlacement`)
advances every (theta, kappa) branch of the SJF-BCO forest by one job per
step.  Its per-step array program -- the Eq. (16) feasibility pools
(``U + rho/u <= theta + 1e-9``), the per-server busy/feasible-count
reductions behind the FA-FFP/LBSGF picks, and the Eq. (6)-(8) tau/rho
scoring of the probed candidates -- is a pile of small dense ops over
``[rows, N]`` operands, which on the NumPy path pays one dispatch per op.
This module fuses each half into ONE ``jax.jit`` program:

  * :func:`pick_orders` -- pool threshold counts at each work item's
    extreme thetas, GPU-id-order per-server busy sums, feasible-slot
    counts and the FA-FFP best-server selection, in one fused program over
    a ``[rows, N]`` block padded to a power-of-two row bucket; the stable
    pick *rankings* then run host-side with NumPy sorts over those
    bitwise-equal keys (XLA's CPU stable sort is ~10-20x slower than
    NumPy's on these small rows, so sorting in-program would erase the
    fusion win);
  * :func:`score_probes` -- Eq. (8) tau and the rho-hat slot count for a
    padded batch of probed candidates, reusing
    :func:`repro.kernels.tau`'s hetero-aware term layout (per-server
    speed floors, shared/isolated uplinks with +inf where absent).

Row shapes are padded to power-of-two buckets so the programs retrace only
per (bucket, cluster) -- never per job (pinned by the compile-count guard
in ``tests/test_columnar_equivalence.py``).  With ``use_kernel=True`` the
same row math runs inside Pallas kernels (grid step = one branch row, the
whole row reduction in VMEM; interpret mode on CPU, real lowering on TPU)
-- the kernels share the jnp expressions with the fast path, so all three
backends (numpy / jit / kernel) are bit-identical under ``jax_enable_x64``:
the per-server sums replay ``np.bincount``'s GPU-id addition order as a
statically unrolled in-order block reduction, and every sort is a stable
sort over bitwise-equal keys.  Without x64 jax computes in float32 and the
fused path is rejected (:func:`require_x64`) rather than silently diverging
from the scalar oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

__all__ = ["pick_orders", "score_probes", "require_x64", "compile_counts"]

#: Smallest padded row bucket (power of two).
MIN_BUCKET = 4

#: Below this many rows the stats run in NumPy instead of the device
#: program: one CPU dispatch+fetch round-trip (~300us measured on this
#: host) costs more than the reductions it replaces.  Calibrated on the
#: 32-server Philly cluster at |J| = 8192 (thresh 32 -> 37.6s, thresh
#: 64 -> 32.9s vs 32.8s pure NumPy; the work-group histogram tops out
#: near 48 rows there, so 64 means "dispatch only on genuinely tall
#: batches").  ``use_kernel=True`` always dispatches (the Pallas path
#: is about lowering, not CPU speed).
DISPATCH_MIN_ROWS = 64


def require_x64() -> None:
    """Reject the fused path when jax would compute in float32.

    The columnar engine's bit-identity contract against the scalar oracle
    only holds in float64; callers resolve ``columnar_backend="auto"`` to
    "numpy" in that case, so reaching this error means "jit"/"kernel" was
    forced explicitly.
    """
    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "columnar_backend='jit'/'kernel' needs jax_enable_x64 for "
            "bit-identity with the scalar oracle; enable x64 "
            '(jax.config.update("jax_enable_x64", True)) or use '
            "columnar_backend='numpy'")


def _bucket(n: int) -> int:
    """Power-of-two padding bucket for ``n`` rows (>= MIN_BUCKET)."""
    return max(MIN_BUCKET, 1 << (max(1, n) - 1).bit_length())


@functools.lru_cache(maxsize=64)
def _cluster_consts(cluster) -> dict:
    """Per-cluster constant arrays for the fused programs (cached; the
    Cluster dataclass is frozen/hashable).  ``block_idx``/``block_valid``
    drive the GPU-id-order per-server block sums: server ``s`` owns the
    contiguous GPU range ``[offset_s, offset_s + cap_s)``, padded to the
    cluster's max capacity with clipped (masked-out) indices."""
    caps = cluster.capacities_array
    offsets = np.concatenate([[0], np.cumsum(caps)[:-1]])
    maxcap = int(caps.max())
    block_idx = np.minimum(offsets[:, None] + np.arange(maxcap)[None, :],
                           cluster.num_gpus - 1)
    block_valid = np.arange(maxcap)[None, :] < caps[:, None]
    return {
        # Device-committed constants (passed into the jit programs; the
        # pjit fast path sees committed arrays and skips the transfer).
        "block_idx": jnp.asarray(block_idx),
        "block_valid": jnp.asarray(block_valid),
        "speed_floor": jnp.asarray(cluster.server_speed_floor),
        "uplink_shared": jnp.asarray(cluster.uplink_shared_or_inf),
        "uplink_isolated": jnp.asarray(cluster.uplink_isolated_or_inf),
        # Host copies for the NumPy ranking half.
        "np_gpu_server": np.asarray(cluster.gpu_server),
        "np_caps": np.asarray(caps),
    }


# --------------------------------------------------------------------------
# Row math (shared verbatim by the jnp fast path and the Pallas kernels)
# --------------------------------------------------------------------------


def _pool_row_math(U, tlo, thi, rho_u, G, block_idx, block_valid):
    """Per-row pool/threshold/server reductions for a ``[B, N]`` block.

    Returns ``(V, feas, c_lo, c_hi, load, cnt, best_srv, has_fit)``.  The
    per-server busy sums replay ``np.bincount(gpu_server, weights=U)``'s
    sequential GPU-id addition order as a statically unrolled in-order
    reduction over each server's contiguous block (trailing masked lanes
    add +0.0, which is the identity for the non-negative clocks), so the
    FA-FFP/LBSGF sort keys are bitwise equal to the NumPy pickers'."""
    N = U.shape[-1]
    V = U + rho_u[:, None]
    feas = V <= tlo[:, None] + 1e-9                     # Eq. (16) pool
    c_lo = jnp.sum(feas, axis=-1)
    c_hi = jnp.sum(V <= thi[:, None] + 1e-9, axis=-1)
    Ub = U[:, block_idx]                                # [B, S, maxcap]
    Fb = feas[:, block_idx] & block_valid[None]
    cnt = jnp.sum(Fb, axis=-1)                          # exact: bool counts
    load = jnp.zeros(U.shape[:-1] + block_idx.shape[:1], U.dtype)
    for i in range(block_idx.shape[1]):                 # GPU-id order
        load = load + jnp.where(block_valid[None, :, i], Ub[:, :, i], 0.0)
    # FA-FFP best server: lexicographic min over (feasible slots left,
    # -occupancy, server id) as staged masked argmins -- the same total
    # order as the scalar lexsort, ties resolved by first index.
    fits = cnt >= G
    has_fit = jnp.any(fits, axis=-1)
    k_fit = jnp.where(fits, cnt - G, N + 1)
    k_occ = jnp.where(fits, -load, jnp.inf)
    t1 = k_fit == jnp.min(k_fit, axis=-1, keepdims=True)
    k2 = jnp.where(t1, k_occ, jnp.inf)
    t2 = t1 & (k2 == jnp.min(k2, axis=-1, keepdims=True))
    best_srv = jnp.argmax(t2, axis=-1)
    return V, feas, c_lo, c_hi, load, cnt, best_srv, has_fit


def _score_row_math(Y, f, gamma, two_share, share, reduce_const, compute,
                    iters, speed_floor, uplink_sh, uplink_iso, *, hetero,
                    b_inter, b_intra):
    """Eq. (6)-(8) tau + rho-hat slots for a ``[B, S]`` candidate block.

    Same expressions in the same order as
    :func:`repro.core.contention.scalar_tau_many` /
    :func:`~repro.core.contention.slots_for_many`; the hetero branch reuses
    :func:`repro.kernels.tau`'s term layout (per-server speed floor and
    shared/isolated uplinks with +inf where the class is absent).

    The contention terms that multiply into a later addition -- k, the
    degradation f, gamma = xi2 * n_srv -- arrive precomputed from the host:
    XLA CPU contracts ``a*b + c`` into an FMA inside a fused loop (one ulp
    off the separately rounded NumPy result, and ``optimization_barrier``
    does not stop the LLVM-level contraction), so the program keeps only
    mins, divides, selects and adds, which have no contractible pairs."""
    pos = Y > 0
    multi = jnp.sum(pos, axis=-1) > 1
    if hetero:
        inf = jnp.inf
        speed = jnp.min(jnp.where(pos, speed_floor, inf), axis=-1)
        bw_sh = jnp.min(jnp.where(pos, uplink_sh, inf), axis=-1)
        bw_iso = jnp.min(jnp.where(pos, uplink_iso, inf), axis=-1)
        bw_multi = jnp.minimum(bw_iso, bw_sh / f)
        reduce_t = share / speed
    else:
        bw_multi = b_inter / f
        reduce_t = reduce_const
    bandwidth = jnp.where(multi, bw_multi, b_intra)
    exchange = two_share / bandwidth
    # Eq. (8), same left-to-right addition order as the NumPy engines.
    tau = exchange + reduce_t + gamma + compute
    phi = jnp.maximum(1.0, jnp.floor(1.0 / tau))
    rho = jnp.ceil(iters / phi)
    return tau, rho


# --------------------------------------------------------------------------
# Pallas kernel bodies (one grid step per branch row, reductions in VMEM)
# --------------------------------------------------------------------------


def _pool_kernel(U_ref, tlo_ref, thi_ref, rho_ref, g_ref, bidx_ref,
                 bval_ref, V_ref, feas_ref, clo_ref, chi_ref, load_ref,
                 cnt_ref, best_ref, fit_ref):
    """One branch row: Eq. (16) pools + per-server reductions in VMEM."""
    V, feas, c_lo, c_hi, load, cnt, best, fit = _pool_row_math(
        U_ref[...], tlo_ref[...][:, 0], thi_ref[...][:, 0],
        rho_ref[...][:, 0], g_ref[0, 0], bidx_ref[...], bval_ref[...] != 0)
    V_ref[...] = V
    feas_ref[...] = feas.astype(feas_ref.dtype)
    clo_ref[...] = c_lo[:, None].astype(clo_ref.dtype)
    chi_ref[...] = c_hi[:, None].astype(chi_ref.dtype)
    load_ref[...] = load
    cnt_ref[...] = cnt.astype(cnt_ref.dtype)
    best_ref[...] = best[:, None].astype(best_ref.dtype)
    fit_ref[...] = fit[:, None].astype(fit_ref.dtype)


def _score_kernel(Y_ref, f_ref, gamma_ref, scal_ref, spd_ref, sh_ref,
                  iso_ref, tau_ref, rho_ref, *, hetero, b_inter, b_intra):
    """One candidate row: Eq. (6)-(8) tau + rho-hat slots in VMEM.

    ``scal_ref`` packs the five job scalars (two_share, share,
    reduce_const, compute, iters) into one grid-invariant row."""
    tau, rho = _score_row_math(
        Y_ref[...], f_ref[...][:, 0], gamma_ref[...][:, 0], scal_ref[0, 0],
        scal_ref[0, 1], scal_ref[0, 2], scal_ref[0, 3], scal_ref[0, 4],
        spd_ref[0], sh_ref[0], iso_ref[0], hetero=hetero,
        b_inter=b_inter, b_intra=b_intra)
    tau_ref[...] = tau[:, None]
    rho_ref[...] = rho[:, None]


# --------------------------------------------------------------------------
# Fused jit programs
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def _pool_stats_jit(U, tlo, thi, rho_u, G, block_idx, block_valid, *,
                    use_kernel, interpret):
    """One fused program: pools, thresholds and per-server reductions.

    Everything *sortless* of the pick pipeline runs here -- the charged
    clocks, both extreme-theta pool counts, the GPU-id-order busy sums,
    feasible-slot counts and the FA-FFP best-server argmin.  The stable
    rankings themselves stay on the host (NumPy's stable sorts beat XLA's
    CPU variadic sort by an order of magnitude on these small rows, and
    host sorting over bitwise-equal keys keeps bit-identity trivial).
    """
    B, N = U.shape
    itype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    if use_kernel:
        S, maxcap = block_idx.shape
        outs = pl.pallas_call(
            _pool_kernel,
            grid=(B,),
            in_specs=[
                pl.BlockSpec((1, N), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (0, 0)),
                pl.BlockSpec((S, maxcap), lambda b: (0, 0)),
                pl.BlockSpec((S, maxcap), lambda b: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, N), lambda b: (b, 0)),
                pl.BlockSpec((1, N), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (b, 0)),
                pl.BlockSpec((1, S), lambda b: (b, 0)),
                pl.BlockSpec((1, S), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (b, 0)),
                pl.BlockSpec((1, 1), lambda b: (b, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((B, N), U.dtype),      # V
                jax.ShapeDtypeStruct((B, N), itype),        # feas
                jax.ShapeDtypeStruct((B, 1), itype),        # c_lo
                jax.ShapeDtypeStruct((B, 1), itype),        # c_hi
                jax.ShapeDtypeStruct((B, S), U.dtype),      # load
                jax.ShapeDtypeStruct((B, S), itype),        # cnt
                jax.ShapeDtypeStruct((B, 1), itype),        # best_srv
                jax.ShapeDtypeStruct((B, 1), itype),        # has_fit
            ],
            compiler_params=CompilerParams(),
            interpret=interpret,
        )(U, tlo[:, None], thi[:, None], rho_u[:, None],
          jnp.reshape(G, (1, 1)).astype(itype), block_idx,
          block_valid.astype(itype))
        V, _feas, c_lo2, c_hi2, load, cnt, best2, fit2 = outs
        c_lo, c_hi = c_lo2[:, 0], c_hi2[:, 0]
        best_srv, has_fit = best2[:, 0], fit2[:, 0].astype(bool)
    else:
        V, _feas, c_lo, c_hi, load, cnt, best_srv, has_fit = _pool_row_math(
            U, tlo, thi, rho_u, G, block_idx, block_valid)
    # feas is recomputed host-side from V (one elementwise compare).
    return V, c_lo, c_hi, load, cnt, best_srv, has_fit


@functools.partial(jax.jit, static_argnames=(
    "hetero", "b_inter", "b_intra", "use_kernel", "interpret"))
def _score_probes_jit(Y, f, gamma, scalars, speed_floor, uplink_sh,
                      uplink_iso, *, hetero, b_inter, b_intra, use_kernel,
                      interpret):
    """One fused program: Eq. (6)-(8) tau + rho for a candidate batch.

    ``scalars`` is the ``[1, 5]`` job-scalar row (two_share, share,
    reduce_const, compute, iters), precomputed on the host together with
    the degradation ``f`` and gamma terms (see :func:`_score_row_math` on
    why those multiplies must not live inside the program)."""
    B, S = Y.shape
    if not use_kernel:
        return _score_row_math(
            Y, f, gamma, scalars[0, 0], scalars[0, 1], scalars[0, 2],
            scalars[0, 3], scalars[0, 4], speed_floor[None, :],
            uplink_sh[None, :], uplink_iso[None, :], hetero=hetero,
            b_inter=b_inter, b_intra=b_intra)
    ftype = f.dtype                 # float; Y itself is the int occupancy
    tau2, rho2 = pl.pallas_call(
        functools.partial(_score_kernel, hetero=hetero, b_inter=b_inter,
                          b_intra=b_intra),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 5), lambda b: (0, 0)),
            pl.BlockSpec((1, S), lambda b: (0, 0)),
            pl.BlockSpec((1, S), lambda b: (0, 0)),
            pl.BlockSpec((1, S), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), ftype),
            jax.ShapeDtypeStruct((B, 1), ftype),
        ],
        compiler_params=CompilerParams(),
        interpret=interpret,
    )(Y, f[:, None], gamma[:, None], scalars, speed_floor[None, :],
      uplink_sh[None, :], uplink_iso[None, :])
    return tau2[:, 0], rho2[:, 0]


# --------------------------------------------------------------------------
# Public entry points (NumPy in, NumPy out, power-of-two padding)
# --------------------------------------------------------------------------


def pick_orders(cluster, U_stack: np.ndarray, th_lo: np.ndarray,
                th_hi: np.ndarray, rho_u: np.ndarray, pid: np.ndarray,
                job, *, use_kernel: bool = False,
                interpret: bool | None = None):
    """Fused pool/threshold/pick program over one step's work items.

    ``U_stack`` [nw, N] gathers each work item's busy-time row; ``th_lo``/
    ``th_hi`` its extreme branch thetas, ``rho_u`` its escalated rho/u
    charge and ``pid`` its picker id (0 = FA-FFP, 1 = LBSGF).  Returns
    NumPy ``(V, c_lo, c_hi, order, ok)``: the charged clocks, pool counts
    at both extremes, each row's full stable GPU ordering (the pick is
    ``order[i, :G_j]``) and the pool-large-enough flag -- all bit-identical
    to the NumPy ``pick_many`` forms under x64.

    The device program computes the reductions (pools, per-server busy
    sums/counts, FA-FFP best server); the stable rankings run here on the
    host with NumPy's sorts over those bitwise-equal keys, mirroring the
    second halves of ``_fa_ffp_many`` / ``_lbsgf_many`` term for term.
    Batches under :data:`DISPATCH_MIN_ROWS` skip the device round-trip and
    compute the same reductions in NumPy (identical accumulation order via
    :func:`repro.core.columnar.server_sums`) -- on CPU a dispatch costs
    more than the stats it replaces below that size.
    """
    require_x64()
    nw, N = U_stack.shape
    G = job.num_gpus
    consts = _cluster_consts(cluster)
    gpu_server = consts["np_gpu_server"]
    caps = consts["np_caps"]
    S = caps.shape[0]
    if use_kernel or nw >= DISPATCH_MIN_ROWS:
        R = _bucket(nw)
        if R != nw:
            U_pad = np.concatenate(
                [U_stack, np.zeros((R - nw, N), dtype=U_stack.dtype)])
            pad = np.zeros(R - nw)
            tl, th, ru = (np.concatenate([a, pad])
                          for a in (th_lo, th_hi, rho_u))
        else:
            U_pad, tl, th, ru = U_stack, th_lo, th_hi, rho_u
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        # NumPy operands go straight into the jitted call -- the pjit C++
        # dispatch converts them far cheaper than an eager device_put
        # per arg.
        outs = _pool_stats_jit(
            U_pad, tl, th, ru, G, consts["block_idx"],
            consts["block_valid"], use_kernel=use_kernel,
            interpret=interpret)
        V, c_lo, c_hi, load, _cnt, best_srv, has_fit = (
            np.asarray(o)[:nw] for o in outs)
        feas = V <= th_lo[:, None] + 1e-9              # Eq. (16) pool
    else:
        from repro.core.columnar import server_sums
        V = U_stack + rho_u[:, None]
        feas = V <= th_lo[:, None] + 1e-9              # Eq. (16) pool
        c_lo = feas.sum(axis=1)
        c_hi = (V <= th_hi[:, None] + 1e-9).sum(axis=1)
        load = server_sums(cluster, U_stack)
        cnt = server_sums(cluster,
                          feas.astype(np.float64)).astype(np.int64)
        fits = cnt >= G
        has_fit = fits.any(axis=1)
        k_fit = np.where(fits, cnt - G, N + 1)
        k_occ = np.where(fits, -load, np.inf)
        t1 = k_fit == k_fit.min(axis=1, keepdims=True)
        k2 = np.where(t1, k_occ, np.inf)
        t2 = t1 & (k2 == k2.min(axis=1, keepdims=True))
        best_srv = t2.argmax(axis=1)
    U = U_stack
    order = np.empty((nw, N), dtype=np.int64)
    ok = np.empty(nw, dtype=bool)
    fa = np.flatnonzero(pid == 0)
    if fa.size:
        # FA-FFP: pack into the best-fit server when one fits, else
        # spread over the whole pool (== _fa_ffp_many's masked keys).
        in_best = feas[fa] & (gpu_server[None, :] == best_srv[fa, None])
        keys = np.where(has_fit[fa, None],
                        np.where(in_best, U[fa], np.inf),
                        np.where(feas[fa], U[fa], np.inf))
        order[fa] = np.argsort(keys, axis=1, kind="stable")
        ok[fa] = c_lo[fa] >= G
    lb = np.flatnonzero(pid == 1)
    if lb.size:
        # LBSGF: least-busy server prefix of lambda_j*G capacity, then
        # server-rank-major / least-U lexsort (== _lbsgf_many).
        nl = lb.size
        srv_order = np.argsort(load[lb] / caps[None, :].astype(np.float64),
                               axis=1, kind="stable")
        cum = np.cumsum(np.take_along_axis(
            np.broadcast_to(caps[None, :], srv_order.shape), srv_order,
            axis=1), axis=1)
        m = np.minimum((cum < job.lam * G).sum(axis=1) + 1, S)
        pos = np.arange(S)[None, :]
        rank_vals = np.where(pos < m[:, None], pos, -1)
        srv_rank = np.empty_like(srv_order)
        np.put_along_axis(srv_rank, srv_order, rank_vals, axis=1)
        ranks = srv_rank[:, gpu_server]
        pool = feas[lb] & (ranks >= 0)
        ok[lb] = pool.sum(axis=1) >= G
        k_rank = np.where(pool, ranks, S + 1)
        k_U = np.where(pool, U[lb], np.inf)
        r_off = (np.arange(nl) * N)[:, None]
        flat = np.lexsort((k_U.ravel(), k_rank.ravel(),
                           np.repeat(np.arange(nl), N)))
        order[lb] = flat.reshape(nl, N) - r_off
    return V, c_lo, c_hi, order, ok


def score_probes(cluster, job, Y: np.ndarray, p: np.ndarray, *,
                 use_kernel: bool = False, interpret: bool | None = None):
    """Fused Eq. (6)-(8) scoring of one step's probed candidates.

    ``Y`` [C, S] holds each candidate's occupancy row and ``p`` its
    host-probed contention level (float64, from the incremental engine's
    suffix counts).  Returns NumPy ``(tau, rho)`` bit-identical to
    ``scalar_tau_many`` + ``slots_for_many`` under x64; heterogeneous
    clusters price worst-member device terms exactly like
    :func:`repro.core.contention._hetero_mins`.  Batches under
    :data:`DISPATCH_MIN_ROWS` skip the device round-trip and score through
    those NumPy forms directly (same expressions, same order).
    """
    require_x64()
    C, S = Y.shape
    if not use_kernel and C < DISPATCH_MIN_ROWS:
        from repro.core import contention as ct
        n_srv = (Y > 0).sum(axis=1)
        if cluster.is_heterogeneous:
            tau = ct.scalar_tau_many(cluster, job, p, n_srv,
                                     *ct._hetero_mins(cluster, Y > 0))
        else:
            tau = ct.scalar_tau_many(cluster, job, p, n_srv)
        return tau, ct.slots_for_many(job.iters, tau)
    from repro.core.contention import degradation
    B = _bucket(C)
    # Host-side contention terms (every multiply that would feed an
    # addition in-program; see _score_row_math).
    k = np.maximum(cluster.xi1 * np.asarray(p, dtype=np.float64), 1.0)
    f = degradation(cluster.alpha, k)
    gamma = cluster.xi2 * (Y > 0).sum(axis=1).astype(np.float64)
    if B != C:
        Y = np.concatenate([Y, np.zeros((B - C, S), dtype=Y.dtype)])
        f = np.concatenate([f, np.ones(B - C)])
        gamma = np.concatenate([gamma, np.zeros(B - C)])
    consts = _cluster_consts(cluster)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    w = float(job.num_gpus)
    share = (job.grad_size / w) * (w - 1.0) if w > 1 else 0.0
    compute = job.dt_fwd * float(job.batch) + job.dt_bwd
    scalars = np.array([[2.0 * share, share, share / cluster.gpu_speed,
                         compute, float(job.iters)]])
    tau, rho = _score_probes_jit(
        Y, f, gamma, scalars, consts["speed_floor"],
        consts["uplink_shared"], consts["uplink_isolated"],
        hetero=cluster.is_heterogeneous, b_inter=cluster.b_inter,
        b_intra=cluster.b_intra, use_kernel=use_kernel,
        interpret=interpret)
    return np.asarray(tau)[:C], np.asarray(rho)[:C]


def compile_counts() -> dict[str, int]:
    """Compiled-variant counts of the fused programs (the no-retrace
    guard: bounded by padding buckets x clusters, never growing per job)."""
    return {"pick_orders": _pool_stats_jit._cache_size(),
            "score_probes": _score_probes_jit._cache_size()}
