"""Columnar branch-vectorised placement: the whole sweep x bisect forest
as one array program.

The speculative machinery of :mod:`repro.core.api` (``SharedState`` +
``try_place_group``) advances a *lineage forest* of per-branch
:class:`~repro.core.api.PlacementState` objects: branches fork with
copy-on-write clones at the first divergent placement and never re-merge,
so cross-theta sharing decays to ~5-15% and the scheduler remains a scalar
Python walk per lineage.  :class:`ColumnarPlacement` replaces the forest
with a columnar layout:

  * every (theta, kappa) **branch** maps onto a deduplicated state **row**;
    the row store is a pair of ``[rows, N]`` clock matrices (busy-time U,
    real-time R) plus ONE shared append-only decision-log arena -- flat
    ``jid``/``start``/``finish``/``gpus`` columns threaded by per-record
    parent pointers, so a row is just a tail index into the arena and
    cloning a row costs O(N + S) regardless of how many jobs it has placed
    (the O(placed) per-clone list copies of the first columnar engine were
    the 16k-scale bottleneck);
  * each :meth:`place` call advances **every** live branch by one job as
    masked vectorised ops: the Eq. (16) pools (``U + rho/u <= theta``) are
    threshold counts on one sorted vector per row, the FA-FFP/LBSGF/FF/LS
    argmin picks run as one ``picker.pick_many`` call over the whole
    ``[groups, N]`` batch, refined-rho probes are scored for all groups in
    one :func:`~repro.core.contention.scalar_tau_many` /
    :func:`~repro.core.contention.evaluate_stack` pass, and the Eq. (16)
    re-check splits each theta run with a single vectorised comparison;
  * with ``backend="jit"`` (the default fast path under x64) the pool
    split, the per-server reductions, both pickers' full GPU orderings and
    the Eq. (6)-(8) probe scoring each run as ONE fused ``jax.jit``
    program from :mod:`repro.kernels.placement` -- padded to power-of-two
    row buckets so nothing retraces across jobs; ``backend="kernel"``
    routes the same row math through the Pallas kernels (grid step = one
    branch row, interpret mode on CPU); ``backend="numpy"`` keeps the
    eager NumPy ops.  All three are bit-identical under x64;
  * branches whose decisions coincide are **re-merged**: a committed step
    is a pure function of (parent row, chosen GPU set), so children are
    deduplicated by the ``(parent row, gpus)`` key -- exactly the state
    hash the COW forest cannot exploit once lineages have forked.

Decision-for-decision the engine replays :func:`repro.core.api.try_place`
per branch: the same pool thresholds, the same picker tie-breaks (the
``pick_many`` forms are elementwise-identical to the scalar pickers), the
same memoised rho_hat(y^k) scores, the same ``max(rho, rho_try * 1.05)``
escalation ladder, and the same float expressions in the same order -- so
schedules are bit-identical to the scalar oracle (pinned by
``tests/test_columnar_equivalence.py`` and the ``--quick`` bench smokes).
The engine backs ``placement="columnar"`` of the bisection policies; the
scalar walk stays selectable as ``placement="scalar"``.
"""
from __future__ import annotations

import array as _arr
import bisect as _bisect

import numpy as np

from repro.core import contention
from repro.core.cluster import Cluster
from repro.core.contention import (_job_terms, evaluate_stack,
                                   predict_exec_time, resolve_engine,
                                   scalar_tau, scalar_tau_many, slots_for,
                                   slots_for_many)
from repro.core.jobs import Job

__all__ = ["ColumnarPlacement", "server_sums", "COLUMNAR_BACKENDS"]

#: Selectable math backends for the columnar step (see module docstring).
COLUMNAR_BACKENDS = ("numpy", "jit", "kernel")


# Flat index arrays reused across millions of small pick/score batches
# ([rows ~ 10-50, N ~ 100-300]); at those shapes the allocations cost more
# than the reductions they feed.  Entries are marked read-only -- they are
# only ever lexsort keys / gather indices.  Keys are (kind, R, M): "rep" =
# np.repeat(arange(R), M), "tile" = np.tile(arange(M), R).
_FLAT_IDS: dict[tuple[str, int, int], np.ndarray] = {}


def _flat_ids(kind: str, R: int, M: int) -> np.ndarray:
    a = _FLAT_IDS.get((kind, R, M))
    if a is None:
        a = (np.repeat(np.arange(R), M) if kind == "rep"
             else np.tile(np.arange(M), R))
        a.setflags(write=False)
        _FLAT_IDS[(kind, R, M)] = a
    return a


def server_sums(cluster: Cluster, W: np.ndarray) -> np.ndarray:
    """Per-(row, server) sums of a ``[rows, N]`` per-GPU weight matrix.

    The batched form of ``np.bincount(cluster.gpu_server, weights=w)``:
    one flat bincount over row-major keys accumulates every (row, server)
    bin in GPU-id order -- the same additions in the same order as the
    scalar pickers' per-server bincounts, so the sums are bit-identical
    per row.  Shared by the vectorised ``pick_many`` forms of FA-FFP
    (occupancy scores) and LBSGF (server loads)."""
    R, N = W.shape
    S = cluster.num_servers
    cache = cluster._batch_key_cache
    keys = cache.get(R)
    if keys is None:
        keys = (np.arange(R)[:, None] * S
                + cluster.gpu_server[None, :]).ravel()
        keys.setflags(write=False)
        cache[R] = keys
    return np.bincount(keys, weights=np.ascontiguousarray(W).ravel(),
                       minlength=R * S).reshape(R, S)


class _Work:
    """One resolution-ladder work item: a run of branches sharing a row, a
    picker, the current escalated rho, and the memoised candidate scores
    (shared down the retry chain, as in ``try_place_group``)."""

    __slots__ = ("row", "pid", "branches", "rho_try", "scored")

    def __init__(self, row: int, pid: int, branches: np.ndarray,
                 rho_try: float, scored: dict):
        self.row = row
        self.pid = pid
        self.branches = branches
        self.rho_try = rho_try
        self.scored = scored


class ColumnarPlacement:
    """Branch-vectorised placement over ``[rows, N]`` clock matrices.

    ``thetas`` fixes the branch axis: branch ``b`` replays the scalar
    placement walk at budget ``thetas[b]`` (callers encode the kappa sweep
    by assigning pickers per branch in :meth:`place`).  ``jobs`` is the
    request's jid-indexed job list (the per-jid Eq. (8) terms and the
    reference-engine snapshots are gathered from it).  ``engine`` selects
    how rho_hat(y^k) probes evaluate, exactly as for
    :class:`~repro.core.api.PlacementState`: ``"incremental"`` suffix
    counts + one ``scalar_tau_many`` per step, ``"batched"`` one padded
    :func:`~repro.core.contention.evaluate_stack` pass over the branch
    stack, ``"reference"`` the per-candidate ``evaluate`` loop.
    ``backend`` selects where the step's array math runs: ``"numpy"``
    (eager), ``"jit"`` (fused :mod:`repro.kernels.placement` programs;
    needs ``jax_enable_x64``) or ``"kernel"`` (same programs with the
    Pallas row kernels; interpret mode on CPU) -- all bit-identical.
    """

    #: try_place's escalation-ladder depth (same constant, same semantics).
    TRIES = 4

    def __init__(self, cluster: Cluster, thetas, jobs: list[Job], u: float,
                 engine: str | None = None, backend: str = "numpy"):
        self.cluster = cluster
        self.engine = resolve_engine(engine)
        if backend not in COLUMNAR_BACKENDS:
            raise ValueError(
                f"unknown columnar backend {backend!r}; choose one of "
                f"{COLUMNAR_BACKENDS}")
        self.backend = backend
        self._kern = None
        if backend != "numpy":
            from repro.kernels import placement as _kern
            _kern.require_x64()
            self._kern = _kern
        self.u = float(u)
        self.jobs = jobs
        self.thetas = np.asarray(thetas, dtype=np.float64)
        B = len(self.thetas)
        if B == 0:
            raise ValueError("columnar placement needs at least one branch")
        self.n_branches = B
        self.n_jobs = len(jobs)
        self.alive = np.ones(B, dtype=bool)
        self.row_of = np.zeros(B, dtype=np.int64)
        # Placement-independent Eq. (8) terms, gathered by jid for the
        # batched-engine branch stacks.
        self._G_t, self._share_t, self._compute_t = _job_terms(jobs)

        N = cluster.num_gpus
        S = cluster.num_servers
        cap = max(1, B)
        self.U = np.zeros((cap, N))          # busy-time clocks (Eq. 15/16)
        self.R = np.zeros((cap, N))          # real-time clocks (gang start)
        self._free = list(range(1, cap))
        self._live_rows: set[int] = {0}
        # The shared decision-log arena: one append-only record per
        # committed (child row, jid) decision, flat columns + a parent
        # pointer chain.  A row's history is the chain from its tail
        # record; rows are just (tail, count) pairs, so clones never copy
        # decision lists and result() gathers chains as fancy-indexed
        # NumPy views over the arena columns.
        self._log_jid = _arr.array("q")
        self._log_prev = _arr.array("q")
        self._log_start = _arr.array("d")
        self._log_fin = _arr.array("d")
        self._log_g: list[np.ndarray] = []
        self._log_y: list[np.ndarray] = []
        self._tail: dict[int, int] = {0: -1}
        self._count: dict[int, int] = {0: 0}
        # Per-step caches over the arena (invalidated on commit).
        self._chain_cache: dict[int, np.ndarray] = {}
        self._y_cache: dict[int, np.ndarray] = {}
        # Per-server sorted est_finish of straddling placed jobs, shared
        # copy-on-write between cloned rows (see PlacementState.clone).
        self._straddle_fin: dict[int, list[list[float]]] = \
            {0: [[] for _ in range(S)]}
        self._fin_owned: dict[int, list[bool]] = {0: [True] * S}
        # Running decision-history fingerprint (the dedup "state hash").
        self._state_hash: dict[int, int] = {0: 0}
        # Picker tuple already validated by place() (identity-cached).
        self._checked_pickers: tuple | None = None
        self._pick_ids: np.ndarray | None = None
        # Branch thetas as plain floats for the singleton-run scalar
        # compares (the vector form stays in self.thetas).
        self._thetas_f = self.thetas.tolist()
        # Live-branch counter (place() kills branches; O(1) liveness for
        # the sweep's early-exit check).
        self._n_live = B
        # Per-job rho memo for the homogeneous incremental engine: Eq. (8)
        # depends on the candidate only through (p, n_srv), and a step's
        # candidates hit a handful of distinct pairs -- one scalar_tau per
        # distinct pair replaces whole scalar_tau_many/score_probes calls
        # (bit-identical: the scalar expression is pinned equal to the
        # vectorised and kernel forms).
        self._rho_memo: dict[tuple[int, int], float] = {}
        self._rho_memo_jid = -1

    # -- row store ---------------------------------------------------------

    def _alloc_row(self) -> int:
        if not self._free:
            cap = self.U.shape[0]
            grow = np.zeros_like(self.U)
            self.U = np.concatenate([self.U, grow])
            self.R = np.concatenate([self.R, np.zeros_like(grow)])
            self._free.extend(range(cap, 2 * cap))
        r = self._free.pop()
        self._live_rows.add(r)
        return r

    def _free_row(self, r: int) -> None:
        self._live_rows.discard(r)
        self._free.append(r)
        for store in (self._tail, self._count, self._chain_cache,
                      self._y_cache, self._straddle_fin, self._fin_owned,
                      self._state_hash):
            store.pop(r, None)

    def _clone_row(self, parent: int) -> int:
        """Copy-on-write fork of a row (the columnar PlacementState.clone):
        O(N + S) copies -- the decision history is a tail pointer into the
        shared arena, and the sorted-finish lists are shared until a
        commit first writes into one (both sides drop ownership)."""
        r = self._alloc_row()
        self.U[r] = self.U[parent]
        self.R[r] = self.R[parent]
        self._tail[r] = self._tail[parent]
        self._count[r] = self._count[parent]
        self._straddle_fin[r] = list(self._straddle_fin[parent])
        S = self.cluster.num_servers
        self._fin_owned[r] = [False] * S
        self._fin_owned[parent] = [False] * S
        self._state_hash[r] = self._state_hash[parent]
        return r

    # -- decision-log gathers ----------------------------------------------

    def _chain(self, row: int) -> np.ndarray:
        """Arena record indices of ``row``'s decisions, oldest first
        (cached per step; a chain walk is O(placed) but runs only for
        engines/results that need the full history)."""
        idx = self._chain_cache.get(row)
        if idx is None:
            n = self._count[row]
            idx = np.empty(n, dtype=np.int64)
            i = self._tail[row]
            prev = self._log_prev
            for k in range(n - 1, -1, -1):
                idx[k] = i
                i = prev[i]
            self._chain_cache[row] = idx
        return idx

    def _row_cols(self, row: int) -> tuple[np.ndarray, np.ndarray,
                                           np.ndarray]:
        """(jids, starts, finishes) of ``row``'s decisions, oldest first,
        gathered zero-copy from the arena columns."""
        idx = self._chain(row)
        if not len(idx):
            z = np.empty(0, dtype=np.int64)
            return z, np.empty(0), np.empty(0)
        return (np.frombuffer(self._log_jid, dtype=np.int64)[idx],
                np.frombuffer(self._log_start, dtype=np.float64)[idx],
                np.frombuffer(self._log_fin, dtype=np.float64)[idx])

    def _row_Y(self, row: int) -> np.ndarray:
        """Stacked per-decision occupancy rows ``[placed, S]`` of ``row``
        (cached per step; only the batched/reference engines need it)."""
        Y = self._y_cache.get(row)
        if Y is None:
            idx = self._chain(row)
            S = self.cluster.num_servers
            ylog = self._log_y
            Y = (np.stack([ylog[i] for i in idx.tolist()])
                 if len(idx) else np.zeros((0, S), dtype=np.int64))
            self._y_cache[row] = Y
        return Y

    # -- scoring (rho_hat(y^k) probes, batched over candidates) ------------

    def _score(self, job: Job, need: list[tuple["_Work", bytes, np.ndarray]]
               ) -> None:
        """Score every unseen (row, gpus) candidate of this step in one
        engine pass and fill the work items' memo dicts with
        ``(rho, start, y)``.  Values are bit-identical to
        ``PlacementState.refined_rho`` on the equivalent scalar state."""
        cl = self.cluster
        S = cl.num_servers
        C = len(need)
        G = job.num_gpus
        # All candidates place the same G-gang, so starts and occupancy
        # rows come from two batched gathers instead of C bincounts.
        rows_n = np.fromiter((w.row for w, _, _ in need), np.int64, C)
        gmat = np.concatenate([g for _, _, g in need]).reshape(C, G)
        starts = (self.R[rows_n[:, None], gmat].max(axis=1) if G
                  else np.zeros(C))
        # Integer counts per (candidate, server): one flat bincount (same
        # counts as the np.add.at it replaces, far cheaper per call).
        ys_mat = np.bincount(_flat_ids("rep", C, G) * S
                             + cl.gpu_server[gmat.ravel()],
                             minlength=C * S).reshape(C, S)
        ys = list(ys_mat)
        if self.engine == "incremental":
            ns = (ys_mat > 0).sum(axis=1)
            ps = np.zeros(C, dtype=np.int64)
            cuts = starts + 1e-9
            # Contention probes only on actually-straddled (c, s) pairs
            # (same max-over-servers as the scalar probe, same bisects).
            pc, psrv = np.nonzero((ys_mat > 0) & (ys_mat < G))
            for c, s in zip(pc.tolist(), psrv.tolist()):
                fin = self._straddle_fin[need[c][0].row][s]
                cnt = len(fin) - _bisect.bisect_right(fin, cuts[c]) + 1
                if cnt > ps[c]:
                    ps[c] = cnt
            contention.EVAL_COUNTS["probes"] += C
            if not cl.is_heterogeneous:
                # Homogeneous clusters: Eq. (8) sees the candidate only
                # through (p, n_srv), and a step's candidates hit a
                # handful of distinct pairs -- one memoised scalar_tau
                # per pair (bit-identical to scalar_tau_many AND to the
                # fused score_probes program: the scalar expression chain
                # is pinned equal to both) replaces the whole batched /
                # dispatched evaluation on every backend.
                memo = self._rho_memo
                if self._rho_memo_jid != job.jid:
                    memo.clear()
                    self._rho_memo_jid = job.jid
                ns_l = ns.tolist()
                ps_l = ps.tolist()
                rhos = []
                for c in range(C):
                    pair = (ps_l[c], ns_l[c])
                    r = memo.get(pair)
                    if r is None:
                        r = memo[pair] = slots_for(
                            job.iters, scalar_tau(cl, job, *pair))
                    rhos.append(r)
            elif self._kern is not None:
                # One fused Eq. (6)-(8) program over the candidate batch
                # (bit-identical to the scalar_tau_many expressions).
                _, rhos = self._kern.score_probes(
                    cl, job, ys_mat, ps.astype(np.float64),
                    use_kernel=self.backend == "kernel")
            else:
                speed, bw_sh, bw_iso = contention._hetero_mins(
                    cl, ys_mat > 0)
                taus = scalar_tau_many(cl, job, ps, ns, speed=speed,
                                       bw_shared=bw_sh, bw_isolated=bw_iso)
                rhos = slots_for_many(job.iters, taus)
        elif self.engine == "batched":
            rhos = self._score_batched(job, need, starts, ys)
        else:                                   # "reference"
            rhos = np.empty(C)
            for c, (w, _, g) in enumerate(need):
                jids, _, fins = self._row_cols(w.row)
                cut = starts[c] + 1e-9
                keep = fins > cut
                overlap = jids[keep]
                Y_snap = self._row_Y(w.row)[keep]
                rhos[c] = predict_exec_time(
                    cl, job, [self.jobs[j] for j in overlap.tolist()],
                    Y_snap, ys[c])
        # One bulk tolist instead of C float() casts (same float64 values).
        rhos_l = rhos if type(rhos) is list else rhos.tolist()
        starts_l = starts.tolist()
        for c, (w, key, g) in enumerate(need):
            w.scored[key] = (rhos_l[c], starts_l[c], ys[c])

    def _score_batched(self, job: Job, need, starts: np.ndarray,
                       ys: list[np.ndarray]) -> np.ndarray:
        """All candidates in one padded-branch-stack ``evaluate_stack``
        pass: candidate c's rows are its row's placed jobs (inactive where
        their window misses the candidate's start) plus the candidate
        itself; per-candidate term rows are gathered by jid.  Padding rows
        stay inactive/zero, which leaves active rows' contention untouched
        (a zero row straddles nothing)."""
        cl = self.cluster
        S = cl.num_servers
        C = len(need)
        counts = [self._count[w.row] for (w, _, _) in need]
        Pmax = max(counts)
        Y = np.zeros((C, Pmax + 1, S), dtype=np.int64)
        active = np.zeros((C, Pmax + 1), dtype=bool)
        Gt = np.zeros((C, Pmax + 1), dtype=np.int64)
        sh = np.zeros((C, Pmax + 1))
        # Padding rows keep compute=1 so their (never-read) tau stays
        # finite; their Y rows are zero, so they perturb nothing active.
        cp = np.ones((C, Pmax + 1))
        wG, wsh, wcp = _job_terms([job])
        for c, (w, _, g) in enumerate(need):
            P = counts[c]
            if P:
                jids, _, fins = self._row_cols(w.row)
                Y[c, :P] = self._row_Y(w.row)
                active[c, :P] = fins > starts[c] + 1e-9
                Gt[c, :P] = self._G_t[jids]
                sh[c, :P] = self._share_t[jids]
                cp[c, :P] = self._compute_t[jids]
            Y[c, P] = ys[c]
            active[c, P] = True
            Gt[c, P] = wG[0]
            sh[c, P] = wsh[0]
            cp[c, P] = wcp[0]
        model = evaluate_stack(cl, Gt, sh, cp, Y, active=active)
        taus = np.asarray([model.tau[c, counts[c]] for c in range(C)])
        return slots_for_many(job.iters, taus)

    # -- the one-job step --------------------------------------------------

    def place(self, job: Job, rho_nom: float, pickers, picker_of) -> None:
        """Advance every live branch by one job.

        ``pickers`` is the tuple of candidate pickers (each carrying the
        ``theta_pool`` contract and a vectorised ``pick_many``);
        ``picker_of`` assigns one to each branch (scalar or ``[branches]``
        array of indices into ``pickers`` -- the kappa axis of SJF-BCO).
        Branches sharing (row, picker) advance in lockstep and split only
        where the scalar walk's decisions diverge; committed branches are
        re-merged onto deduplicated child rows.
        """
        if pickers is not self._checked_pickers:
            for picker in pickers:
                if not getattr(picker, "theta_pool", False) \
                        or getattr(picker, "pick_many", None) is None:
                    raise ValueError(
                        f"picker {getattr(picker, '__name__', picker)!r} "
                        "lacks theta_pool/pick_many; the columnar engine "
                        "needs theta to enter only through the feasibility "
                        "pool and a vectorised pick")
            self._checked_pickers = pickers
            # The fused programs rank FA-FFP/LBSGF in-program; pickers
            # without a jit_pick_id fall back to their pick_many per step.
            ids = [getattr(p, "jit_pick_id", -1) for p in pickers]
            self._pick_ids = np.asarray(ids, dtype=np.int64) \
                if self._kern is not None and min(ids) >= 0 else None
        if not self._n_live:
            return
        live = np.flatnonzero(self.alive)
        u = self.u
        fused = self._pick_ids is not None
        picker_of = np.asarray(picker_of, dtype=np.int64)
        if picker_of.shape != (self.n_branches,):
            picker_of = np.broadcast_to(picker_of, (self.n_branches,))
        # Contiguous (row, picker) work groups, branches theta-ascending
        # (then branch id) within each -- one stable lexsort instead of a
        # python dict walk.
        rows_l = self.row_of[live]
        pids_l = picker_of[live]
        order = np.lexsort((live, self.thetas[live], pids_l, rows_l))
        lb, rb, pb = live[order], rows_l[order], pids_l[order]
        gcuts = np.flatnonzero((rb[1:] != rb[:-1]) | (pb[1:] != pb[:-1])) + 1
        bounds = np.concatenate([[0], gcuts, [len(lb)]])
        work = [_Work(int(rb[s]), int(pb[s]), lb[s:e], rho_nom, {})
                for s, e in zip(bounds[:-1], bounds[1:])]
        commits: list[tuple] = []   # (branches, row, gpus, rho, start, y, gb)
        dead: list[np.ndarray] = []
        first_try = True
        for _ in range(self.TRIES):
            # Pool split: within each work item, group branches by how many
            # GPUs clear the rho_try filter -- equal counts <=> equal pools
            # (threshold sets are nested in theta), hence identical picks.
            # The counts at each item's extreme thetas come from one
            # batched compare over the [work, N] clock block; only items
            # whose extremes disagree (rare) pay the full per-theta split.
            nw = len(work)
            if first_try:
                # Round 0 (the common case): every item sits at rho_nom
                # and its branch run is a contiguous slice of the
                # lexsorted (lb, rb, pb) arrays, so the group stats are
                # direct gathers instead of four python fromiter walks.
                first_try = False
                heads = bounds[:-1]
                rows_w = rb[heads]
                rho_w = np.full(nw, rho_nom)
                th_lo = self.thetas[lb[heads]]
                th_hi = self.thetas[lb[bounds[1:] - 1]]
                pid_w = pb[heads]
            else:
                rows_w = np.fromiter((w.row for w in work), np.int64, nw)
                rho_w = np.fromiter((w.rho_try for w in work),
                                    np.float64, nw)
                th_lo = self.thetas[np.fromiter(
                    (w.branches[0] for w in work), np.int64, nw)]
                th_hi = self.thetas[np.fromiter(
                    (w.branches[-1] for w in work), np.int64, nw)]
                pid_w = np.fromiter((w.pid for w in work), np.int64, nw)
            ord_w = ok_w = None
            # The fused program pays one device dispatch + host rankings
            # for the whole batch; below DISPATCH_MIN_ROWS that fixed cost
            # exceeds the stats it replaces, so short batches take the
            # numpy pickers verbatim (the jit backend is then exactly the
            # numpy backend until batches grow tall enough to win).
            fused_now = fused and (self.backend == "kernel"
                                   or nw >= self._kern.DISPATCH_MIN_ROWS)
            U_w = self.U[rows_w]
            if fused_now:
                # One fused program: pools at both extremes, per-server
                # reductions and both full pick orderings per work item.
                V, c_lo, c_hi, ord_w, ok_w = self._kern.pick_orders(
                    self.cluster, U_w, th_lo, th_hi, rho_w / u,
                    self._pick_ids[pid_w], job,
                    use_kernel=self.backend == "kernel")
            else:
                V = U_w + (rho_w / u)[:, None]
                # Pool counts only matter where an item's extreme thetas
                # differ (equal thetas => equal pools trivially); most
                # items are singletons, so the compares usually vanish.
                multi = th_lo != th_hi
                c_lo = np.zeros(nw, dtype=np.int64)
                c_hi = c_lo
                if multi.any():
                    c_hi = np.zeros(nw, dtype=np.int64)
                    Vm = V[multi]
                    c_lo[multi] = (Vm <= th_lo[multi][:, None]
                                   + 1e-9).sum(axis=1)
                    c_hi[multi] = (Vm <= th_hi[multi][:, None]
                                   + 1e-9).sum(axis=1)
            runs: list[tuple[_Work, np.ndarray, int]] = []
            c_lo_l, c_hi_l = c_lo.tolist(), c_hi.tolist()
            for i, w in enumerate(work):
                if len(w.branches) == 1 or c_lo_l[i] == c_hi_l[i]:
                    runs.append((w, w.branches, i))
                else:
                    counts = np.searchsorted(np.sort(V[i]),
                                             self.thetas[w.branches] + 1e-9,
                                             side="right")
                    cuts = np.flatnonzero(counts[1:] != counts[:-1]) + 1
                    for sub in np.split(w.branches, cuts):
                        runs.append((w, sub, i))
            nr = len(runs)
            # nr == nw <=> no item split, and then run i IS work item i.
            v_idx = (np.arange(nw) if nr == nw
                     else np.fromiter((r[2] for r in runs), np.int64, nr))
            rows_r = rows_w[v_idx]
            picks: list[np.ndarray | None] = [None] * nr
            pending: list[int] = []
            if fused_now:
                # The program ranked each work item's th_lo pool; any run
                # whose pool equals it (all non-split runs, and a split's
                # lowest-theta sub) reads its pick off the precomputed
                # ordering.  Higher split subs (rare) fall back below.
                G = job.num_gpus
                for i, (w, sub, wi) in enumerate(runs):
                    if len(sub) == len(w.branches) or c_lo[wi] == c_hi[wi] \
                            or sub[0] == w.branches[0]:
                        picks[i] = ord_w[wi, :G] if ok_w[wi] else None
                    else:
                        pending.append(i)
            else:
                pending = list(range(nr))
            if pending:
                if len(pending) == nr == nw:
                    # Whole-batch numpy round with no splits (the common
                    # case): run i IS work item i, so the representative
                    # theta per run is exactly th_lo and the [nw, N]
                    # clock gathers U_w/V are reused without copies.
                    th_rep = th_lo
                    U_all = U_w
                    feas_all = V <= th_rep[:, None] + 1e-9
                else:
                    th_rep = self.thetas[np.fromiter(
                        (runs[i][1][0] for i in pending), np.int64,
                        len(pending))]
                    p_idx = v_idx[pending]
                    U_all = self.U[rows_r[pending]]
                    feas_all = V[p_idx] <= th_rep[:, None] + 1e-9
                # Vectorised picks: one pick_many call per distinct picker
                # over the whole [pending, N] batch.
                by_pid: dict[int, list[int]] = {}
                for j, i in enumerate(pending):
                    by_pid.setdefault(runs[i][0].pid, []).append(j)
                for pid, idxs in sorted(by_pid.items()):
                    if len(idxs) == len(pending):  # single-picker fast path
                        U_g, feas = U_all, feas_all
                    else:
                        U_g, feas = U_all[idxs], feas_all[idxs]
                    gp, okv = pickers[pid].pick_many(self.cluster, U_g,
                                                     feas, job)
                    okl = okv.tolist()
                    for j, jj in enumerate(idxs):
                        picks[pending[jj]] = gp[j] if okl[j] else None
            # Batched scoring of every first-seen candidate of this level.
            # One pass over the runs collects the dead (no pick), the
            # survivors (ok_i/ok_g) and the unseen candidates to score;
            # keys_r memoises each run's candidate bytes so the commit
            # loop below reads the memo without re-serialising.
            need: list[tuple[_Work, bytes, np.ndarray]] = []
            keys_r: list[bytes | None] = [None] * nr
            ok_i: list[int] = []
            ok_g: list[np.ndarray] = []
            for i, (w, sub, _) in enumerate(runs):
                g = picks[i]
                if g is None:
                    dead.append(sub)
                    continue
                key = g.tobytes()
                keys_r[i] = key
                ok_i.append(i)
                ok_g.append(g)
                if key not in w.scored:
                    w.scored[key] = None      # claimed; filled by _score
                    need.append((w, key, g))
            if need:
                self._score(job, need)
            # Eq. (16) re-check: each run splits into a committing upper
            # theta range and a retrying lower one.  All runs place the
            # same G-gang, so the refined-rho bounds come from one batched
            # [picked, G] gather instead of a max() per run.
            next_work: list[_Work] = []
            ok_sc = [runs[i][0].scored[keys_r[i]] for i in ok_i]
            if ok_i:
                gmat = np.concatenate(ok_g).reshape(len(ok_g),
                                                    job.num_gpus)
                rhos = np.fromiter((sc[0] for sc in ok_sc), np.float64,
                                   len(ok_sc))
                bnd = (self.U[rows_r[ok_i][:, None], gmat]
                       + (rhos / u)[:, None]).max(axis=1).tolist()
                thetas_f = self._thetas_f
                for j, i in enumerate(ok_i):
                    w, sub, _ = runs[i]
                    rho, start, y = ok_sc[j]
                    if len(sub) == 1:
                        # Singleton run (the common case): one scalar
                        # compare, no boolean mask / fancy indexing.
                        if thetas_f[sub[0]] + 1e-9 >= bnd[j]:
                            commits.append((sub, w.row, ok_g[j], rho,
                                            start, y, keys_r[i]))
                        else:
                            next_work.append(_Work(
                                w.row, w.pid, sub,
                                max(rho, w.rho_try * 1.05), w.scored))
                        continue
                    passes = self.thetas[sub] + 1e-9 >= bnd[j]
                    hi, lo = sub[passes], sub[~passes]
                    if len(hi):
                        commits.append((hi, w.row, ok_g[j], rho, start, y,
                                        keys_r[i]))
                    if len(lo):
                        next_work.append(_Work(w.row, w.pid, lo,
                                               max(rho, w.rho_try * 1.05),
                                               w.scored))
            work = next_work
            if not work:
                break
        for w in work:                        # escalation ladder exhausted
            dead.append(w.branches)
        self._apply(job, commits, dead)

    def _apply(self, job: Job, commits: list[tuple],
               dead: list[np.ndarray]) -> None:
        """Fold a step's outcomes into the row store: kill failed branches,
        dedup commits by (parent row, gpus) -- the re-merge the lineage
        forest cannot do -- clone rows only at true divergences, and apply
        all clock/est updates as one vectorised write per matrix."""
        jid = job.jid
        for bs in dead:
            if len(bs):
                self.alive[bs] = False
                self._n_live -= len(bs)
        # Merge identical decisions: a child state is a pure function of
        # (parent row, committed gpus), so branches picking the same set
        # off the same row land on ONE child row.
        merged: dict[tuple[int, bytes], list] = {}
        for bs, row, g, rho, start, y, gb in commits:
            key = (row, gb)
            ent = merged.get(key)
            if ent is None:
                merged[key] = [bs, row, g, rho, start, y, gb]
            else:
                ent[0] = np.concatenate([ent[0], bs])
        by_parent: dict[int, list] = {}
        for ent in merged.values():        # dicts keep insertion order
            by_parent.setdefault(ent[1], []).append(ent)
        # Assign child rows: the first class reuses the parent in place
        # (every branch leaves it this step), the rest fork copy-on-write.
        child_rows: list[tuple[int, list]] = []
        for parent in sorted(by_parent):
            classes = by_parent[parent]
            for k, ent in enumerate(classes):
                child = parent if k == 0 else self._clone_row(parent)
                child_rows.append((child, ent))
        if child_rows:
            self._chain_cache.clear()
            self._y_cache.clear()
            u = self.u
            rows_arr = np.asarray([c for c, _ in child_rows])
            gmat = np.concatenate(
                [ent[2] for _, ent in child_rows]).reshape(
                    len(child_rows), job.num_gpus)
            rhos = np.asarray([ent[3] for _, ent in child_rows])
            starts = np.asarray([ent[4] for _, ent in child_rows])
            # The columnar Eq. (15) charge: one masked write per matrix.
            # (Index pairs are unique: child rows are distinct and a gang's
            # GPUs are distinct, so the fancy += is the scalar addition.)
            self.U[rows_arr[:, None], gmat] += (rhos / u)[:, None]
            self.R[rows_arr[:, None], gmat] = (starts + rhos)[:, None]
            G = job.num_gpus
            fins = (starts + rhos).tolist()
            for child, ent in child_rows:
                bs, _, g, rho, start, y, gb = ent
                self.row_of[bs] = child
                fin = start + rho
                rec = len(self._log_jid)
                self._log_jid.append(jid)
                self._log_prev.append(self._tail[child])
                self._log_start.append(start)
                self._log_fin.append(fin)
                self._log_g.append(g)
                self._log_y.append(y)
                self._tail[child] = rec
                self._count[child] += 1
                self._state_hash[child] = hash(
                    (self._state_hash[child], jid, gb))
            # Straddled (child, server) pairs in one batched scan (the
            # per-child flatnonzero dominated this loop); argwhere's
            # row-major order reproduces the per-child, server-ascending
            # insort order exactly.
            ymat = np.concatenate(
                [ent[5] for _, ent in child_rows]).reshape(
                    len(child_rows), self.cluster.num_servers)
            sc_ci, sc_s = np.nonzero((ymat > 0) & (ymat < G))
            for ci, s in zip(sc_ci.tolist(), sc_s.tolist()):
                child = child_rows[ci][0]
                sf = self._straddle_fin[child]
                owned = self._fin_owned[child]
                if not owned[s]:                 # copy-on-first-write
                    sf[s] = list(sf[s])
                    owned[s] = True
                _bisect.insort(sf[s], fins[ci])
        # Release rows no branch references any more.
        referenced = set(self.row_of[self.alive].tolist())
        for r in [r for r in self._live_rows if r not in referenced]:
            self._free_row(r)

    # -- results -----------------------------------------------------------

    @property
    def n_rows(self) -> int:
        """Distinct live states (the dedup the lineage forest lacks)."""
        return len(self._live_rows)

    @property
    def n_live(self) -> int:
        """Live branches, tracked O(1) (== ``alive.sum()``)."""
        return self._n_live

    def state_hash(self, b: int) -> int | None:
        """Decision-history fingerprint of branch ``b`` (None if dead)."""
        if not self.alive[b]:
            return None
        return self._state_hash[int(self.row_of[b])]

    def result(self, b: int, theta: float, kappa: int | None,
               policy: str):
        """Freeze branch ``b`` into a ScheduleResult (None if it failed).
        Same construction as :func:`repro.core.api.finalize` on the
        equivalent scalar state."""
        from repro.core.api import ScheduleResult
        if not self.alive[b]:
            return None
        row = int(self.row_of[b])
        est_start = np.full(self.n_jobs, -1.0)
        est_finish = np.full(self.n_jobs, -1.0)
        idx = self._chain(row)
        jids, starts, fins = self._row_cols(row)
        if len(idx):
            est_start[jids] = starts
            est_finish[jids] = fins
        glog = self._log_g
        assignment = [(int(j), glog[i])
                      for j, i in zip(jids.tolist(), idx.tolist())]
        return ScheduleResult(
            assignment=assignment,
            est_start=est_start, est_finish=est_finish,
            est_makespan=float(est_finish.max(initial=0.0)),
            theta=theta, kappa=kappa, policy=policy,
            max_busy_time=float(self.U[row].max(initial=0.0)))
