"""Columnar branch-vectorised placement: the whole sweep x bisect forest
as one array program.

The speculative machinery of :mod:`repro.core.api` (``SharedState`` +
``try_place_group``) advances a *lineage forest* of per-branch
:class:`~repro.core.api.PlacementState` objects: branches fork with
copy-on-write clones at the first divergent placement and never re-merge,
so cross-theta sharing decays to ~5-15% and the scheduler remains a scalar
Python walk per lineage.  :class:`ColumnarPlacement` replaces the forest
with a columnar layout:

  * every (theta, kappa) **branch** maps onto a deduplicated state **row**;
    the row store is a pair of ``[rows, N]`` clock matrices (busy-time U,
    real-time R), ``[rows, |J|]`` est-start/est-finish matrices, and a
    per-row decision log (the committed ``(jid, gpus)`` sequence, whose
    running hash is the row's state fingerprint);
  * each :meth:`place` call advances **every** live branch by one job as
    masked vectorised ops: the Eq. (16) pools (``U + rho/u <= theta``) are
    threshold counts on one sorted vector per row, the FA-FFP/LBSGF/FF/LS
    argmin picks run as one ``picker.pick_many`` call over the whole
    ``[groups, N]`` batch, refined-rho probes are scored for all groups in
    one :func:`~repro.core.contention.scalar_tau_many` /
    :func:`~repro.core.contention.evaluate_stack` pass, and the Eq. (16)
    re-check splits each theta run with a single vectorised comparison;
  * branches whose decisions coincide are **re-merged**: a committed step
    is a pure function of (parent row, chosen GPU set), so children are
    deduplicated by the ``(parent row, gpus)`` key -- exactly the state
    hash the COW forest cannot exploit once lineages have forked.

Decision-for-decision the engine replays :func:`repro.core.api.try_place`
per branch: the same pool thresholds, the same picker tie-breaks (the
``pick_many`` forms are elementwise-identical to the scalar pickers), the
same memoised rho_hat(y^k) scores, the same ``max(rho, rho_try * 1.05)``
escalation ladder, and the same float expressions in the same order -- so
schedules are bit-identical to the scalar oracle (pinned by
``tests/test_columnar_equivalence.py`` and the ``--quick`` bench smokes).
The engine backs ``placement="columnar"`` of the bisection policies; the
scalar walk stays selectable as ``placement="scalar"``.
"""
from __future__ import annotations

import bisect as _bisect

import numpy as np

from repro.core import contention
from repro.core.cluster import Cluster
from repro.core.contention import (_job_terms, evaluate_stack,
                                   predict_exec_time, resolve_engine,
                                   scalar_tau_many, slots_for_many)
from repro.core.jobs import Job

__all__ = ["ColumnarPlacement", "server_sums"]


def server_sums(cluster: Cluster, W: np.ndarray) -> np.ndarray:
    """Per-(row, server) sums of a ``[rows, N]`` per-GPU weight matrix.

    The batched form of ``np.bincount(cluster.gpu_server, weights=w)``:
    one flat bincount over row-major keys accumulates every (row, server)
    bin in GPU-id order -- the same additions in the same order as the
    scalar pickers' per-server bincounts, so the sums are bit-identical
    per row.  Shared by the vectorised ``pick_many`` forms of FA-FFP
    (occupancy scores) and LBSGF (server loads)."""
    R, N = W.shape
    S = cluster.num_servers
    keys = (np.arange(R)[:, None] * S
            + cluster.gpu_server[None, :]).ravel()
    return np.bincount(keys, weights=np.ascontiguousarray(W).ravel(),
                       minlength=R * S).reshape(R, S)


class _Work:
    """One resolution-ladder work item: a run of branches sharing a row, a
    picker, the current escalated rho, and the memoised candidate scores
    (shared down the retry chain, as in ``try_place_group``)."""

    __slots__ = ("row", "pid", "branches", "rho_try", "scored")

    def __init__(self, row: int, pid: int, branches: np.ndarray,
                 rho_try: float, scored: dict):
        self.row = row
        self.pid = pid
        self.branches = branches
        self.rho_try = rho_try
        self.scored = scored


class ColumnarPlacement:
    """Branch-vectorised placement over ``[rows, N]`` clock matrices.

    ``thetas`` fixes the branch axis: branch ``b`` replays the scalar
    placement walk at budget ``thetas[b]`` (callers encode the kappa sweep
    by assigning pickers per branch in :meth:`place`).  ``jobs`` is the
    request's jid-indexed job list (the per-jid Eq. (8) terms and the
    reference-engine snapshots are gathered from it).  ``engine`` selects
    how rho_hat(y^k) probes evaluate, exactly as for
    :class:`~repro.core.api.PlacementState`: ``"incremental"`` suffix
    counts + one ``scalar_tau_many`` per step, ``"batched"`` one padded
    :func:`~repro.core.contention.evaluate_stack` pass over the branch
    stack, ``"reference"`` the per-candidate ``evaluate`` loop.
    """

    #: try_place's escalation-ladder depth (same constant, same semantics).
    TRIES = 4

    def __init__(self, cluster: Cluster, thetas, jobs: list[Job], u: float,
                 engine: str | None = None):
        self.cluster = cluster
        self.engine = resolve_engine(engine)
        self.u = float(u)
        self.jobs = jobs
        self.thetas = np.asarray(thetas, dtype=np.float64)
        B = len(self.thetas)
        if B == 0:
            raise ValueError("columnar placement needs at least one branch")
        self.n_branches = B
        self.n_jobs = len(jobs)
        self.alive = np.ones(B, dtype=bool)
        self.row_of = np.zeros(B, dtype=np.int64)
        # Placement-independent Eq. (8) terms, gathered by jid for the
        # batched-engine branch stacks.
        self._G_t, self._share_t, self._compute_t = _job_terms(jobs)

        N = cluster.num_gpus
        S = cluster.num_servers
        cap = max(1, B)
        self.U = np.zeros((cap, N))          # busy-time clocks (Eq. 15/16)
        self.R = np.zeros((cap, N))          # real-time clocks (gang start)
        self._free = list(range(1, cap))
        self._live_rows: set[int] = {0}
        # Per-row python structures (few rows thanks to dedup; everything
        # hot is in the matrices above).  Committed est_start/est_finish
        # live as per-decision lists parallel to _jid_seq -- O(placed)
        # per row instead of O(|J|), so clones stay cheap at trace scale;
        # result() scatters them back into dense arrays.
        self._assignment: dict[int, list] = {0: []}
        self._jid_seq: dict[int, list[int]] = {0: []}
        self._y_seq: dict[int, list[np.ndarray]] = {0: []}
        self._start_seq: dict[int, list[float]] = {0: []}
        self._fin_seq: dict[int, list[float]] = {0: []}
        # Per-server sorted est_finish of straddling placed jobs, shared
        # copy-on-write between cloned rows (see PlacementState.clone).
        self._straddle_fin: dict[int, list[list[float]]] = \
            {0: [[] for _ in range(S)]}
        self._fin_owned: dict[int, list[bool]] = {0: [True] * S}
        # Running decision-history fingerprint (the dedup "state hash").
        self._state_hash: dict[int, int] = {0: 0}
        # Picker tuple already validated by place() (identity-cached).
        self._checked_pickers: tuple | None = None

    # -- row store ---------------------------------------------------------

    def _alloc_row(self) -> int:
        if not self._free:
            cap = self.U.shape[0]
            grow = np.zeros_like(self.U)
            self.U = np.concatenate([self.U, grow])
            self.R = np.concatenate([self.R, np.zeros_like(grow)])
            self._free.extend(range(cap, 2 * cap))
        r = self._free.pop()
        self._live_rows.add(r)
        return r

    def _free_row(self, r: int) -> None:
        self._live_rows.discard(r)
        self._free.append(r)
        for store in (self._assignment, self._jid_seq, self._y_seq,
                      self._start_seq, self._fin_seq,
                      self._straddle_fin, self._fin_owned, self._state_hash):
            store.pop(r, None)

    def _clone_row(self, parent: int) -> int:
        """Copy-on-write fork of a row (the columnar PlacementState.clone):
        O(N + placed) copies; the sorted-finish lists are shared until a
        commit first writes into one (both sides drop ownership)."""
        r = self._alloc_row()
        self.U[r] = self.U[parent]
        self.R[r] = self.R[parent]
        self._assignment[r] = list(self._assignment[parent])
        self._jid_seq[r] = list(self._jid_seq[parent])
        self._y_seq[r] = list(self._y_seq[parent])
        self._start_seq[r] = list(self._start_seq[parent])
        self._fin_seq[r] = list(self._fin_seq[parent])
        self._straddle_fin[r] = list(self._straddle_fin[parent])
        S = self.cluster.num_servers
        self._fin_owned[r] = [False] * S
        self._fin_owned[parent] = [False] * S
        self._state_hash[r] = self._state_hash[parent]
        return r

    # -- scoring (rho_hat(y^k) probes, batched over candidates) ------------

    def _score(self, job: Job, need: list[tuple["_Work", bytes, np.ndarray]]
               ) -> None:
        """Score every unseen (row, gpus) candidate of this step in one
        engine pass and fill the work items' memo dicts with
        ``(rho, start, y)``.  Values are bit-identical to
        ``PlacementState.refined_rho`` on the equivalent scalar state."""
        cl = self.cluster
        S = cl.num_servers
        C = len(need)
        starts = np.empty(C)
        ys: list[np.ndarray] = []
        for c, (w, _, g) in enumerate(need):
            starts[c] = float(self.R[w.row, g].max()) if len(g) else 0.0
            ys.append(np.bincount(cl.gpu_server[g], minlength=S))
        if self.engine == "incremental":
            ps = np.empty(C, dtype=np.int64)
            ns = np.empty(C, dtype=np.int64)
            G = job.num_gpus
            for c, (w, _, g) in enumerate(need):
                sf = self._straddle_fin[w.row]
                cut = starts[c] + 1e-9
                p = 0
                n_srv = 0
                for s, yv in enumerate(ys[c].tolist()):
                    if yv > 0:
                        n_srv += 1
                        if yv < G:
                            fin = sf[s]
                            p = max(p, len(fin)
                                    - _bisect.bisect_right(fin, cut) + 1)
                ps[c] = p
                ns[c] = n_srv
            contention.EVAL_COUNTS["probes"] += C
            if cl.is_heterogeneous:
                speed, bw_sh, bw_iso = contention._hetero_mins(
                    cl, np.asarray(ys) > 0)
                taus = scalar_tau_many(cl, job, ps, ns, speed=speed,
                                       bw_shared=bw_sh, bw_isolated=bw_iso)
            else:
                taus = scalar_tau_many(cl, job, ps, ns)
            rhos = slots_for_many(job.iters, taus)
        elif self.engine == "batched":
            rhos = self._score_batched(job, need, starts, ys)
        else:                                   # "reference"
            rhos = np.empty(C)
            for c, (w, _, g) in enumerate(need):
                jids = self._jid_seq[w.row]
                fins = self._fin_seq[w.row]
                cut = starts[c] + 1e-9
                overlap = [j for j, f in zip(jids, fins) if f > cut]
                Y_snap = np.asarray(
                    [y for y, f in zip(self._y_seq[w.row], fins)
                     if f > cut], dtype=np.int64
                ).reshape(len(overlap), S)
                rhos[c] = predict_exec_time(
                    cl, job, [self.jobs[j] for j in overlap], Y_snap, ys[c])
        for c, (w, key, g) in enumerate(need):
            w.scored[key] = (float(rhos[c]), float(starts[c]), ys[c])

    def _score_batched(self, job: Job, need, starts: np.ndarray,
                       ys: list[np.ndarray]) -> np.ndarray:
        """All candidates in one padded-branch-stack ``evaluate_stack``
        pass: candidate c's rows are its row's placed jobs (inactive where
        their window misses the candidate's start) plus the candidate
        itself; per-candidate term rows are gathered by jid.  Padding rows
        stay inactive/zero, which leaves active rows' contention untouched
        (a zero row straddles nothing)."""
        cl = self.cluster
        S = cl.num_servers
        C = len(need)
        counts = [len(self._jid_seq[w.row]) for (w, _, _) in need]
        Pmax = max(counts)
        Y = np.zeros((C, Pmax + 1, S), dtype=np.int64)
        active = np.zeros((C, Pmax + 1), dtype=bool)
        Gt = np.zeros((C, Pmax + 1), dtype=np.int64)
        sh = np.zeros((C, Pmax + 1))
        # Padding rows keep compute=1 so their (never-read) tau stays
        # finite; their Y rows are zero, so they perturb nothing active.
        cp = np.ones((C, Pmax + 1))
        wG, wsh, wcp = _job_terms([job])
        for c, (w, _, g) in enumerate(need):
            P = counts[c]
            if P:
                jids = np.asarray(self._jid_seq[w.row], dtype=np.int64)
                Y[c, :P] = np.stack(self._y_seq[w.row])
                active[c, :P] = \
                    np.asarray(self._fin_seq[w.row]) > starts[c] + 1e-9
                Gt[c, :P] = self._G_t[jids]
                sh[c, :P] = self._share_t[jids]
                cp[c, :P] = self._compute_t[jids]
            Y[c, P] = ys[c]
            active[c, P] = True
            Gt[c, P] = wG[0]
            sh[c, P] = wsh[0]
            cp[c, P] = wcp[0]
        model = evaluate_stack(cl, Gt, sh, cp, Y, active=active)
        taus = np.asarray([model.tau[c, counts[c]] for c in range(C)])
        return slots_for_many(job.iters, taus)

    # -- the one-job step --------------------------------------------------

    def place(self, job: Job, rho_nom: float, pickers, picker_of) -> None:
        """Advance every live branch by one job.

        ``pickers`` is the tuple of candidate pickers (each carrying the
        ``theta_pool`` contract and a vectorised ``pick_many``);
        ``picker_of`` assigns one to each branch (scalar or ``[branches]``
        array of indices into ``pickers`` -- the kappa axis of SJF-BCO).
        Branches sharing (row, picker) advance in lockstep and split only
        where the scalar walk's decisions diverge; committed branches are
        re-merged onto deduplicated child rows.
        """
        if pickers is not self._checked_pickers:
            for picker in pickers:
                if not getattr(picker, "theta_pool", False) \
                        or getattr(picker, "pick_many", None) is None:
                    raise ValueError(
                        f"picker {getattr(picker, '__name__', picker)!r} "
                        "lacks theta_pool/pick_many; the columnar engine "
                        "needs theta to enter only through the feasibility "
                        "pool and a vectorised pick")
            self._checked_pickers = pickers
        live = np.flatnonzero(self.alive)
        if not len(live):
            return
        u = self.u
        picker_of = np.broadcast_to(np.asarray(picker_of, dtype=np.int64),
                                    (self.n_branches,))
        # Contiguous (row, picker) work groups, branches theta-ascending
        # (then branch id) within each -- one stable lexsort instead of a
        # python dict walk.
        rows_l = self.row_of[live]
        pids_l = picker_of[live]
        order = np.lexsort((live, self.thetas[live], pids_l, rows_l))
        lb, rb, pb = live[order], rows_l[order], pids_l[order]
        gcuts = np.flatnonzero((rb[1:] != rb[:-1]) | (pb[1:] != pb[:-1])) + 1
        bounds = np.concatenate([[0], gcuts, [len(lb)]])
        work = [_Work(int(rb[s]), int(pb[s]), lb[s:e], rho_nom, {})
                for s, e in zip(bounds[:-1], bounds[1:])]
        commits: list[tuple] = []       # (branches, row, gpus, rho, start, y)
        dead: list[np.ndarray] = []
        for _ in range(self.TRIES):
            # Pool split: within each work item, group branches by how many
            # GPUs clear the rho_try filter -- equal counts <=> equal pools
            # (threshold sets are nested in theta), hence identical picks.
            # The counts at each item's extreme thetas come from one
            # batched compare over the [work, N] clock block; only items
            # whose extremes disagree (rare) pay the full per-theta split.
            nw = len(work)
            rows_w = np.fromiter((w.row for w in work), np.int64, nw)
            rho_w = np.fromiter((w.rho_try for w in work), np.float64, nw)
            V = self.U[rows_w] + (rho_w / u)[:, None]
            th_lo = self.thetas[np.fromiter((w.branches[0] for w in work),
                                            np.int64, nw)]
            th_hi = self.thetas[np.fromiter((w.branches[-1] for w in work),
                                            np.int64, nw)]
            c_lo = (V <= th_lo[:, None] + 1e-9).sum(axis=1)
            c_hi = (V <= th_hi[:, None] + 1e-9).sum(axis=1)
            runs: list[tuple[_Work, np.ndarray, int]] = []
            for i, w in enumerate(work):
                if len(w.branches) == 1 or c_lo[i] == c_hi[i]:
                    runs.append((w, w.branches, i))
                else:
                    counts = np.searchsorted(np.sort(V[i]),
                                             self.thetas[w.branches] + 1e-9,
                                             side="right")
                    cuts = np.flatnonzero(counts[1:] != counts[:-1]) + 1
                    for sub in np.split(w.branches, cuts):
                        runs.append((w, sub, i))
            nr = len(runs)
            v_idx = np.fromiter((r[2] for r in runs), np.int64, nr)
            th_rep = self.thetas[np.fromiter((r[1][0] for r in runs),
                                             np.int64, nr)]
            feas_all = V[v_idx] <= th_rep[:, None] + 1e-9
            rows_r = rows_w[v_idx]
            # Vectorised picks: one pick_many call per distinct picker over
            # the whole [runs, N] batch.
            picks: list[np.ndarray | None] = [None] * nr
            by_pid: dict[int, list[int]] = {}
            for i, (w, _, _) in enumerate(runs):
                by_pid.setdefault(w.pid, []).append(i)
            for pid, idxs in sorted(by_pid.items()):
                if len(idxs) == nr:             # single-picker fast path
                    U_g, feas = self.U[rows_r], feas_all
                else:
                    U_g, feas = self.U[rows_r[idxs]], feas_all[idxs]
                gp, okv = pickers[pid].pick_many(self.cluster, U_g, feas,
                                                 job)
                for j, i in enumerate(idxs):
                    picks[i] = gp[j] if okv[j] else None
            # Batched scoring of every first-seen candidate of this level.
            need: list[tuple[_Work, bytes, np.ndarray]] = []
            for i, (w, _, _) in enumerate(runs):
                g = picks[i]
                if g is None:
                    continue
                key = g.tobytes()
                if key not in w.scored:
                    w.scored[key] = None      # claimed; filled by _score
                    need.append((w, key, g))
            if need:
                self._score(job, need)
            # Eq. (16) re-check: each run splits into a committing upper
            # theta range and a retrying lower one.  All runs place the
            # same G-gang, so the refined-rho bounds come from one batched
            # [picked, G] gather instead of a max() per run.
            next_work: list[_Work] = []
            ok_i: list[int] = []
            ok_g: list[np.ndarray] = []
            ok_sc: list[tuple] = []
            for i, (w, sub, _) in enumerate(runs):
                g = picks[i]
                if g is None:
                    dead.append(sub)
                else:
                    ok_i.append(i)
                    ok_g.append(g)
                    ok_sc.append(w.scored[g.tobytes()])
            if ok_i:
                gmat = np.stack(ok_g)
                rhos = np.fromiter((sc[0] for sc in ok_sc), np.float64,
                                   len(ok_sc))
                bnd = (self.U[rows_r[ok_i][:, None], gmat]
                       + (rhos / u)[:, None]).max(axis=1)
                for j, i in enumerate(ok_i):
                    w, sub, _ = runs[i]
                    rho, start, y = ok_sc[j]
                    passes = self.thetas[sub] + 1e-9 >= bnd[j]
                    hi, lo = sub[passes], sub[~passes]
                    if len(hi):
                        commits.append((hi, w.row, ok_g[j], rho, start, y))
                    if len(lo):
                        next_work.append(_Work(w.row, w.pid, lo,
                                               max(rho, w.rho_try * 1.05),
                                               w.scored))
            work = next_work
            if not work:
                break
        for w in work:                        # escalation ladder exhausted
            dead.append(w.branches)
        self._apply(job, commits, dead)

    def _apply(self, job: Job, commits: list[tuple],
               dead: list[np.ndarray]) -> None:
        """Fold a step's outcomes into the row store: kill failed branches,
        dedup commits by (parent row, gpus) -- the re-merge the lineage
        forest cannot do -- clone rows only at true divergences, and apply
        all clock/est updates as one vectorised write per matrix."""
        jid = job.jid
        for bs in dead:
            if len(bs):
                self.alive[bs] = False
        # Merge identical decisions: a child state is a pure function of
        # (parent row, committed gpus), so branches picking the same set
        # off the same row land on ONE child row.
        merged: dict[tuple[int, bytes], list] = {}
        order: list[tuple[int, bytes]] = []
        for bs, row, g, rho, start, y in commits:
            key = (row, g.tobytes())
            ent = merged.get(key)
            if ent is None:
                merged[key] = [bs, row, g, rho, start, y]
                order.append(key)
            else:
                ent[0] = np.concatenate([ent[0], bs])
        by_parent: dict[int, list] = {}
        for key in order:
            ent = merged[key]
            by_parent.setdefault(ent[1], []).append(ent)
        # Assign child rows: the first class reuses the parent in place
        # (every branch leaves it this step), the rest fork copy-on-write.
        child_rows: list[tuple[int, list]] = []
        for parent in sorted(by_parent):
            classes = by_parent[parent]
            for k, ent in enumerate(classes):
                child = parent if k == 0 else self._clone_row(parent)
                child_rows.append((child, ent))
        if child_rows:
            u = self.u
            rows_arr = np.asarray([c for c, _ in child_rows])
            gmat = np.stack([ent[2] for _, ent in child_rows])
            rhos = np.asarray([ent[3] for _, ent in child_rows])
            starts = np.asarray([ent[4] for _, ent in child_rows])
            # The columnar Eq. (15) charge: one masked write per matrix.
            # (Index pairs are unique: child rows are distinct and a gang's
            # GPUs are distinct, so the fancy += is the scalar addition.)
            self.U[rows_arr[:, None], gmat] += (rhos / u)[:, None]
            self.R[rows_arr[:, None], gmat] = (starts + rhos)[:, None]
            G = job.num_gpus
            for child, ent in child_rows:
                bs, _, g, rho, start, y = ent
                self.row_of[bs] = child
                self._assignment[child].append((jid, g))
                self._jid_seq[child].append(jid)
                self._y_seq[child].append(y)
                fin = start + rho
                self._start_seq[child].append(start)
                self._fin_seq[child].append(fin)
                sf = self._straddle_fin[child]
                owned = self._fin_owned[child]
                for s, yv in enumerate(y.tolist()):
                    if 0 < yv < G:
                        if not owned[s]:         # copy-on-first-write
                            sf[s] = list(sf[s])
                            owned[s] = True
                        _bisect.insort(sf[s], fin)
                self._state_hash[child] = hash(
                    (self._state_hash[child], jid, g.tobytes()))
        # Release rows no branch references any more.
        referenced = set(self.row_of[self.alive].tolist())
        for r in [r for r in self._live_rows if r not in referenced]:
            self._free_row(r)

    # -- results -----------------------------------------------------------

    @property
    def n_rows(self) -> int:
        """Distinct live states (the dedup the lineage forest lacks)."""
        return len(self._live_rows)

    def state_hash(self, b: int) -> int | None:
        """Decision-history fingerprint of branch ``b`` (None if dead)."""
        if not self.alive[b]:
            return None
        return self._state_hash[int(self.row_of[b])]

    def result(self, b: int, theta: float, kappa: int | None,
               policy: str):
        """Freeze branch ``b`` into a ScheduleResult (None if it failed).
        Same construction as :func:`repro.core.api.finalize` on the
        equivalent scalar state."""
        from repro.core.api import ScheduleResult
        if not self.alive[b]:
            return None
        row = int(self.row_of[b])
        est_start = np.full(self.n_jobs, -1.0)
        est_finish = np.full(self.n_jobs, -1.0)
        jids = self._jid_seq[row]
        if jids:
            est_start[jids] = self._start_seq[row]
            est_finish[jids] = self._fin_seq[row]
        return ScheduleResult(
            assignment=list(self._assignment[row]),
            est_start=est_start, est_finish=est_finish,
            est_makespan=float(est_finish.max(initial=0.0)),
            theta=theta, kappa=kappa, policy=policy,
            max_busy_time=float(self.U[row].max(initial=0.0)))
