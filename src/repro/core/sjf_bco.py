"""SJF-BCO: Smallest Job First with Balanced Contention and Overhead.

Implements the paper's Algorithm 1 (bisection on the per-GPU execution-time
budget theta_u, sweep over the small/large-job threshold kappa), Algorithm 2
(FA-FFP, fragment-aware first-fit packing, used when G_j <= kappa) and
Algorithm 3 (LBSGF, least-busy-server-GPU-first, used when G_j > kappa).

Accounting follows §5-3: every GPU g carries an accumulated *busy-time*
clock U_s^g, charged rho_hat_j(y^k) / u per placed job (Eq. 15), and
placement is feasible only while U stays within theta_u (Eq. 16) -- this is
what Lemma 2 certifies.  Alongside U we keep a real-time clock R_g
(estimated gang start = max R over the chosen GPUs) used to *estimate* the
makespan of a candidate (theta_u, kappa) schedule; the actual makespan is
later produced by ``repro.core.simulator`` which re-evaluates contention
slot by slot.

rho_hat_j(y^k) is schedule-dependent, exactly as in the paper's Table 1: we
evaluate Eq. (8) against the snapshot of already-placed, time-overlapping
jobs (the Fig. 3 "search -> evaluate" loop) and multiply by F_j.  A cheap
contention-free *nominal* estimate pre-filters the feasible GPU pool; the
refined estimate is what gets charged to U and re-checked against theta_u.

The paper's "wait for some job to exit and retry" (Alg. 2 line 9, Alg. 3
line 12) concerns run-time availability; in the static busy-time accounting
waiting never reduces U, so an insufficient feasible-GPU set is reported as
infeasible for the current (theta_u, kappa), matching Alg. 1 line 14.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import Cluster
from repro.core.contention import evaluate, tau_bounds
from repro.core.jobs import Job


@dataclasses.dataclass
class Schedule:
    """Result of a scheduling policy, ready for the simulator."""
    assignment: list[tuple[int, np.ndarray]]   # (job idx, gpu ids), placement order
    est_start: np.ndarray
    est_finish: np.ndarray
    est_makespan: float
    theta: float
    kappa: int | None = None
    policy: str = ""
    _max_busy: float = 0.0

    @property
    def max_busy_time(self) -> float:          # = W_max^Alg1 (Lemma 2)
        return self._max_busy


def nominal_rho(cluster: Cluster, job: Job) -> float:
    """Contention-free lower estimate (tau at b_intra, single server)."""
    lo, _ = tau_bounds(cluster, job)
    phi = max(1, int(np.floor(1.0 / lo)))
    return float(int(np.ceil(job.iters / phi)))


def rho_hat(cluster: Cluster, job: Job) -> float:
    """Schedule-independent mid-bracket estimate, used by theory checks."""
    lo, hi = tau_bounds(cluster, job)
    tau = 0.5 * (lo + hi)
    phi = max(1, int(np.floor(1.0 / tau)))
    return float(int(np.ceil(job.iters / phi)))


class _State:
    """Per-attempt scheduler state: busy clocks U, real clocks R, and the
    snapshot of placed jobs used for the rho_hat(y^k) refinement."""

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self.U = np.zeros(cluster.num_gpus)    # busy-time clock (Eq. 15/16)
        self.R = np.zeros(cluster.num_gpus)    # real-time clock (gang start)
        self.assignment: list[tuple[int, np.ndarray]] = []
        self.placed_jobs: list[Job] = []
        self.placed_y: list[np.ndarray] = []   # per-server GPU counts
        self.est_start: dict[int, float] = {}
        self.est_finish: dict[int, float] = {}

    def _y_of(self, gpus: np.ndarray) -> np.ndarray:
        y = np.zeros(self.cluster.num_servers, dtype=np.int64)
        np.add.at(y, self.cluster.gpu_server[gpus], 1)
        return y

    def refined_rho(self, job: Job, gpus: np.ndarray) -> tuple[float, float]:
        """rho_hat_j(y^k): Eq. (8) against placed jobs overlapping the
        estimated gang start.  Returns (rho_hat, est_start)."""
        start = float(self.R[gpus].max()) if len(gpus) else 0.0
        y_j = self._y_of(gpus)
        overlap_jobs, overlap_y = [], []
        for jb, y in zip(self.placed_jobs, self.placed_y):
            if self.est_finish[jb.jid] > start + 1e-9:
                overlap_jobs.append(jb)
                overlap_y.append(y)
        Y = np.vstack(overlap_y + [y_j]) if overlap_y else y_j[None, :]
        model = evaluate(self.cluster, overlap_jobs + [job], Y)
        tau = float(model.tau[-1])
        phi = max(1, int(np.floor(1.0 / tau)))
        return float(int(np.ceil(job.iters / phi))), start

    def commit(self, job: Job, gpus: np.ndarray, rho: float, start: float,
               u: float) -> None:
        self.U[gpus] += rho / u
        self.R[gpus] = start + rho
        self.assignment.append((job.jid, gpus))
        self.placed_jobs.append(job)
        self.placed_y.append(self._y_of(gpus))
        self.est_start[job.jid] = start
        self.est_finish[job.jid] = start + rho


def _try_place(state: _State, job: Job, picker, rho_nom: float, u: float,
               theta: float, tries: int = 4) -> bool:
    """Pick GPUs with the nominal-estimate filter, refine rho_hat(y^k) for
    the chosen set, and re-check the Eq. (16) budget.  If the refined charge
    overflows theta on some GPU, re-filter with the refined estimate (which
    excludes the marginal GPUs) and retry -- mirroring the paper's
    "re-evaluate after the schedule is known" loop of Fig. 3."""
    rho_try = rho_nom
    for _ in range(tries):
        gpus = picker(state, job, rho_try, u, theta)
        if gpus is None:
            return False
        gpus = np.asarray(gpus)
        rho, start = state.refined_rho(job, gpus)
        if np.all(state.U[gpus] + rho / u <= theta + 1e-9):
            state.commit(job, gpus, rho, start, u)
            return True
        rho_try = max(rho, rho_try * 1.05)
    return False


def fa_ffp(state: _State, job: Job, rho_nom: float, u: float, theta: float
           ) -> np.ndarray | None:
    """Algorithm 2: Fragment-Aware First-Fit Packing (small jobs).

    Feasible pool = GPUs whose busy time stays within theta after the job
    (Alg. 2 line 2).  Fragment-awareness (the stated intuition of §5-4):
    prefer to pack the whole job into a single, already-occupied server --
    best-fit on feasible capacity -- so small jobs neither fragment empty
    servers nor straddle links; fall back to globally least-loaded GPUs
    (least-execution-time-first, the property Lemma 4(b) relies on) when no
    single server fits."""
    cl = state.cluster
    feasible = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(feasible) < job.num_gpus:
        return None
    srv_of = cl.gpu_server[feasible]
    best_srv, best_key = -1, None
    for s in range(cl.num_servers):
        cnt = int((srv_of == s).sum())
        if cnt < job.num_gpus:
            continue
        occupied = float(state.U[cl.server_gpu_ids(s)].sum())
        # Best fit: fewest feasible slots left after placing; prefer servers
        # that already carry work (pack, don't open fresh servers).
        key = (cnt - job.num_gpus, -occupied)
        if best_key is None or key < best_key:
            best_srv, best_key = s, key
    if best_srv >= 0:
        pool = feasible[srv_of == best_srv]
        order = pool[np.argsort(state.U[pool], kind="stable")]
        return order[: job.num_gpus]
    order = feasible[np.argsort(state.U[feasible], kind="stable")]
    return order[: job.num_gpus]


def lbsgf(state: _State, job: Job, rho_nom: float, u: float, theta: float
          ) -> np.ndarray | None:
    """Algorithm 3: Least-Busy-Server-GPU-First (large jobs).

    Sort servers by average GPU busy time; take the top-m least-busy servers
    with cumulative capacity >= lambda_j * G_j (line 2); walk those servers
    in least-busy order appending their feasible GPUs sorted by U (lines
    4-5), and take the first G_j (line 7).  Server-major order packs the
    ring into the emptiest few servers — which is what makes a larger
    lambda (a wider server pool) monotonically reduce contention+overhead,
    the Fig. 7 behaviour."""
    cl = state.cluster
    srv_of = cl.gpu_server
    caps = cl.capacities_array
    srv_load = np.zeros(cl.num_servers)
    np.add.at(srv_load, srv_of, state.U)
    srv_order = np.argsort(srv_load / caps, kind="stable")
    need = job.lam * job.num_gpus
    cum = np.cumsum(caps[srv_order])
    m = int(np.searchsorted(cum, need) + 1)
    m = min(m, cl.num_servers)
    selected = srv_order[:m]
    srv_rank = {int(s): r for r, s in enumerate(selected)}

    pool = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    pool = pool[np.isin(srv_of[pool], selected)]
    if len(pool) < job.num_gpus:
        return None
    ranks = np.asarray([srv_rank[int(srv_of[g])] for g in pool])
    order = np.lexsort((state.U[pool], ranks))   # server-major, then least U
    return pool[order][: job.num_gpus]


def _attempt(cluster: Cluster, jobs_sorted: list[Job], rho_noms: dict[int, float],
             u: float, theta: float, kappa: int) -> _State | None:
    """One (theta, kappa) pass of Alg. 1 lines 8-16."""
    state = _State(cluster)
    for job in jobs_sorted:
        picker = fa_ffp if job.num_gpus <= kappa else lbsgf
        if not _try_place(state, job, picker, rho_noms[job.jid], u, theta):
            return None
    return state


def _finalize(state: _State, n_jobs: int, theta: float, kappa: int | None,
              policy: str) -> Schedule:
    est_start = np.full(n_jobs, -1.0)
    est_finish = np.full(n_jobs, -1.0)
    for j, s in state.est_start.items():
        est_start[j] = s
        est_finish[j] = state.est_finish[j]
    return Schedule(assignment=state.assignment, est_start=est_start,
                    est_finish=est_finish,
                    est_makespan=float(est_finish.max(initial=0.0)),
                    theta=theta, kappa=kappa, policy=policy,
                    _max_busy=float(state.U.max(initial=0.0)))


def sjf_bco(cluster: Cluster, jobs: list[Job], horizon: int,
            u: float = 1.5, kappas: list[int] | None = None) -> Schedule:
    """Algorithm 1.  ``horizon`` is T, the bisection upper bound for theta_u."""
    jobs_sorted = sorted(jobs, key=lambda j: (j.num_gpus, j.jid))   # line 3
    rho_noms = {j.jid: nominal_rho(cluster, j) for j in jobs}
    if kappas is None:
        # Only kappa values at distinct job sizes change the FA-FFP/LBSGF
        # split; sweeping them is equivalent to the paper's 1..max_j G_j.
        kappas = sorted({j.num_gpus for j in jobs})
        if 1 not in kappas:
            kappas.insert(0, 1)

    best: Schedule | None = None
    left, right = 1.0, float(horizon)                              # line 4
    while left <= right:                                           # line 5
        theta = 0.5 * (left + right)                               # line 6
        best_theta: Schedule | None = None
        for kappa in kappas:                                       # line 7
            state = _attempt(cluster, jobs_sorted, rho_noms, u, theta, kappa)
            if state is None:                                      # line 14
                continue
            cand = _finalize(state, len(jobs), theta, kappa, "SJF-BCO")
            if best_theta is None or cand.est_makespan < best_theta.est_makespan:
                best_theta = cand                                  # lines 17-18
        if best_theta is not None:                                 # lines 19-21
            if best is None or best_theta.est_makespan <= best.est_makespan:
                best = best_theta
            right = theta - 1.0
        else:
            left = theta + 1.0                                     # line 23
    if best is None:
        raise RuntimeError("SJF-BCO: no feasible schedule within horizon; "
                           "increase T")
    return best
