"""SJF-BCO: Smallest Job First with Balanced Contention and Overhead.

Implements the paper's Algorithm 1 (bisection on the per-GPU execution-time
budget theta_u, sweep over the small/large-job threshold kappa), Algorithm 2
(FA-FFP, fragment-aware first-fit packing, used when G_j <= kappa) and
Algorithm 3 (LBSGF, least-busy-server-GPU-first, used when G_j > kappa).

Accounting follows §5-3 and lives in :mod:`repro.core.api`
(:class:`~repro.core.api.PlacementState`, :func:`~repro.core.api.try_place`,
:func:`~repro.core.api.bisect_theta`): every GPU carries an accumulated
busy-time clock U, charged rho_hat_j(y^k) / u per placed job (Eq. 15), and
placement is feasible only while U stays within theta_u (Eq. 16) -- this is
what Lemma 2 certifies.  The actual makespan is later produced by
``repro.core.simulator`` which re-evaluates contention slot by slot.

The paper's "wait for some job to exit and retry" (Alg. 2 line 9, Alg. 3
line 12) concerns run-time availability; in the static busy-time accounting
waiting never reduces U, so an insufficient feasible-GPU set is reported as
infeasible for the current (theta_u, kappa), matching Alg. 1 line 14.

With ``request.arrivals`` set, the policy runs the online epoch loop
(:func:`~repro.core.api.schedule_arrivals`): at each arrival the job is
placed against the live busy-time clocks with the finish-minimising
pack-or-spread choice between FA-FFP and LBSGF -- under open-ended
arrivals there is no theta bisection to spread load, so queueing delay
itself is the penalty that balances the two subroutines.
"""
from __future__ import annotations

import numpy as np

from repro.core.api import (Chooser, PlacementState, ScheduleRequest,
                            ScheduleResult, SharedState, bisect_theta,
                            finalize, nominal_rho, pick_best_finish,
                            register_chooser, register_policy,
                            resolve_columnar_backend, resolve_placement,
                            rho_hat, schedule_arrivals, try_place,
                            try_place_group)
from repro.core.cluster import Cluster
from repro.core.columnar import ColumnarPlacement, _flat_ids, server_sums
from repro.core.jobs import Job

__all__ = ["fa_ffp", "lbsgf", "nominal_rho", "rho_hat", "sjf_bco_policy"]


def fa_ffp(state: PlacementState, job: Job, rho_nom: float, u: float,
           theta: float) -> np.ndarray | None:
    """Algorithm 2: Fragment-Aware First-Fit Packing (small jobs).

    Feasible pool = GPUs whose busy time stays within theta after the job
    (Alg. 2 line 2).  Fragment-awareness (the stated intuition of §5-4):
    prefer to pack the whole job into a single, already-occupied server --
    best-fit on feasible capacity -- so small jobs neither fragment empty
    servers nor straddle links; fall back to globally least-loaded GPUs
    (least-execution-time-first, the property Lemma 4(b) relies on) when no
    single server fits."""
    cl = state.cluster
    feasible = (state.U + rho_nom / u <= theta + 1e-9).nonzero()[0]
    if len(feasible) < job.num_gpus:
        return None
    srv_of = cl.gpu_server[feasible]
    # All candidate servers scored in one vectorised pass: feasible-GPU
    # count and total occupancy per server, then best fit = fewest feasible
    # slots left after placing, preferring servers that already carry work
    # (pack, don't open fresh servers), lowest server id on ties.
    cnt = np.bincount(srv_of, minlength=cl.num_servers)
    fits = (cnt >= job.num_gpus).nonzero()[0]
    if len(fits):
        # bincount-with-weights sums U in GPU-id order, exactly like the
        # np.add.at it replaces (same additions, same order), ~10x faster.
        occupied = np.bincount(cl.gpu_server, weights=state.U,
                               minlength=cl.num_servers)
        order = np.lexsort((fits, -occupied[fits], cnt[fits] - job.num_gpus))
        best_srv = int(fits[order[0]])
        pool = feasible[srv_of == best_srv]
        order = pool[np.argsort(state.U[pool], kind="stable")]
        return order[: job.num_gpus]
    order = feasible[np.argsort(state.U[feasible], kind="stable")]
    return order[: job.num_gpus]


def lbsgf(state: PlacementState, job: Job, rho_nom: float, u: float,
          theta: float) -> np.ndarray | None:
    """Algorithm 3: Least-Busy-Server-GPU-First (large jobs).

    Sort servers by average GPU busy time; take the top-m least-busy servers
    with cumulative capacity >= lambda_j * G_j (line 2); walk those servers
    in least-busy order appending their feasible GPUs sorted by U (lines
    4-5), and take the first G_j (line 7).  Server-major order packs the
    ring into the emptiest few servers — which is what makes a larger
    lambda (a wider server pool) monotonically reduce contention+overhead,
    the Fig. 7 behaviour."""
    cl = state.cluster
    srv_of = cl.gpu_server
    caps = cl.capacities_array
    srv_load = np.bincount(srv_of, weights=state.U,
                           minlength=cl.num_servers)
    srv_order = np.argsort(srv_load / caps, kind="stable")
    need = job.lam * job.num_gpus
    cum = np.cumsum(caps[srv_order])
    m = int(np.searchsorted(cum, need) + 1)
    m = min(m, cl.num_servers)
    selected = srv_order[:m]
    srv_rank = np.full(cl.num_servers, -1, dtype=np.int64)
    srv_rank[selected] = np.arange(m)

    pool = (state.U + rho_nom / u <= theta + 1e-9).nonzero()[0]
    pool = pool[srv_rank[srv_of[pool]] >= 0]
    if len(pool) < job.num_gpus:
        return None
    ranks = srv_rank[srv_of[pool]]
    order = np.lexsort((state.U[pool], ranks))   # server-major, then least U
    return pool[order][: job.num_gpus]


def _fa_ffp_many(cluster: Cluster, U: np.ndarray, feasible: np.ndarray,
                 job: Job) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised FA-FFP over a batch of branch rows.

    ``U`` [rows, N] holds each branch row's busy-time clocks and
    ``feasible`` [rows, N] its Eq. (16) pool; returns ``(gpus, ok)`` with
    ``gpus`` [rows, G_j] and ``ok`` [rows] (False where the pool is too
    small -- :func:`fa_ffp` returns None there).  Every row reproduces the
    scalar pick exactly: the per-server counts/occupancies come from the
    same GPU-id-order bincounts (:func:`~repro.core.columnar.server_sums`),
    the best-fit server from one flat lexsort whose within-row keys match
    the scalar lexsort (ties broken identically by lexsort stability), and
    the within-server / fallback orders from stable argsorts over masked
    keys, which order ties by GPU id exactly like the scalar pool sorts."""
    R, N = U.shape
    S = cluster.num_servers
    Gj = job.num_gpus
    ok = feasible.sum(axis=1) >= Gj
    # One flat bincount covers both per-server reductions (pool counts and
    # occupancy): rows 0..R-1 count the feasible pool, rows R..2R-1 sum the
    # clocks.  Bins are disjoint per row, so each row's additions keep
    # their GPU-id order (concatenate upcasts bool -> 0.0/1.0 exactly like
    # the astype it replaces).
    both = server_sums(cluster, np.concatenate([feasible, U]))
    cnt = both[:R].astype(np.int64)
    occupied = both[R:]
    fits = cnt >= Gj
    has_fit = fits.any(axis=1)
    any_fit = bool(has_fit.any())
    packed = None
    if any_fit:
        # Best server per row by (fewest feasible slots left, most
        # occupied, lowest id): one flat lexsort with the row as the
        # primary key, so row r's candidates occupy positions
        # r*S..(r+1)*S-1 of the order.
        r_flat = _flat_ids("rep", R, S)
        s_flat = _flat_ids("tile", R, S)
        # k_fit ranges over [0, N+1], so folding it into the row key
        # (row * (N+2) + k_fit) preserves the (row, k_fit) lexicographic
        # order exactly while dropping one full sort pass.
        k_fit = (r_flat * (N + 2)
                 + np.where(fits, cnt - Gj, N + 1).ravel())
        k_occ = np.where(fits, -occupied, np.inf).ravel()
        order = np.lexsort((s_flat, k_occ, k_fit))
        best_srv = s_flat[order[::S]]
        in_best = feasible \
            & (cluster.gpu_server[None, :] == best_srv[:, None])
        packed = np.argsort(np.where(in_best, U, np.inf), axis=1,
                            kind="stable")[:, :Gj]
        if has_fit.all():
            return packed, ok
    spread = np.argsort(np.where(feasible, U, np.inf), axis=1,
                        kind="stable")[:, :Gj]
    if not any_fit:
        return spread, ok
    return np.where(has_fit[:, None], packed, spread), ok


def _lbsgf_many(cluster: Cluster, U: np.ndarray, feasible: np.ndarray,
                job: Job) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised LBSGF over a batch of branch rows.

    Same contract as :func:`_fa_ffp_many`.  Per row: server loads from the
    GPU-id-order bincount, the least-busy server order from a stable
    argsort of load/capacity (ties by server id, as in the scalar
    argsort), the lambda_j-sized top-m pool from the same cumulative
    -capacity threshold count, and the final server-major/least-U GPU
    order from one flat lexsort whose within-row keys equal the scalar
    ``np.lexsort((U[pool], ranks))`` -- so every row's pick is
    bit-identical to :func:`lbsgf`."""
    R, N = U.shape
    S = cluster.num_servers
    Gj = job.num_gpus
    caps = cluster.capacities_array
    srv_load = server_sums(cluster, U)
    srv_order = np.argsort(srv_load / caps[None, :], axis=1, kind="stable")
    need = job.lam * Gj
    cum = np.cumsum(caps[srv_order], axis=1)
    m = np.minimum((cum < need).sum(axis=1) + 1, S)
    pos = np.arange(S)[None, :]
    rank_vals = np.where(pos < m[:, None], pos, -1)
    srv_rank = np.empty((R, S), dtype=np.int64)
    # Scatter along axis 1 directly (put_along_axis minus its per-call
    # index-grid construction): row r gets rank_vals[r] at srv_order[r].
    rows_col = np.arange(R)[:, None]
    srv_rank[rows_col, srv_order] = rank_vals
    ranks = srv_rank[rows_col, cluster.gpu_server[None, :]]
    pool = feasible & (ranks >= 0)
    ok = pool.sum(axis=1) >= Gj
    # k_rank ranges over [0, S+1]; folded into the row key it preserves
    # the (row, rank) lexicographic order exactly (one sort pass fewer).
    k_rank = (_flat_ids("rep", R, N) * (S + 2)
              + np.where(pool, ranks, S + 1).ravel())
    k_U = np.where(pool, U, np.inf).ravel()
    order = np.lexsort((k_U, k_rank))
    gpus = order.reshape(R, N)[:, :Gj] - (np.arange(R) * N)[:, None]
    return gpus, ok


# theta enters both pickers only through the U + rho/u <= theta + 1e-9
# feasibility pool, which is what lets the speculative bisection advance a
# whole group of thetas in lockstep (see api.try_place_group) and the
# columnar engine batch whole branch stacks per pick (pick_many).
fa_ffp.theta_pool = True
lbsgf.theta_pool = True
fa_ffp.pick_many = _fa_ffp_many
lbsgf.pick_many = _lbsgf_many
# Stable ids under which repro.kernels.placement's fused jit program ranks
# these pickers in-program (0 = FA-FFP, 1 = LBSGF); pickers without an id
# make the columnar engine fall back to per-step pick_many calls.
fa_ffp.jit_pick_id = 0
lbsgf.jit_pick_id = 1


# The adaptive pack-or-spread choice IS SJF-BCO's online rule (extensions'
# sjf-bco-adaptive shares it), so the chooser registers both names.
@register_chooser("sjf-bco", "sjf-bco-adaptive")
def sjf_bco_chooser(cluster: Cluster, u: float, params: dict) -> Chooser:
    """Online SJF-BCO: the finish-minimising FA-FFP/LBSGF choice of the
    epoch loop, bound to one (cluster, u) context."""
    rho_noms: dict[int, float] = {}

    def choose(state: PlacementState, job: Job, theta: float) -> bool:
        if job.jid not in rho_noms:
            rho_noms[job.jid] = nominal_rho(cluster, job)
        return pick_best_finish(state, job, [fa_ffp, lbsgf],
                                rho_noms[job.jid], u, theta)

    return choose


def _attempt(cluster: Cluster, jobs_sorted: list[Job],
             rho_noms: dict[int, float], u: float, theta: float,
             kappa: int, engine: str | None = None,
             hints: dict[int, np.ndarray] | None = None
             ) -> PlacementState | None:
    """One (theta, kappa) pass of Alg. 1 lines 8-16."""
    state = PlacementState(cluster, engine=engine)
    for job in jobs_sorted:
        picker = fa_ffp if job.num_gpus <= kappa else lbsgf
        hint = hints.get(job.jid) if hints else None
        if not try_place(state, job, picker, rho_noms[job.jid], u, theta,
                         hint=hint):
            return None
    return state


def _sweep_batched(cluster: Cluster, jobs_sorted: list[Job],
                   rho_noms: dict[int, float], u: float, theta: float,
                   kappas: list[int], engine: str | None,
                   hints: dict[int, np.ndarray] | None
                   ) -> dict[int, ScheduleResult | None]:
    """Every kappa branch of one theta, sharing placed prefixes.

    In sorted-job order the branch for kappa places jobs with G_j <= kappa
    via FA-FFP and the rest via LBSGF, so for ascending kappas the FA-FFP
    prefix of one branch is a prefix of the next branch's: each prefix
    segment is placed ONCE into a shared :class:`PlacementState` and every
    branch forks off it (:meth:`PlacementState.clone`) for its LBSGF
    suffix.  Placement is deterministic given the state, so each branch's
    schedule -- and a prefix placement failure, which dooms every kappa at
    or above the failing job's size -- is bit-identical to running
    :func:`_attempt` per kappa from scratch."""
    n = len(jobs_sorted)
    shared = PlacementState(cluster, engine=engine)
    results: dict[int, ScheduleResult | None] = {}
    idx = 0                       # next job to absorb into the shared prefix
    prefix_ok = True
    for kappa in sorted(set(kappas)):
        while prefix_ok and idx < n and jobs_sorted[idx].num_gpus <= kappa:
            job = jobs_sorted[idx]
            hint = hints.get(job.jid) if hints else None
            if not try_place(shared, job, fa_ffp, rho_noms[job.jid], u,
                             theta, hint=hint):
                prefix_ok = False                              # line 14
                break
            idx += 1
        if not prefix_ok:
            results[kappa] = None
            continue
        # All jobs placed already: later branches add nothing, so the
        # shared state needs no fork (it is never committed to again).
        state = shared.clone() if idx < n else shared
        ok = True
        for job in jobs_sorted[idx:]:
            hint = hints.get(job.jid) if hints else None
            if not try_place(state, job, lbsgf, rho_noms[job.jid], u, theta,
                             hint=hint):
                ok = False                                     # line 14
                break
        results[kappa] = finalize(state, n, theta, kappa, "SJF-BCO") \
            if ok else None
    return results


def _sweep_speculative(cluster: Cluster, jobs_sorted: list[Job],
                       rho_noms: dict[int, float], u: float,
                       thetas: list[float], kappas: list[int],
                       engine: str | None
                       ) -> dict[float, dict[int, ScheduleResult | None]]:
    """Every (theta, kappa) attempt of one speculative bisection round.

    Extends :func:`_sweep_batched`'s shared-prefix idea to the theta axis:
    all thetas of a probe ladder start from ONE shared
    :class:`PlacementState` and advance in lockstep
    (:func:`~repro.core.api.try_place_group`), splitting -- with
    copy-on-write clones -- only where the theta budgets actually change
    a placement decision.  Within each theta group the kappa branches
    fork off shared FA-FFP prefixes exactly as in the batched sweep.
    Decision-for-decision identical to running :func:`_sweep_batched`
    per theta, which is itself bit-identical to :func:`_attempt`."""
    n = len(jobs_sorted)
    thetas_arr = np.asarray(sorted(thetas), dtype=np.float64)
    results: dict[float, dict[int, ScheduleResult | None]] = \
        {float(th): {} for th in thetas_arr}
    # Live prefix groups (thetas, state holder, next job to absorb) plus
    # the theta ranges whose shared prefix failed -- a prefix failure at
    # one kappa dooms every kappa at or above it (Alg. 1 line 14), so
    # doomed ranges stay doomed for the rest of the sweep.
    groups = [(thetas_arr, SharedState(PlacementState(cluster,
                                                      engine=engine)), 0)]
    doomed: list[np.ndarray] = []
    for kappa in sorted(set(kappas)):
        work, groups = groups, []
        while work:
            th_g, holder, idx = work.pop()
            if idx < n and jobs_sorted[idx].num_gpus <= kappa:
                job = jobs_sorted[idx]
                for sub, sh, ok in try_place_group(
                        th_g, holder, job, fa_ffp, rho_noms[job.jid], u):
                    if ok:
                        work.append((sub, sh, idx + 1))
                    else:
                        doomed.append(sub)
            else:
                groups.append((th_g, holder, idx))
        for sub in doomed:
            for th in sub:
                results[float(th)][kappa] = None
        for th_g, holder, idx in groups:
            if idx == n:
                # All jobs live in the prefix: nothing to fork (the state
                # is never committed to again), as in the batched sweep.
                for th in th_g:
                    results[float(th)][kappa] = \
                        finalize(holder.state, n, float(th), kappa, "SJF-BCO")
                continue
            holder.split(2)          # one ref stays with the prefix
            swork = [(th_g, holder, idx)]
            while swork:
                th_s, sh, j = swork.pop()
                if j == n:
                    for th in th_s:
                        results[float(th)][kappa] = \
                            finalize(sh.state, n, float(th), kappa, "SJF-BCO")
                    sh.release()
                    continue
                job = jobs_sorted[j]
                for sub, sh2, ok in try_place_group(
                        th_s, sh, job, lbsgf, rho_noms[job.jid], u):
                    if ok:
                        swork.append((sub, sh2, j + 1))
                    else:
                        for th in sub:
                            results[float(th)][kappa] = None
    return results


def _sweep_columnar(cluster: Cluster, jobs: list[Job],
                    jobs_sorted: list[Job], rho_noms: dict[int, float],
                    u: float, thetas: list[float], kappas: list[int],
                    engine: str | None, backend: str = "numpy"
                    ) -> dict[float, dict[int, ScheduleResult | None]]:
    """Every (theta, kappa) attempt as ONE columnar array program.

    Each (theta, kappa) pair is a branch of a single
    :class:`~repro.core.columnar.ColumnarPlacement`; one :meth:`place`
    call per sorted job advances the whole forest -- the kappa axis enters
    purely as the per-branch FA-FFP/LBSGF picker assignment (G_j <= kappa
    packs, else spreads), the theta axis purely through the Eq. (16)
    pools.  Branches whose decisions coincide share one state row (and
    re-merge when they re-coincide), which subsumes both the batched
    sweep's shared FA-FFP prefixes and the speculative bisection's
    copy-on-write lineages.  Decision-for-decision identical to
    :func:`_attempt` per pair, hence bit-identical schedules."""
    kap = sorted(set(kappas))
    pairs = [(float(th), k) for th in sorted(thetas) for k in kap]
    col = ColumnarPlacement(cluster, [th for th, _ in pairs], jobs, u,
                            engine=engine, backend=backend)
    kappa_arr = np.asarray([k for _, k in pairs], dtype=np.int64)
    # Jobs repeat few distinct sizes, and the picker split depends only on
    # G_j -- one assignment array per size instead of one per job.
    picker_by_G: dict[int, np.ndarray] = {}
    for job in jobs_sorted:
        picker_of = picker_by_G.get(job.num_gpus)
        if picker_of is None:
            picker_of = (job.num_gpus > kappa_arr).astype(np.int64)
            picker_by_G[job.num_gpus] = picker_of
        col.place(job, rho_noms[job.jid], (fa_ffp, lbsgf), picker_of)
        if not col.n_live:
            break                                              # line 14
    results: dict[float, dict[int, ScheduleResult | None]] = \
        {float(th): {} for th in thetas}
    for b, (th, k) in enumerate(pairs):
        results[th][k] = col.result(b, th, k, "SJF-BCO")
    return results


@register_policy("sjf-bco")
def sjf_bco_policy(request: ScheduleRequest) -> ScheduleResult:
    """Algorithm 1 (batch) / finish-minimising epoch scheduler (online).

    ``request.params``:
      * ``kappas`` -- candidate small/large thresholds to sweep (batch
        only); defaults to the distinct job sizes, which is equivalent to
        the paper's 1..max_j G_j sweep.
      * ``engine`` -- contention-model engine (see
        :class:`~repro.core.api.PlacementState`).
      * ``sweep`` -- ``"batched"`` (default) runs all kappa branches of a
        theta off shared placed prefixes (jobs below a branch's kappa
        place identically in every branch at or above it, so each FA-FFP
        prefix segment is placed once); ``"sequential"`` is the reference
        one-kappa-at-a-time loop.  Both produce bit-identical schedules
        (pinned by tests and the CI bench smoke).
      * ``bisect`` -- ``"speculative"`` (default) scores the whole probe
        ladder of each bisection round (:func:`~repro.core.api.probe_thetas`)
        in one :func:`_sweep_speculative` pass and commits several theta
        decisions at once; ``"sequential"`` is the one-theta-at-a-time
        Alg. 1 oracle.  Bit-identical final (theta, kappa, placements);
        pinned by ``tests/test_bisect_equivalence.py`` and the CI bench
        smoke.  Speculation needs the batched sweep's shared-prefix
        structure and a cold start, so ``sweep="sequential"`` or
        ``warm_start=True`` fall back to the sequential bisection.
      * ``bisect_levels`` -- how many bisection decisions each
        speculative round precomputes (the probe ladder is the
        descending assume-feasible chain, at most one probe per level).
        Default 4 for the scalar walk, 8 for the columnar engine (an
        extra probe theta there is one more branch row of the same
        array ops).
      * ``bisect_prune`` -- whether the ladder drops tail probes below
        the bracket's likely-infeasible cutoff (default: pruned for the
        scalar walk, unpruned for columnar).  Never changes results,
        only which probes are precomputed.
      * ``warm_start`` -- seed each theta's attempts with the placements
        committed at the previous feasible theta (off by default; changes
        the search trajectory, not the accounting).
      * ``placement`` -- ``"scalar"`` is the per-branch
        :class:`~repro.core.api.PlacementState` walk, the bit-identity
        oracle and the fastest CPU path at small |J| (its
        copy-on-write lineages already share placement work between
        branches); ``"columnar"`` advances the whole (theta, kappa)
        forest of each attempt/round as one
        :class:`~repro.core.columnar.ColumnarPlacement` array program
        with deduplicated branch rows -- identical decisions held in
        strictly-array state (the trace-scale fast path / accelerator
        substrate).  Unset, the default is size-aware: columnar from
        ``api.COLUMNAR_DEFAULT_MIN_JOBS`` jobs -- but that constant is
        ``None`` while the bench records no scalar-vs-columnar
        crossover (the scalar walk wins at every measured size on this
        CPU host), so the unset default is scalar throughout and
        columnar stays an explicit opt-in.  Columnar needs the cold-start
        batched sweep (hints change decisions), so
        ``sweep="sequential"`` or ``warm_start=True`` fall back to the
        scalar walk.
      * ``columnar_backend`` -- where the columnar step's array math
        runs: ``"auto"`` (default; the fused jit programs when jax is
        in float64, else eager NumPy), ``"jit"``, ``"kernel"`` (Pallas
        row kernels, interpret mode on CPU) or ``"numpy"`` -- all
        bit-identical under x64 (see
        :func:`~repro.core.api.resolve_columnar_backend`).
    """
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    placement = resolve_placement(
        request.params, len(request.jobs) if request.is_batch else None)
    sweep = request.params.get("sweep", "batched")
    if sweep not in ("batched", "sequential"):
        raise ValueError(
            f"unknown sweep mode {sweep!r}; choose 'batched' or 'sequential'")
    bisect_mode = request.params.get("bisect", "speculative")
    if bisect_mode not in ("speculative", "sequential"):
        raise ValueError(f"unknown bisect mode {bisect_mode!r}; "
                         "choose 'speculative' or 'sequential'")
    if not request.is_batch:
        # The one online code path: the same chooser factory that
        # repro.service pulls via get_chooser("sjf-bco").
        return schedule_arrivals(
            request, sjf_bco_chooser(cluster, u, request.params), "SJF-BCO")

    jobs = request.jobs
    jobs_sorted = sorted(jobs, key=lambda j: (j.num_gpus, j.jid))   # line 3
    rho_noms = {j.jid: nominal_rho(cluster, j) for j in jobs}
    kappas = request.params.get("kappas")
    if kappas is None:
        # Only kappa values at distinct job sizes change the FA-FFP/LBSGF
        # split; sweeping them is equivalent to the paper's 1..max_j G_j.
        kappas = sorted({j.num_gpus for j in jobs})
        if 1 not in kappas:
            kappas.insert(0, 1)

    warm = bool(request.params.get("warm_start"))
    use_columnar = placement == "columnar" and sweep == "batched" and not warm
    backend = resolve_columnar_backend(request.params) if use_columnar \
        else "numpy"

    def attempt(theta: float,
                prev: ScheduleResult | None = None) -> ScheduleResult | None:
        hints = dict(prev.assignment) if prev is not None else None
        if use_columnar:
            sweep_results = _sweep_columnar(cluster, jobs, jobs_sorted,
                                            rho_noms, u, [theta], kappas,
                                            engine, backend)[float(theta)]
        elif sweep == "batched":
            sweep_results = _sweep_batched(cluster, jobs_sorted, rho_noms,
                                           u, theta, kappas, engine, hints)
        best_theta: ScheduleResult | None = None
        for kappa in kappas:                                       # line 7
            if use_columnar or sweep == "batched":
                cand = sweep_results[kappa]
            else:
                state = _attempt(cluster, jobs_sorted, rho_noms, u, theta,
                                 kappa, engine=engine, hints=hints)
                cand = finalize(state, len(jobs), theta, kappa, "SJF-BCO") \
                    if state is not None else None                 # line 14
            if cand is None:
                continue
            if best_theta is None or cand.est_makespan < best_theta.est_makespan:
                best_theta = cand                                  # lines 17-18
        return best_theta

    attempt_many = None
    if bisect_mode == "speculative" and sweep == "batched" and not warm:
        def attempt_many(thetas: list[float]
                         ) -> dict[float, ScheduleResult | None]:
            if use_columnar:
                sweep_results = _sweep_columnar(cluster, jobs, jobs_sorted,
                                                rho_noms, u, thetas, kappas,
                                                engine, backend)
            else:
                sweep_results = _sweep_speculative(cluster, jobs_sorted,
                                                   rho_noms, u, thetas,
                                                   kappas, engine)
            out: dict[float, ScheduleResult | None] = {}
            for th in thetas:
                best_theta: ScheduleResult | None = None
                for kappa in kappas:                               # line 7
                    cand = sweep_results[th][kappa]
                    if cand is None:
                        continue
                    if best_theta is None \
                            or cand.est_makespan < best_theta.est_makespan:
                        best_theta = cand                          # lines 17-18
                out[th] = best_theta
            return out

    # The columnar program prices an extra probe theta at one more branch
    # row of the same array ops, so it keeps the whole ladder (no bracket
    # pruning) and speculates deeper by default; the scalar walk pays one
    # placement lineage per probe and keeps the conservative ladder.
    default_levels = 8 if use_columnar else 4
    return bisect_theta(attempt, request.horizon, "SJF-BCO",
                        warm_start=warm, attempt_many=attempt_many,
                        levels=int(request.params.get("bisect_levels",
                                                      default_levels)),
                        floor=max(rho_noms.values()) / u,
                        prune=bool(request.params.get("bisect_prune",
                                                      not use_columnar)))
