"""SJF-BCO: Smallest Job First with Balanced Contention and Overhead.

Implements the paper's Algorithm 1 (bisection on the per-GPU execution-time
budget theta_u, sweep over the small/large-job threshold kappa), Algorithm 2
(FA-FFP, fragment-aware first-fit packing, used when G_j <= kappa) and
Algorithm 3 (LBSGF, least-busy-server-GPU-first, used when G_j > kappa).

Accounting follows §5-3 and lives in :mod:`repro.core.api`
(:class:`~repro.core.api.PlacementState`, :func:`~repro.core.api.try_place`,
:func:`~repro.core.api.bisect_theta`): every GPU carries an accumulated
busy-time clock U, charged rho_hat_j(y^k) / u per placed job (Eq. 15), and
placement is feasible only while U stays within theta_u (Eq. 16) -- this is
what Lemma 2 certifies.  The actual makespan is later produced by
``repro.core.simulator`` which re-evaluates contention slot by slot.

The paper's "wait for some job to exit and retry" (Alg. 2 line 9, Alg. 3
line 12) concerns run-time availability; in the static busy-time accounting
waiting never reduces U, so an insufficient feasible-GPU set is reported as
infeasible for the current (theta_u, kappa), matching Alg. 1 line 14.

With ``request.arrivals`` set, the policy runs the online epoch loop
(:func:`~repro.core.api.schedule_arrivals`): at each arrival the job is
placed against the live busy-time clocks with the finish-minimising
pack-or-spread choice between FA-FFP and LBSGF -- under open-ended
arrivals there is no theta bisection to spread load, so queueing delay
itself is the penalty that balances the two subroutines.
"""
from __future__ import annotations

import numpy as np

from repro.core.api import (PlacementState, ScheduleRequest, ScheduleResult,
                            bisect_theta, finalize, nominal_rho,
                            pick_best_finish, register_policy, rho_hat,
                            schedule_arrivals, try_place)
from repro.core.cluster import Cluster
from repro.core.jobs import Job

__all__ = ["fa_ffp", "lbsgf", "nominal_rho", "rho_hat", "sjf_bco_policy"]


def fa_ffp(state: PlacementState, job: Job, rho_nom: float, u: float,
           theta: float) -> np.ndarray | None:
    """Algorithm 2: Fragment-Aware First-Fit Packing (small jobs).

    Feasible pool = GPUs whose busy time stays within theta after the job
    (Alg. 2 line 2).  Fragment-awareness (the stated intuition of §5-4):
    prefer to pack the whole job into a single, already-occupied server --
    best-fit on feasible capacity -- so small jobs neither fragment empty
    servers nor straddle links; fall back to globally least-loaded GPUs
    (least-execution-time-first, the property Lemma 4(b) relies on) when no
    single server fits."""
    cl = state.cluster
    feasible = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(feasible) < job.num_gpus:
        return None
    srv_of = cl.gpu_server[feasible]
    # All candidate servers scored in one vectorised pass: feasible-GPU
    # count and total occupancy per server, then best fit = fewest feasible
    # slots left after placing, preferring servers that already carry work
    # (pack, don't open fresh servers), lowest server id on ties.
    cnt = np.bincount(srv_of, minlength=cl.num_servers)
    occupied = np.zeros(cl.num_servers)
    np.add.at(occupied, cl.gpu_server, state.U)
    fits = np.flatnonzero(cnt >= job.num_gpus)
    if len(fits):
        order = np.lexsort((fits, -occupied[fits], cnt[fits] - job.num_gpus))
        best_srv = int(fits[order[0]])
        pool = feasible[srv_of == best_srv]
        order = pool[np.argsort(state.U[pool], kind="stable")]
        return order[: job.num_gpus]
    order = feasible[np.argsort(state.U[feasible], kind="stable")]
    return order[: job.num_gpus]


def lbsgf(state: PlacementState, job: Job, rho_nom: float, u: float,
          theta: float) -> np.ndarray | None:
    """Algorithm 3: Least-Busy-Server-GPU-First (large jobs).

    Sort servers by average GPU busy time; take the top-m least-busy servers
    with cumulative capacity >= lambda_j * G_j (line 2); walk those servers
    in least-busy order appending their feasible GPUs sorted by U (lines
    4-5), and take the first G_j (line 7).  Server-major order packs the
    ring into the emptiest few servers — which is what makes a larger
    lambda (a wider server pool) monotonically reduce contention+overhead,
    the Fig. 7 behaviour."""
    cl = state.cluster
    srv_of = cl.gpu_server
    caps = cl.capacities_array
    srv_load = np.zeros(cl.num_servers)
    np.add.at(srv_load, srv_of, state.U)
    srv_order = np.argsort(srv_load / caps, kind="stable")
    need = job.lam * job.num_gpus
    cum = np.cumsum(caps[srv_order])
    m = int(np.searchsorted(cum, need) + 1)
    m = min(m, cl.num_servers)
    selected = srv_order[:m]
    srv_rank = np.full(cl.num_servers, -1, dtype=np.int64)
    srv_rank[selected] = np.arange(m)

    pool = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    pool = pool[srv_rank[srv_of[pool]] >= 0]
    if len(pool) < job.num_gpus:
        return None
    ranks = srv_rank[srv_of[pool]]
    order = np.lexsort((state.U[pool], ranks))   # server-major, then least U
    return pool[order][: job.num_gpus]


def _attempt(cluster: Cluster, jobs_sorted: list[Job],
             rho_noms: dict[int, float], u: float, theta: float,
             kappa: int, engine: str | None = None,
             hints: dict[int, np.ndarray] | None = None
             ) -> PlacementState | None:
    """One (theta, kappa) pass of Alg. 1 lines 8-16."""
    state = PlacementState(cluster, engine=engine)
    for job in jobs_sorted:
        picker = fa_ffp if job.num_gpus <= kappa else lbsgf
        hint = hints.get(job.jid) if hints else None
        if not try_place(state, job, picker, rho_noms[job.jid], u, theta,
                         hint=hint):
            return None
    return state


def _sweep_batched(cluster: Cluster, jobs_sorted: list[Job],
                   rho_noms: dict[int, float], u: float, theta: float,
                   kappas: list[int], engine: str | None,
                   hints: dict[int, np.ndarray] | None
                   ) -> dict[int, ScheduleResult | None]:
    """Every kappa branch of one theta, sharing placed prefixes.

    In sorted-job order the branch for kappa places jobs with G_j <= kappa
    via FA-FFP and the rest via LBSGF, so for ascending kappas the FA-FFP
    prefix of one branch is a prefix of the next branch's: each prefix
    segment is placed ONCE into a shared :class:`PlacementState` and every
    branch forks off it (:meth:`PlacementState.clone`) for its LBSGF
    suffix.  Placement is deterministic given the state, so each branch's
    schedule -- and a prefix placement failure, which dooms every kappa at
    or above the failing job's size -- is bit-identical to running
    :func:`_attempt` per kappa from scratch."""
    n = len(jobs_sorted)
    shared = PlacementState(cluster, engine=engine)
    results: dict[int, ScheduleResult | None] = {}
    idx = 0                       # next job to absorb into the shared prefix
    prefix_ok = True
    for kappa in sorted(set(kappas)):
        while prefix_ok and idx < n and jobs_sorted[idx].num_gpus <= kappa:
            job = jobs_sorted[idx]
            hint = hints.get(job.jid) if hints else None
            if not try_place(shared, job, fa_ffp, rho_noms[job.jid], u,
                             theta, hint=hint):
                prefix_ok = False                              # line 14
                break
            idx += 1
        if not prefix_ok:
            results[kappa] = None
            continue
        # All jobs placed already: later branches add nothing, so the
        # shared state needs no fork (it is never committed to again).
        state = shared.clone() if idx < n else shared
        ok = True
        for job in jobs_sorted[idx:]:
            hint = hints.get(job.jid) if hints else None
            if not try_place(state, job, lbsgf, rho_noms[job.jid], u, theta,
                             hint=hint):
                ok = False                                     # line 14
                break
        results[kappa] = finalize(state, n, theta, kappa, "SJF-BCO") \
            if ok else None
    return results


@register_policy("sjf-bco")
def sjf_bco_policy(request: ScheduleRequest) -> ScheduleResult:
    """Algorithm 1 (batch) / finish-minimising epoch scheduler (online).

    ``request.params``:
      * ``kappas`` -- candidate small/large thresholds to sweep (batch
        only); defaults to the distinct job sizes, which is equivalent to
        the paper's 1..max_j G_j sweep.
      * ``engine`` -- contention-model engine (see
        :class:`~repro.core.api.PlacementState`).
      * ``sweep`` -- ``"batched"`` (default) runs all kappa branches of a
        theta off shared placed prefixes (jobs below a branch's kappa
        place identically in every branch at or above it, so each FA-FFP
        prefix segment is placed once); ``"sequential"`` is the reference
        one-kappa-at-a-time loop.  Both produce bit-identical schedules
        (pinned by tests and the CI bench smoke).
      * ``warm_start`` -- seed each theta's attempts with the placements
        committed at the previous feasible theta (off by default; changes
        the search trajectory, not the accounting).
    """
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    sweep = request.params.get("sweep", "batched")
    if sweep not in ("batched", "sequential"):
        raise ValueError(
            f"unknown sweep mode {sweep!r}; choose 'batched' or 'sequential'")
    if not request.is_batch:
        def choose(state: PlacementState, job: Job, theta: float) -> bool:
            return pick_best_finish(state, job, [fa_ffp, lbsgf],
                                    nominal_rho(cluster, job), u, theta)
        return schedule_arrivals(request, choose, "SJF-BCO")

    jobs = request.jobs
    jobs_sorted = sorted(jobs, key=lambda j: (j.num_gpus, j.jid))   # line 3
    rho_noms = {j.jid: nominal_rho(cluster, j) for j in jobs}
    kappas = request.params.get("kappas")
    if kappas is None:
        # Only kappa values at distinct job sizes change the FA-FFP/LBSGF
        # split; sweeping them is equivalent to the paper's 1..max_j G_j.
        kappas = sorted({j.num_gpus for j in jobs})
        if 1 not in kappas:
            kappas.insert(0, 1)

    def attempt(theta: float,
                prev: ScheduleResult | None = None) -> ScheduleResult | None:
        hints = dict(prev.assignment) if prev is not None else None
        if sweep == "batched":
            sweep_results = _sweep_batched(cluster, jobs_sorted, rho_noms,
                                           u, theta, kappas, engine, hints)
        best_theta: ScheduleResult | None = None
        for kappa in kappas:                                       # line 7
            if sweep == "batched":
                cand = sweep_results[kappa]
            else:
                state = _attempt(cluster, jobs_sorted, rho_noms, u, theta,
                                 kappa, engine=engine, hints=hints)
                cand = finalize(state, len(jobs), theta, kappa, "SJF-BCO") \
                    if state is not None else None                 # line 14
            if cand is None:
                continue
            if best_theta is None or cand.est_makespan < best_theta.est_makespan:
                best_theta = cand                                  # lines 17-18
        return best_theta

    return bisect_theta(attempt, request.horizon, "SJF-BCO",
                        warm_start=bool(request.params.get("warm_start")))
