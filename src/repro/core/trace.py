"""Trace-replay arrivals: step a recorded GPU-cluster job log through the
scheduler (ROADMAP item 4, first slice).

A trace is a CSV with Alibaba ``cluster-trace-gpu-2020``-style columns:
one row per job, ``start_time`` (the arrival instant, seconds/slots),
``plan_gpu`` (requested GPU share in GPU-percent -- 100 per device, as in
the Alibaba schema; 200 = a 2-GPU gang), ``iterations`` (F_j) and
``grad_size`` (m_j, GB).  Optional columns ``batch``/``dt_fwd``/
``dt_bwd``/``lam`` override the per-iteration cost terms; absent columns
fall back to mid-range Philly-workload constants, so a minimal 4-column
log replays out of the box.

Two consumers share :func:`load_trace`:

  * the declarative scenario layer -- ``WorkloadSpec(kind="trace",
    path=...)`` builds the job list and ``ArrivalSpec(kind="trace",
    path=...)`` the arrival vector, so :func:`repro.core.scenario.run_scenario`
    replays the log end-to-end;
  * the service daemon -- :func:`replay_trace` admits each row at its
    recorded arrival, so a long-running daemon steps the identical
    stream (placements match ``schedule_arrivals`` on the same trace by
    the daemon's identity guarantee).

A bundled sample lives at ``examples/sample_trace.csv``.
"""
from __future__ import annotations

import csv
import dataclasses

import numpy as np

from repro.core.jobs import Job

__all__ = ["TRACE_COLUMNS", "load_trace", "replay_trace"]

# Required header names; optional extras: batch, dt_fwd, dt_bwd, lam.
TRACE_COLUMNS = ("start_time", "plan_gpu", "iterations", "grad_size")

# Philly-workload mid-range fallbacks for traces that only record the
# (arrival, shape, length) columns (see repro.core.jobs.philly_workload).
_DEFAULT_BATCH = 32
_DEFAULT_DT_FWD = 3.0e-4
_DEFAULT_DT_BWD = 8.0e-3


def load_trace(path: str) -> tuple[list[Job], np.ndarray]:
    """Parse a trace CSV into ``(jobs, arrivals)``.

    Rows are sorted by ``start_time`` (ties keep file order) and jobs are
    renumbered so ``jid == index`` -- the invariant the simulator's
    assignment indexing and the scheduler's ``(arrival, G_j, jid)`` visit
    order rely on.  Arrivals are floored to integer slots, shifted so the
    first arrival lands at slot 0 (a trace excerpt's absolute epoch is
    irrelevant to scheduling).
    """
    with open(path, newline="") as fh:
        reader = csv.DictReader(fh)
        header = reader.fieldnames or []
        missing = [c for c in TRACE_COLUMNS if c not in header]
        if missing:
            raise ValueError(
                f"trace {path!r} is missing required columns {missing}; "
                f"expected at least {list(TRACE_COLUMNS)} (got {header})")
        rows = list(reader)
    if not rows:
        raise ValueError(f"trace {path!r} has no job rows")
    parsed = []
    for i, row in enumerate(rows):
        try:
            start = float(row["start_time"])
            plan_gpu = float(row["plan_gpu"])
            iters = int(float(row["iterations"]))
            grad = float(row["grad_size"])
        except (TypeError, ValueError) as exc:
            raise ValueError(f"trace {path!r} row {i + 2}: {exc}") from None
        # Alibaba logs GPU shares in percent; fractional-GPU requests
        # round up to one whole device (gang scheduling is device-whole).
        gpus = max(1, int(round(plan_gpu / 100.0)))
        job = Job(
            jid=0, num_gpus=gpus, iters=iters, grad_size=grad,
            batch=int(float(row.get("batch") or _DEFAULT_BATCH)),
            dt_fwd=float(row.get("dt_fwd") or _DEFAULT_DT_FWD),
            dt_bwd=float(row.get("dt_bwd") or _DEFAULT_DT_BWD),
            lam=float(row.get("lam") or 1.0),
        )
        parsed.append((start, i, job))
    parsed.sort(key=lambda t: (t[0], t[1]))
    jobs = [dataclasses.replace(job, jid=i)
            for i, (_, _, job) in enumerate(parsed)]
    arrivals = np.floor(np.asarray([s for s, _, _ in parsed])).astype(np.int64)
    arrivals -= arrivals[0]
    return jobs, arrivals


def replay_trace(daemon, path: str, tenant: str = "default") -> list:
    """Admit every trace row into a service daemon at its recorded arrival.

    ``daemon`` is a :class:`repro.service.daemon.Daemon` (or anything with
    its ``admit(job, arrival, tenant)`` surface, e.g. a
    :class:`~repro.service.api.SchedulerService`'s ``.daemon``).  Returns
    the admitted :class:`~repro.service.state.JobRecord` list in arrival
    order; the caller steps/drains the daemon as usual.
    """
    jobs, arrivals = load_trace(path)
    return [daemon.admit(job, arrival=int(t), tenant=tenant)
            for job, t in zip(jobs, arrivals)]
