"""Theory checks for SJF-BCO (paper §6).

  * Lemma 2 -- max busy time of the returned schedule equals theta_tilde.
  * Lemma 3 -- makespan <= n_g * W_max (busy + gang-idle bound).
  * Theorem 5 -- makespan <= n_g * phi * (u/l) * T_opt; here we compute the
    certified *upper bound* and empirical l, u from simulated actuals.
  * Theorem 6 -- running time O(n_g |J| N log N log T) (asserted-by-design;
    we expose the trial counter for the test).

These are used by tests/test_theory.py (hypothesis property tests) and by
benchmarks to report the certified ratio alongside the measured makespan.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.api import ScheduleResult, rho_hat
from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.simulator import SimResult


@dataclasses.dataclass(frozen=True)
class TheoryReport:
    n_g: int
    theta_tilde: float        # tightest budget found (== max busy time, Lem. 2)
    makespan: float           # actual, from the simulator
    makespan_bound: float     # n_g * W_max (Lemma 3, w.r.t. busy-time clocks)
    l: float                  # empirical lower bracket of rho_hat / rho
    u: float                  # empirical upper bracket of rho_hat / rho
    varphi: float             # max_j rho ratio across schedules (Lemma 4)
    approx_ratio_bound: float  # n_g * varphi * u / l (Theorem 5)
    lower_bound_makespan: float  # max GPU busy time: no schedule can beat this

    @property
    def certified(self) -> bool:
        """Does the end-to-end Thm.-5 chain hold on this instance?"""
        return self.makespan <= self.approx_ratio_bound * max(
            self.lower_bound_makespan, 1e-12)


def empirical_brackets(cluster: Cluster, jobs: list[Job], sim: SimResult
                       ) -> tuple[float, float]:
    """Empirical l, u with rho_hat in [l*rho, u*rho] over completed jobs."""
    ls, us = [], []
    for j in jobs:
        if sim.finish[j.jid] < 0 or sim.start[j.jid] < 0:
            continue
        actual = float(sim.finish[j.jid] - sim.start[j.jid])
        if actual <= 0:
            continue
        ratio = rho_hat(cluster, j) / actual
        ls.append(min(ratio, 1.0))
        us.append(max(ratio, 1.0))
    if not ls:
        return 1.0, 1.0
    return float(min(ls)), float(max(us))


def report(cluster: Cluster, jobs: list[Job], schedule: ScheduleResult,
           sim: SimResult, varphi: float | None = None) -> TheoryReport:
    n_g = max(j.num_gpus for j in jobs)
    l, u = empirical_brackets(cluster, jobs, sim)
    if varphi is None:
        # Worst-case actual-time ratio of one job across candidate schedules;
        # bounded by tau_hi/tau_lo which we take as the conservative default.
        from repro.core.contention import tau_bounds
        ratios = []
        for j in jobs:
            lo, hi = tau_bounds(cluster, j)
            ratios.append(hi / max(lo, 1e-12))
        varphi = float(max(ratios))
    # A makespan lower bound for *any* schedule: total work on the busiest
    # possible GPU cannot be smaller than total_gpu_work / N, and no job can
    # finish faster than its contention-free execution time.
    from repro.core.api import nominal_rho
    total_work = sum(nominal_rho(cluster, j) * j.num_gpus for j in jobs)
    lb = max(total_work / cluster.num_gpus,
             max(nominal_rho(cluster, j) for j in jobs))
    return TheoryReport(
        n_g=n_g,
        theta_tilde=schedule.theta,
        makespan=sim.makespan,
        makespan_bound=n_g * schedule.max_busy_time,
        l=l, u=u, varphi=varphi,
        approx_ratio_bound=n_g * varphi * u / max(l, 1e-12),
        lower_bound_makespan=lb,
    )
