"""Unified scheduling API: one request/result pair, a policy registry, and
the shared busy-time machinery every policy builds on.

The paper's Fig. 3 loop is "search a placement -> evaluate it under
contention".  Every scheduler in this repo is an instance of that loop, so
the public surface is deliberately small:

  * :class:`ScheduleRequest` -- cluster, jobs, optional arrival times,
    horizon T, slack factor u, and policy-specific ``params``.  Batch
    scheduling (the paper's §4 setting, all jobs known at t=0) is the
    ``arrivals=None`` special case of the same code path that serves
    online streams.
  * :class:`ScheduleResult` -- placement + busy-time certificate, ready
    for :func:`repro.core.simulator.simulate`.
  * :func:`register_policy` / :func:`get_policy` / :func:`list_policies`
    -- a decorator-based registry; ``get_policy(name)(request)`` runs any
    registered policy through one signature.

Supported building blocks for policy authors (promoted out of
``sjf_bco.py``, which previously kept them private):

  * :class:`PlacementState` -- busy-time clocks U (Eq. 15/16), real-time
    clocks R, and the placed-job snapshot used by the rho_hat(y^k)
    refinement of Eq. (8).
  * :func:`try_place` -- nominal-filter -> refine -> re-check loop
    (the Fig. 3 "re-evaluate after the schedule is known" retry).
  * :func:`bisect_theta` -- Algorithm 1's bisection on the per-GPU
    execution-time budget theta_u, generic over the per-theta attempt.
  * :func:`schedule_arrivals` -- the online epoch loop: advance the real
    clocks to each arrival and greedily place with a policy-supplied
    chooser.
  * :func:`finalize`, :func:`nominal_rho`, :func:`rho_hat`.

A new policy is ~20 lines::

    @register_policy("my-policy")
    def my_policy(request: ScheduleRequest) -> ScheduleResult:
        def attempt(theta):
            state = PlacementState(request.cluster)
            for job in request.jobs:
                if not try_place(state, job, my_picker,
                                 nominal_rho(request.cluster, job),
                                 request.u, theta):
                    return None
            return finalize(state, len(request.jobs), theta, None, "MINE")
        return bisect_theta(attempt, request.horizon, "MINE")
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core import contention
from repro.core.cluster import Cluster
from repro.core.contention import (evaluate_many, predict_exec_time,
                                   resolve_engine, scalar_tau, slots_for,
                                   tau_bounds)
from repro.core.jobs import Job

# --------------------------------------------------------------------------
# Request / result
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScheduleRequest:
    """One scheduling problem instance.

    ``arrivals`` (optional) gives the arrival slot of ``jobs[i]`` as
    ``arrivals[i]``; ``None`` -- or an all-zero array -- is the batch
    setting where every job is available at t=0.  ``params`` carries
    policy-specific knobs (e.g. ``{"kappas": [8]}`` for SJF-BCO,
    ``{"seed": 1}`` for RAND).  Every built-in policy honours
    ``"engine"`` (contention-model engine: ``"incremental"``,
    ``"batched"`` or ``"reference"`` -- all bit-identical, see
    :mod:`repro.core.contention`); the try_place-based bisection policies
    (``sjf-bco``, ``ff``, ``ls``) additionally honour ``"warm_start"``
    (seed each theta of the bisection with the previous theta's
    placements).
    """

    cluster: Cluster
    jobs: list[Job]
    arrivals: np.ndarray | None = None
    horizon: int = 1200
    u: float = 1.5
    params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.jobs:
            raise ValueError("request needs at least one job")
        for i, j in enumerate(self.jobs):
            # Assignments carry job ids and the simulator indexes ``jobs``
            # with them, so ids must be 0..n-1 in list order.
            if j.jid != i:
                raise ValueError(
                    f"jobs[{i}].jid == {j.jid}; job ids must equal their "
                    "list index (renumber with dataclasses.replace)")
        if self.arrivals is not None:
            arr = np.asarray(self.arrivals)
            if arr.shape != (len(self.jobs),):
                raise ValueError(
                    f"arrivals shape {arr.shape} != ({len(self.jobs)},)")
            if np.any(arr < 0):
                raise ValueError("arrival slots must be >= 0")
            object.__setattr__(self, "arrivals", arr)

    @property
    def is_batch(self) -> bool:
        """True when every job is available at t=0 (the paper's setting)."""
        return self.arrivals is None or not np.any(self.arrivals > 0)

    def arrival_of(self, job: Job) -> int:
        """Arrival slot of ``job`` (0 in the batch setting)."""
        if self.arrivals is None:
            return 0
        return int(self.arrivals[self.jobs.index(job)])

    def arrival_items(self) -> list[tuple[Job, int]]:
        """(job, arrival) pairs, in request order."""
        if self.arrivals is None:
            return [(j, 0) for j in self.jobs]
        return [(j, int(t)) for j, t in zip(self.jobs, self.arrivals)]


@dataclasses.dataclass
class ScheduleResult:
    """Result of a scheduling policy, ready for the simulator.

    Subsumes the legacy ``Schedule``: ``assignment`` is the ordered
    (job id, gpu ids) placement, ``theta`` the busy-time budget the
    schedule was certified against (Eq. 16), ``max_busy_time`` the
    realised max U (== theta_tilde of Lemma 2 for the tightest feasible
    theta).
    """

    assignment: list[tuple[int, np.ndarray]]   # (job id, gpu ids), order
    est_start: np.ndarray
    est_finish: np.ndarray
    est_makespan: float
    theta: float
    kappa: int | None = None
    policy: str = ""
    max_busy_time: float = 0.0
    # Per-assignment-entry iteration quotas for preemptive schedules (a
    # jid may then appear in several entries -- its checkpointed
    # segments); None for the non-preemptive Eq. (3) setting.  Passed to
    # :func:`repro.core.simulator.simulate` as ``quotas``.
    quotas: np.ndarray | None = None


@runtime_checkable
class SchedulingPolicy(Protocol):
    """A scheduling policy: one problem instance in, one schedule out."""

    def __call__(self, request: ScheduleRequest) -> ScheduleResult: ...


# --------------------------------------------------------------------------
# Policy registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, SchedulingPolicy] = {}
_BUILTINS_LOADED = False


def _load_builtins() -> None:
    """Import the built-in policy modules so their decorators run.

    Lazy so ``repro.core.api`` has no imports of the modules that import
    it -- this is what removes the old ``POLICIES["sjf-bco"] = None``
    import-cycle patch.
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from repro.core import baselines, extensions, preempt, sjf_bco  # noqa: F401


def register_policy(name: str, *aliases: str
                    ) -> Callable[[SchedulingPolicy], SchedulingPolicy]:
    """Decorator: make ``fn`` available as ``get_policy(name)``."""

    def deco(fn: SchedulingPolicy) -> SchedulingPolicy:
        """Register ``fn`` under ``name`` and every alias."""
        for key in (name, *aliases):
            key = key.lower()        # lookups lowercase too
            if key in _REGISTRY and _REGISTRY[key] is not fn:
                raise ValueError(f"policy {key!r} already registered")
            _REGISTRY[key] = fn
        fn.policy_name = name.lower()   # type: ignore[attr-defined]
        return fn

    return deco


def get_policy(name: str) -> SchedulingPolicy:
    """Look up a registered policy by name (case-insensitive)."""
    _load_builtins()
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown policy {name!r}; registered: {', '.join(list_policies())}")
    return _REGISTRY[key]


def list_policies() -> list[str]:
    """Sorted names of every registered policy."""
    _load_builtins()
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# Online-chooser registry (the incremental face of the same policies)
# --------------------------------------------------------------------------

# A chooser factory binds a policy's per-arrival placement rule to a
# (cluster, u, params) context; the returned Chooser is exactly what the
# policy's own ``arrivals`` branch hands to :func:`schedule_arrivals`.
ChooserFactory = Callable[["Cluster", float, dict], "Chooser"]

_CHOOSERS: dict[str, ChooserFactory] = {}


def register_chooser(name: str, *aliases: str
                     ) -> Callable[[ChooserFactory], ChooserFactory]:
    """Decorator: register a policy's online chooser factory.

    Every policy with an ``arrivals`` path registers the factory that
    builds its per-arrival chooser, and its own online branch goes through
    the same factory -- so a long-running consumer (``repro.service``)
    that pulls the chooser via :func:`get_chooser` and drives it against a
    persistent :class:`PlacementState` makes decision-for-decision the
    same placements as a one-shot :func:`schedule_arrivals` call."""

    def deco(fn: ChooserFactory) -> ChooserFactory:
        """Register ``fn`` under ``name`` and every alias."""
        for key in (name, *aliases):
            key = key.lower()
            if key in _CHOOSERS and _CHOOSERS[key] is not fn:
                raise ValueError(f"chooser {key!r} already registered")
            _CHOOSERS[key] = fn
        return fn

    return deco


def get_chooser(name: str) -> ChooserFactory:
    """Look up a registered online-chooser factory (case-insensitive).

    ``get_chooser(name)(cluster, u, params)`` returns the same
    :data:`Chooser` the policy's online branch uses, bound to the given
    context; stateful choosers (RAND's rng) carry ``stateful = True``."""
    _load_builtins()
    key = name.lower()
    if key not in _CHOOSERS:
        raise KeyError(
            f"policy {name!r} has no online chooser; "
            f"registered: {', '.join(sorted(_CHOOSERS))}")
    return _CHOOSERS[key]


def list_choosers() -> list[str]:
    """Sorted names of every registered online chooser."""
    _load_builtins()
    return sorted(_CHOOSERS)


# --------------------------------------------------------------------------
# Placement-engine axis
# --------------------------------------------------------------------------

# How the bisection policies advance their (theta, kappa) attempt forest:
# "columnar" runs the whole forest as one branch-vectorised array program
# over deduplicated state rows
# (:class:`repro.core.columnar.ColumnarPlacement`); "scalar" walks one
# :class:`PlacementState` per branch (with the COW lineage sharing of
# ``try_place_group``) and is the bit-identity oracle.  Same selectable
# -oracle pattern as the ``engine``/``sweep``/``bisect`` axes.
PLACEMENTS = ("scalar", "columnar")

#: Job count from which the size-aware default flips to the columnar
#: engine, or ``None`` while no flip is warranted.  Set from
#: BENCH_contention.json's measured scalar-vs-columnar crossover
#: (``placement_crossover_J``), and the bench records *no* crossover on
#: this CPU host: the scalar COW walk wins at every measured size
#: (24.7s vs 70.8s jit-columnar at |J| = 16384; both scale ~|J|^1.1,
#: and the |J| = 100000 ``--scale`` point confirms scalar ahead), so
#: the default stays scalar until a bench on some host proves a win.
#: The columnar engine remains the explicit opt-in
#: (``params={"placement": "columnar"}``) -- it is the strictly-array
#: substrate accelerator work targets, not the CPU fast path.
COLUMNAR_DEFAULT_MIN_JOBS: int | None = None


def resolve_placement(params: dict, n_jobs: int | None = None) -> str:
    """The request's ``placement`` param, validated.

    An explicit ``placement`` always wins.  Without one the default is
    size-aware: "scalar" below :data:`COLUMNAR_DEFAULT_MIN_JOBS` jobs,
    "columnar" at or above it -- but only where the bench-recorded
    crossover proves the fused array program wins, and the current
    BENCH_contention.json records none (the constant is ``None``, so
    the default is "scalar" at every size); callers that pass no
    ``n_jobs`` -- the scalar-only validate sites -- default to
    "scalar" always.

    "scalar" is the per-branch ``PlacementState`` walk -- the bit-identity
    oracle and, on CPU at small |J|, the faster end-to-end path (its
    copy-on-write lineages already share ~all placement work between
    probe branches, and it pays no per-step vectorisation overhead).
    "columnar" advances the whole sweep x bisect forest as one
    [branches, S] array program (:class:`ColumnarPlacement`) -- identical
    decisions, strictly-array state, jit-fused per step
    (:mod:`repro.kernels.placement`); it is the trace-scale fast path and
    the accelerator substrate (see docs/ARCHITECTURE.md).
    """
    placement = params.get("placement")
    if placement is None:
        return ("columnar" if COLUMNAR_DEFAULT_MIN_JOBS is not None
                and n_jobs is not None
                and n_jobs >= COLUMNAR_DEFAULT_MIN_JOBS else "scalar")
    if placement not in PLACEMENTS:
        raise ValueError(f"unknown placement {placement!r}; "
                         f"choose from {PLACEMENTS}")
    return placement


def resolve_columnar_backend(params: dict) -> str:
    """The request's ``columnar_backend`` param, resolved (default "auto").

    "auto" picks the fused "jit" programs when jax runs in float64
    (``jax_enable_x64``, the bit-identity precondition) and falls back to
    "numpy" otherwise; "jit"/"kernel"/"numpy" force a backend ("kernel"
    routes the same row math through the Pallas kernels of
    :mod:`repro.kernels.placement`, interpret mode on CPU).  All backends
    are bit-identical under x64 (pinned by
    ``tests/test_columnar_equivalence.py``).
    """
    backend = params.get("columnar_backend", "auto")
    if backend == "auto":
        import jax
        return "jit" if jax.config.jax_enable_x64 else "numpy"
    from repro.core.columnar import COLUMNAR_BACKENDS
    if backend not in COLUMNAR_BACKENDS:
        raise ValueError(
            f"unknown columnar backend {backend!r}; choose 'auto' or one "
            f"of {COLUMNAR_BACKENDS}")
    return backend


# --------------------------------------------------------------------------
# Estimates (Table 1 / §5.1)
# --------------------------------------------------------------------------


def nominal_rho(cluster: Cluster, job: Job) -> float:
    """Contention-free lower estimate (tau at b_intra, single server)."""
    lo, _ = tau_bounds(cluster, job)
    return slots_for(job.iters, lo)


def rho_hat(cluster: Cluster, job: Job) -> float:
    """Schedule-independent mid-bracket estimate, used by theory checks."""
    lo, hi = tau_bounds(cluster, job)
    return slots_for(job.iters, 0.5 * (lo + hi))


# --------------------------------------------------------------------------
# Busy-time accounting (§5-3)
# --------------------------------------------------------------------------


class PlacementState:
    """Per-attempt scheduler state: busy clocks U, real clocks R, and the
    snapshot of placed jobs used for the rho_hat(y^k) refinement.

    ``engine`` selects how rho_hat(y^k) probes evaluate the Eq. (6)-(8)
    model (default: the module-wide :data:`repro.core.contention.DEFAULT_ENGINE`):

      * ``"incremental"`` -- per-server sorted lists of the est_finish
        times of straddling placed jobs, updated once per commit; a probe's
        contention level p is then a suffix count (jobs still running at
        the candidate's start) per straddled server, so each rho_hat is
        O(straddled servers * log placed) + scalar Eq. (8) instead of a
        full [J, S] model pass;
      * ``"batched"`` -- :meth:`refined_rho_many` scores all candidates of
        a placement decision in one ``evaluate_many`` pass;
      * ``"reference"`` -- the original per-candidate ``evaluate`` loop.

    All three produce bit-identical estimates (and therefore identical
    schedules); see ``tests/test_batched_contention.py``.
    """

    def __init__(self, cluster: Cluster, engine: str | None = None):
        self.cluster = cluster
        self.engine = resolve_engine(engine)
        self.U = np.zeros(cluster.num_gpus)    # busy-time clock (Eq. 15/16)
        self.R = np.zeros(cluster.num_gpus)    # real-time clock (gang start)
        self.assignment: list[tuple[int, np.ndarray]] = []
        self.placed_jobs: list[Job] = []
        self.placed_y: list[np.ndarray] = []   # per-server GPU counts
        self.est_start: dict[int, float] = {}
        self.est_finish: dict[int, float] = {}
        # Per-assignment-entry (segment) bookkeeping.  Non-preemptive
        # policies commit one entry per job and never read these; the
        # preemption primitives (:mod:`repro.core.preempt`) need the EXACT
        # committed floats (est_finish - est_start would not round-trip
        # rho) plus the entry <-> placed-row linkage to undo/truncate a
        # commit.  ``seg_quota`` is each entry's planned iteration share
        # (the job's full F_j until an eviction splits it), which is what
        # the simulator's per-segment execution consumes.
        self.seg_rho: list[float] = []         # committed rho per entry
        self.seg_start: list[float] = []       # committed gang start per entry
        self.seg_quota: list[float] = []       # planned iterations per entry
        self.seg_prev: list[int] = []          # previous entry of same jid, -1
        self.seg_row: list[int] = []           # placed_jobs row of the entry
        self.placed_fin: list[float] = []      # per-ROW est finish (rows of a
        #   split job carry their own truncated finishes; est_finish keeps
        #   only the job's latest)
        self._entry_of: dict[int, int] = {}    # jid -> latest live entry
        self.preempted = False                 # any evict happened here
        self.now = 0.0                         # decision clock (advance_to)
        # Per-server sorted est_finish of straddling placed jobs (Eq. 6
        # suffix counts for the incremental engine; maintained by commit).
        # Cloning shares these lists copy-on-write: ``_fin_owned[s]`` says
        # whether this state may mutate server s's list in place.
        self._straddle_fin: list[list[float]] = \
            [[] for _ in range(cluster.num_servers)]
        self._fin_owned = [True] * cluster.num_servers
        # Optional observer called after every commit with the exact
        # (job, gpus, rho, start) committed -- the write-ahead journal of
        # repro.service captures placements here so a crash replay can
        # re-commit bit-identically (est_finish - est_start would NOT
        # round-trip rho through float subtraction).
        self.commit_hook: "Callable[[Job, np.ndarray, float, float], None] | None" = None
        # Optional observer called by :func:`repro.core.preempt.evict` with
        # (job, t_ev, residual_job) after an eviction is applied -- the
        # service daemon journals EVICT/RESIZE records here.
        self.evict_hook: "Callable[[Job, float, Job], None] | None" = None

    def _y_of(self, gpus: np.ndarray) -> np.ndarray:
        return np.bincount(self.cluster.gpu_server[gpus],
                           minlength=self.cluster.num_servers)

    def clone(self) -> "PlacementState":
        """Independent copy of the attempt state: committing to the clone
        leaves the original untouched.  The batched (theta, kappa) sweep
        (``sjf-bco`` with ``params={"sweep": "batched"}``) and the
        speculative bisection's lineage forks both clone per branch.

        The per-server sorted-finish lists are shared copy-on-write:
        both sides drop ownership here, and :meth:`commit` copies a
        server's list the first time it inserts into an un-owned one --
        so a clone is O(placed jobs + servers) instead of O(total finish
        entries), which is what keeps heavy branching affordable at
        |J| ~ 1024."""
        new = PlacementState.__new__(PlacementState)
        new.cluster = self.cluster
        new.engine = self.engine
        new.U = self.U.copy()
        new.R = self.R.copy()
        new.assignment = list(self.assignment)
        new.placed_jobs = list(self.placed_jobs)
        new.placed_y = list(self.placed_y)
        new.est_start = dict(self.est_start)
        new.est_finish = dict(self.est_finish)
        new.seg_rho = list(self.seg_rho)
        new.seg_start = list(self.seg_start)
        new.seg_quota = list(self.seg_quota)
        new.seg_prev = list(self.seg_prev)
        new.seg_row = list(self.seg_row)
        new.placed_fin = list(self.placed_fin)
        new._entry_of = dict(self._entry_of)
        new.preempted = self.preempted
        new.now = self.now
        new._straddle_fin = list(self._straddle_fin)
        self._fin_owned = [False] * self.cluster.num_servers
        new._fin_owned = [False] * self.cluster.num_servers
        new.commit_hook = None      # observers watch one state, not forks
        new.evict_hook = None
        return new

    def advance_to(self, t: float) -> None:
        """Advance the real-time clocks to ``t`` (an arrival instant): a
        GPU idle before the arrival cannot have been used earlier.  Also
        records ``t`` as :attr:`now`, the state's decision clock -- the
        preemptive choosers read it as the eviction instant."""
        self.now = max(self.now, float(t))
        np.maximum(self.R, float(t), out=self.R)

    def _overlaps(self, start: float) -> np.ndarray:
        """Mask over placed rows whose estimated window covers ``start``.

        Per-ROW finishes (not per-jid): segments of a preempted job carry
        their own truncated finishes; for non-preemptive states the row
        finish equals ``est_finish[jid]`` exactly."""
        return np.asarray([fin > start + 1e-9 for fin in self.placed_fin],
                          dtype=bool)

    def _probe_p(self, job: Job, y_j: np.ndarray, start: float
                 ) -> tuple[int, int]:
        """(p, n_srv) of a candidate placement against the placed jobs:
        the Eq. (6) level is 1 + max over its straddled servers of the
        number of placed straddling jobs still running at ``start`` (a
        suffix count on the per-server sorted est_finish lists)."""
        p = 0
        n_srv = 0
        cut = start + 1e-9
        G = job.num_gpus
        straddle_fin = self._straddle_fin
        for s, y in enumerate(y_j.tolist()):
            if y > 0:
                n_srv += 1
                if y < G:
                    fin = straddle_fin[s]
                    p = max(p, len(fin) - bisect.bisect_right(fin, cut) + 1)
        return p, n_srv

    def _probe_rho(self, job: Job, y_j: np.ndarray, start: float) -> float:
        """Incremental rho_hat(y^k): Eq. (6) via :meth:`_probe_p`, then
        the scalar Eq. (8); tau_j needs nothing else.  On heterogeneous
        clusters the candidate's worst-member device terms ride along, so
        the probe prices the slow tier / isolated uplink it would land on."""
        p, n_srv = self._probe_p(job, y_j, start)
        contention.EVAL_COUNTS["probes"] += 1
        cl = self.cluster
        if cl.is_heterogeneous:
            pos = y_j > 0
            tau = scalar_tau(
                cl, job, p, n_srv,
                speed=float(cl.server_speed_floor[pos].min()),
                bw_shared=float(cl.uplink_shared_or_inf[pos].min()),
                bw_isolated=float(cl.uplink_isolated_or_inf[pos].min()))
        else:
            tau = scalar_tau(cl, job, p, n_srv)
        return slots_for(job.iters, tau)

    def refined_rho(self, job: Job, gpus: np.ndarray) -> tuple[float, float]:
        """rho_hat_j(y^k): Eq. (8) against placed jobs overlapping the
        estimated gang start.  Returns (rho_hat, est_start)."""
        start = float(self.R[gpus].max()) if len(gpus) else 0.0
        y_j = self._y_of(gpus)
        if self.engine == "incremental":
            return self._probe_rho(job, y_j, start), start
        overlap = self._overlaps(start)
        overlap_jobs = [jb for jb, ov in zip(self.placed_jobs, overlap) if ov]
        overlap_y = [y for y, ov in zip(self.placed_y, overlap) if ov]
        Y_snap = np.asarray(overlap_y, dtype=np.int64).reshape(
            len(overlap_jobs), self.cluster.num_servers)
        return predict_exec_time(self.cluster, job, overlap_jobs, Y_snap,
                                 y_j), start

    def refined_rho_many(self, job: Job, gpu_sets: list[np.ndarray]
                         ) -> list[tuple[float, float]]:
        """Batch form of :meth:`refined_rho` over C candidate GPU sets.

        Under the ``"batched"`` engine all candidates are scored in a
        single ``evaluate_many`` pass over one [C, P+1, S] stack (placed
        jobs not overlapping a candidate's start are masked out, which is
        equivalent to omitting their rows).  Under ``"incremental"`` the
        per-candidate contention levels come from the suffix counts and
        one vectorised :func:`~repro.core.contention.scalar_tau_many` call
        scores every candidate at once.  ``"reference"`` falls back to
        per-candidate :meth:`refined_rho`.  Results are identical across
        engines."""
        gpu_sets = [np.asarray(g) for g in gpu_sets]
        if not gpu_sets:
            return []
        if self.engine == "incremental":
            starts = [float(self.R[g].max()) if len(g) else 0.0
                      for g in gpu_sets]
            ps = np.empty(len(gpu_sets), dtype=np.int64)
            n_srv = np.empty(len(gpu_sets), dtype=np.int64)
            ys = np.empty((len(gpu_sets), self.cluster.num_servers),
                          dtype=np.int64)
            for c, (g, start) in enumerate(zip(gpu_sets, starts)):
                ys[c] = self._y_of(g)
                ps[c], n_srv[c] = self._probe_p(job, ys[c], start)
            contention.EVAL_COUNTS["probes"] += len(gpu_sets)
            if self.cluster.is_heterogeneous:
                speed, bw_sh, bw_iso = contention._hetero_mins(
                    self.cluster, ys > 0)
                taus = contention.scalar_tau_many(
                    self.cluster, job, ps, n_srv, speed=speed,
                    bw_shared=bw_sh, bw_isolated=bw_iso)
            else:
                taus = contention.scalar_tau_many(self.cluster, job, ps, n_srv)
            return [(slots_for(job.iters, float(tau)), start)
                    for tau, start in zip(taus, starts)]
        if self.engine != "batched":
            return [self.refined_rho(job, g) for g in gpu_sets]
        P = len(self.placed_jobs)
        C = len(gpu_sets)
        starts = [float(self.R[g].max()) if len(g) else 0.0 for g in gpu_sets]
        Y = np.zeros((C, P + 1, self.cluster.num_servers), dtype=np.int64)
        active = np.zeros((C, P + 1), dtype=bool)
        placed_Y = np.asarray(self.placed_y, dtype=np.int64).reshape(
            P, self.cluster.num_servers)
        for c, (g, start) in enumerate(zip(gpu_sets, starts)):
            active[c, :P] = self._overlaps(start)
            Y[c, :P] = placed_Y
            Y[c, P] = self._y_of(g)
            active[c, P] = True
        model = evaluate_many(self.cluster, self.placed_jobs + [job], Y,
                              active=active)
        return [(slots_for(job.iters, float(model.tau[c, P])), starts[c])
                for c in range(C)]

    def commit(self, job: Job, gpus: np.ndarray, rho: float, start: float,
               u: float) -> None:
        """Charge ``rho / u`` to the chosen GPUs and record the placement
        (Eq. 15 accounting + the rho-hat snapshot)."""
        self.U[gpus] += rho / u
        self.R[gpus] = start + rho
        jid = job.jid
        prev = self._entry_of.get(jid, -1)
        self.assignment.append((jid, gpus))
        y = self._y_of(gpus)
        self.placed_jobs.append(job)
        self.placed_y.append(y)
        if prev < 0:                  # first segment sets the job's start
            self.est_start[jid] = start
        self.est_finish[jid] = start + rho
        self.seg_rho.append(rho)
        self.seg_start.append(start)
        self.seg_quota.append(float(job.iters))
        self.seg_prev.append(prev)
        self.seg_row.append(len(self.placed_jobs) - 1)
        self.placed_fin.append(start + rho)
        self._entry_of[jid] = len(self.assignment) - 1
        G = job.num_gpus
        fin = start + rho
        for s, ys in enumerate(y.tolist()):
            if 0 < ys < G:
                if not self._fin_owned[s]:       # copy-on-first-write
                    self._straddle_fin[s] = list(self._straddle_fin[s])
                    self._fin_owned[s] = True
                bisect.insort(self._straddle_fin[s], fin)
        if self.commit_hook is not None:
            self.commit_hook(job, gpus, rho, start)

    def observe_finish(self, job: Job, gpus: np.ndarray,
                       finish: float) -> None:
        """Completion feedback: replace ``job``'s *estimated* finish with
        its observed (simulated or measured) one.

        The online epoch loop never looks back, so by default placements
        keep pricing contention against the rho-hat estimates.  A
        long-running scheduler that watches real executions
        (``repro.service`` with ``feedback="actual"``) calls this when a
        job completes: the rho_hat(y^k) overlap snapshot -- est_finish and
        the per-server straddler suffix-count lists -- is updated so later
        probes see the job gone at its actual finish, and the real-time
        clocks of GPUs last written by this job are pulled back so the
        arrival loop can start successors earlier.  This deliberately
        changes future decisions (it is the feedback extension, not the
        bit-identical default)."""
        jid = job.jid
        old = self.est_finish.get(jid)
        if old is None or old == finish:
            return
        gpus = np.asarray(gpus)
        self.est_finish[jid] = finish
        entry = self._entry_of.get(jid, -1)
        if entry >= 0:                 # keep the row finish in sync
            self.placed_fin[self.seg_row[entry]] = finish
        y = self._y_of(gpus)
        G = job.num_gpus
        for s, ys in enumerate(y.tolist()):
            if 0 < ys < G:
                if not self._fin_owned[s]:       # copy-on-first-write
                    self._straddle_fin[s] = list(self._straddle_fin[s])
                    self._fin_owned[s] = True
                fin = self._straddle_fin[s]
                i = bisect.bisect_left(fin, old)
                if i < len(fin) and fin[i] == old:
                    fin.pop(i)
                bisect.insort(fin, finish)
        # A GPU whose real-time clock was set by this very job frees at
        # the observed finish instead of the estimate.
        mask = self.R[gpus] == old
        self.R[gpus[mask]] = finish


# A picker maps (state, job, rho_nom, u, theta) -> gpu ids or None.
Picker = Callable[[PlacementState, Job, float, float, float],
                  "np.ndarray | None"]


class SharedState:
    """A :class:`PlacementState` shared by several speculative branches.

    The speculative bisection evaluates many thetas off one placement
    history; branches read the shared state freely and :meth:`acquire` an
    exclusive copy only when they are about to commit.  ``refs`` counts
    the live branches: acquiring with siblings still attached clones
    (:meth:`PlacementState.clone`, itself copy-on-write), acquiring as the
    sole owner reuses the state in place -- so a run that never diverges
    costs exactly one state, like the sequential oracle."""

    __slots__ = ("state", "refs")

    def __init__(self, state: PlacementState, refs: int = 1):
        self.state = state
        self.refs = refs

    def split(self, n_children: int) -> None:
        """Replace this holder's one reference by ``n_children`` of them."""
        self.refs += n_children - 1

    def acquire(self) -> "SharedState":
        """An exclusively-owned holder, cloning only if siblings remain."""
        if self.refs <= 1:
            return self
        self.refs -= 1
        return SharedState(self.state.clone())

    def release(self) -> None:
        """Drop one reference (a branch that failed or finished)."""
        self.refs -= 1


def try_place(state: PlacementState, job: Job, picker: Picker,
              rho_nom: float, u: float, theta: float, tries: int = 4,
              hint: "np.ndarray | None" = None) -> bool:
    """Pick GPUs with the nominal-estimate filter, refine rho_hat(y^k) for
    the chosen set, and re-check the Eq. (16) budget.  If the refined charge
    overflows theta on some GPU, re-filter with the refined estimate (which
    excludes the marginal GPUs) and retry -- mirroring the paper's
    "re-evaluate after the schedule is known" loop of Fig. 3.

    ``hint`` (optional) is a warm-start GPU set -- typically the job's
    placement from the previous theta of :func:`bisect_theta` -- committed
    directly if it passes the refined budget re-check, before the picker
    runs at all.

    rho_hat(y^k) is a pure function of the GPU set (the overlap snapshot is
    fixed until a commit), so candidate scores are memoised across tries;
    under the "batched" engine the escalation ladder's candidate sets are
    additionally pre-scored in a single ``evaluate_many`` pass.  (The
    ladder escalates by the plain 1.05 factor -- a lower bound on the real
    escalation ``max(rho, rho_try * 1.05)`` -- so when a refined rho jumps
    past it, the loop below falls back to scoring the unseen candidate
    individually; the result is identical either way.)"""
    scored: dict[tuple, tuple[float, float]] = {}
    if hint is not None:
        gpus = np.asarray(hint)
        rho, start = state.refined_rho(job, gpus)
        # max-then-add equals elementwise add-then-max (float addition is
        # monotone), so one scalar comparison decides the Eq. (16) check.
        if float(state.U[gpus].max()) + rho / u <= theta + 1e-9:
            state.commit(job, gpus, rho, start, u)
            return True
        scored[gpus.tobytes()] = (rho, start)
    # The ladder pre-calls the picker speculatively, which would desync a
    # stateful picker (e.g. RAND's rng): such pickers set ``stateful=True``
    # and are scored per-try only.
    if state.engine == "batched" and tries > 1 \
            and not getattr(picker, "stateful", False):
        ladder: dict[tuple, np.ndarray] = {}
        r = rho_nom
        for _ in range(tries):
            g = picker(state, job, r, u, theta)
            if g is None:
                break
            g = np.asarray(g)
            ladder.setdefault(tuple(g.tolist()), g)
            r *= 1.05
        if len(ladder) > 1:
            scored.update(zip(ladder, state.refined_rho_many(
                job, list(ladder.values()))))
    rho_try = rho_nom
    for _ in range(tries):
        gpus = picker(state, job, rho_try, u, theta)
        if gpus is None:
            return False
        gpus = np.asarray(gpus)
        key = gpus.tobytes()
        if key not in scored:
            scored[key] = state.refined_rho(job, gpus)
        rho, start = scored[key]
        if float(state.U[gpus].max()) + rho / u <= theta + 1e-9:
            state.commit(job, gpus, rho, start, u)
            return True
        rho_try = max(rho, rho_try * 1.05)
    return False


def _theta_runs(thetas: np.ndarray, keys: np.ndarray) -> list[np.ndarray]:
    """Split an ascending theta vector into runs of equal ``keys``."""
    cuts = np.flatnonzero(keys[1:] != keys[:-1]) + 1
    return np.split(thetas, cuts)


def try_place_group(thetas, shared: SharedState, job: Job, picker: Picker,
                    rho_nom: float, u: float, tries: int = 4
                    ) -> list[tuple[np.ndarray, "SharedState | None", bool]]:
    """:func:`try_place` for a whole group of thetas sharing one history.

    ``thetas`` (ascending) all reached this placement step with identical
    committed placements (held by ``shared``).  The group is advanced in
    lockstep and split only where the per-theta decisions of the
    sequential :func:`try_place` actually diverge:

      * the picker's feasible pool is the threshold set
        ``U + rho/u <= theta + 1e-9``, so thetas whose pools coincide pick
        the same GPUs (pools are nested in theta; the picker must declare
        this dependence with ``picker.theta_pool = True``);
      * the refined Eq. (16) re-check passes exactly for
        ``theta + 1e-9 >= max(U[gpus] + rho/u)``, so a group splits into a
        committing upper range and a retrying lower range.

    Returns ``(sub_thetas, shared_state, placed)`` triples covering
    ``thetas``; failed subgroups carry ``None``.  Decision-for-decision
    identical to running :func:`try_place` per theta, with states cloned
    only at divergence points (see :class:`SharedState`).
    """
    if not getattr(picker, "theta_pool", False):
        raise ValueError(
            f"picker {getattr(picker, '__name__', picker)!r} is not marked "
            "theta_pool; speculative placement needs theta to enter only "
            "through the U + rho/u <= theta feasibility pool")
    thetas = np.asarray(thetas, dtype=np.float64)
    if len(thetas) == 1 and shared.refs <= 1:
        # Singleton group holding its state exclusively: no split can
        # trigger and no sibling reads the state, so run the plain loop
        # (same decisions, none of the group bookkeeping).  This is the
        # dominant case once lineages have diverged.
        ok = try_place(shared.state, job, picker, rho_nom, u,
                       float(thetas[0]), tries=tries)
        return [(thetas, shared if ok else None, ok)]
    out: list[tuple[np.ndarray, SharedState | None, bool]] = []
    # Worklist items: (thetas, shared holder, rho_try, memoised scores).
    # Scores are pure functions of (state, gpu set) and every branch of a
    # work item reads the same un-mutated state, so the memo is shared.
    work = [(thetas, shared, rho_nom, {})]
    for _ in range(tries):
        next_work = []
        for th_g, holder, rho_try, scored in work:
            state = holder.state
            # Pool split: group thetas by how many GPUs clear the
            # rho_try-filter.  Equal counts <=> equal pools (threshold
            # sets are nested), hence identical picker decisions.  The
            # common no-split case needs only the two extreme counts.
            v = state.U + rho_try / u
            if len(th_g) == 1 or int((v <= th_g[0] + 1e-9).sum()) \
                    == int((v <= th_g[-1] + 1e-9).sum()):
                subs = [th_g]
            else:
                counts = np.searchsorted(np.sort(v), th_g + 1e-9,
                                         side="right")
                subs = _theta_runs(th_g, counts)
            outcomes = []      # (sub, kind, payload)
            n_live = 0
            for sub in subs:
                gpus = picker(state, job, rho_try, u, float(sub[0]))
                if gpus is None:
                    outcomes.append((sub, "fail", None))
                    continue
                gpus = np.asarray(gpus)
                key = gpus.tobytes()
                if key not in scored:
                    scored[key] = state.refined_rho(job, gpus)
                rho, start = scored[key]
                passes = sub + 1e-9 >= (state.U[gpus] + rho / u).max()
                lo, hi = sub[~passes], sub[passes]
                if len(hi):
                    outcomes.append((hi, "commit", (gpus, rho, start)))
                    n_live += 1
                if len(lo):
                    outcomes.append((lo, "retry", max(rho, rho_try * 1.05)))
                    n_live += 1
            holder.split(n_live)       # fails drop their reference
            for sub, kind, payload in outcomes:
                if kind == "fail":
                    out.append((sub, None, False))
                elif kind == "commit":
                    own = holder.acquire()
                    gpus, rho, start = payload
                    own.state.commit(job, gpus, rho, start, u)
                    out.append((sub, own, True))
                else:
                    next_work.append((sub, holder, payload, scored))
        work = next_work
        if not work:
            break
    for th_g, holder, _, _ in work:    # tries exhausted
        holder.release()
        out.append((th_g, None, False))
    return out


def finalize(state: PlacementState, n_jobs: int, theta: float,
             kappa: int | None, policy: str) -> ScheduleResult:
    """Freeze a placement attempt into a :class:`ScheduleResult`."""
    est_start = np.full(n_jobs, -1.0)
    est_finish = np.full(n_jobs, -1.0)
    for j, s in state.est_start.items():
        est_start[j] = s
        est_finish[j] = state.est_finish[j]
    return ScheduleResult(assignment=state.assignment, est_start=est_start,
                          est_finish=est_finish,
                          est_makespan=float(est_finish.max(initial=0.0)),
                          theta=theta, kappa=kappa, policy=policy,
                          max_busy_time=float(state.U.max(initial=0.0)),
                          quotas=np.asarray(state.seg_quota)
                          if state.preempted else None)


# --------------------------------------------------------------------------
# Generic control loops
# --------------------------------------------------------------------------


def probe_thetas(left: float, right: float, levels: int,
                 cutoff: float = -np.inf) -> list[float]:
    """The geometric probe ladder of the speculative bisection.

    Descends from the bracket midpoint assuming each probe comes back
    feasible -- the sequential bisection's next theta after a feasible
    midpoint is the midpoint of the *lower* half, so the ladder is the
    exact theta sequence of up to ``levels`` consecutive
    feasible-tightening steps, spaced geometrically (bracket-halving)
    inside ``[left, right]``.  Probing the descending chain (rather than
    the full decision tree) keeps the speculative attempts clustered:
    consecutive probes share almost all their placement decisions, and a
    mispredicted (infeasible) probe simply ends the committed walk early.

    ``cutoff`` prunes ladder tail entries that are almost certainly
    infeasible (probing those would buy nothing: an infeasible result
    ends the committed walk anyway, and near-boundary failures are the
    expensive ones).  The bracket midpoint is always kept, so every round
    still commits at least one bisection decision.
    """
    nodes: list[float] = []
    hi = right
    for _ in range(levels):
        if left > hi:
            break
        mid = 0.5 * (left + hi)
        if nodes and mid < cutoff:
            break
        nodes.append(mid)
        hi = mid - 1.0
    return nodes


def bisect_theta(attempt: Callable[..., "ScheduleResult | None"],
                 horizon: int, policy: str,
                 warm_start: bool = False,
                 attempt_many: "Callable[[list[float]], dict[float, ScheduleResult | None]] | None" = None,
                 levels: int = 3, floor: float = -np.inf,
                 prune: bool = True) -> ScheduleResult:
    """Algorithm 1's outer loop: bisection on the busy-time budget theta_u.

    ``attempt(theta)`` returns the best schedule feasible under that
    budget, or None.  Feasible => tighten (search below theta);
    infeasible => relax.  Matches the paper's "theta_u^f is the maximum
    execution time limit returned by policy f" for the baselines too.

    With ``warm_start=True`` the attempt is called as ``attempt(theta,
    prev)`` where ``prev`` is the schedule committed at the previous
    feasible theta (or None); policies use its placements as the initial
    candidate set (see ``try_place``'s ``hint``), so each bisection step
    starts from a known-good placement instead of searching from scratch.

    With ``attempt_many`` set (and ``warm_start`` off -- a warm start
    makes each attempt depend on the previous one, which cannot be
    speculated), the bisection runs **speculatively**: each round scores
    every theta of the :func:`probe_thetas` ladder in one batched
    ``attempt_many`` call, then commits bisection decisions by walking
    the exact sequential update rule over the precomputed results until
    the next theta falls outside the ladder (the first mispredicted,
    i.e. infeasible, probe).  Unconsumed probe results are discarded, so
    the final schedule -- best feasible theta, its kappa, its placements
    -- is bit-identical to the sequential oracle's.

    ``prune=True`` (the default) additionally drops ladder entries in the
    bottom quarter of the bracket -- the right trade when every extra
    probe walks its own per-branch placement lineage.  Engines whose
    marginal branch cost is near zero (the columnar placement program,
    where an extra theta is one more row of the same array ops) pass
    ``prune=False`` to keep the whole ladder and commit several bisection
    decisions per round.  Pruning never changes the result, only how
    many rounds the bisection needs.
    """
    best: ScheduleResult | None = None
    prev: ScheduleResult | None = None
    left, right = 1.0, float(horizon)
    speculative = attempt_many is not None and not warm_start and levels > 1
    results: dict[float, ScheduleResult | None] = {}
    while left <= right:
        theta = 0.5 * (left + right)
        if speculative:
            if theta not in results:
                # Results are cached across rounds: a probe evaluated but
                # not yet consumed (the walk broke off elsewhere) is free
                # when a later bracket's midpoint lands on it.  Ladder
                # entries are pruned below (a) the policy's feasibility
                # floor (e.g. the largest single-job charge rho_nom/u: no
                # GPU could fit that job under a smaller budget), (b) the
                # bottom quarter of the bracket, where the committed
                # `left` (the largest theta proven infeasible, plus one)
                # says infeasibility is close -- an infeasible probe ends
                # the walk anyway, and near-boundary failures are the
                # expensive attempts.  Pruning never changes the result:
                # a pruned theta the walk does need is simply evaluated
                # as the next round's bracket midpoint.
                cut = max(floor, left + (right - left) / 4.0) if prune \
                    else floor
                todo = [th for th in probe_thetas(left, right, levels, cut)
                        if th not in results]
                results.update(attempt_many(todo))
            while left <= right:
                theta = 0.5 * (left + right)
                if theta not in results:
                    break           # mispredicted: start the next round
                cand = results[theta]
                if cand is not None:
                    prev = cand
                    if best is None or cand.est_makespan <= best.est_makespan:
                        best = cand
                    right = theta - 1.0
                else:
                    left = theta + 1.0
            continue
        cand = attempt(theta, prev) if warm_start else attempt(theta)
        if cand is not None:
            prev = cand
            if best is None or cand.est_makespan <= best.est_makespan:
                best = cand
            right = theta - 1.0
        else:
            left = theta + 1.0
    if best is None:
        raise RuntimeError(f"{policy}: no feasible schedule within horizon; "
                           "increase T")
    return best


# An online chooser places (and commits) one arrived job, or returns False.
Chooser = Callable[[PlacementState, Job, float], bool]


def schedule_arrivals(request: ScheduleRequest, choose: Chooser,
                      policy: str) -> ScheduleResult:
    """The online epoch loop shared by every policy's ``arrivals`` path.

    Jobs are visited in (arrival, G_j) order; the real-time clocks are
    advanced to each arrival instant before the policy's ``choose``
    places-and-commits the job against the live busy-time clocks.  There
    is no theta bisection online (the stream is open-ended), so the
    budget is the horizon, matching the paper's RAND convention.
    """
    order = sorted(request.arrival_items(),
                   key=lambda it: (it[1], it[0].num_gpus, it[0].jid))
    state = PlacementState(request.cluster,
                           engine=request.params.get("engine"))
    theta = float(request.horizon)
    for job, arrival in order:
        state.advance_to(arrival)
        if not choose(state, job, theta):
            raise RuntimeError(f"{policy}: cannot place job {job.jid} "
                               f"arriving at slot {arrival}")
    return finalize(state, len(request.jobs), theta, None, policy)


def pick_best_finish(state: PlacementState, job: Job, pickers: list[Picker],
                     rho_nom: float, u: float, theta: float) -> bool:
    """Adaptive pack-or-spread: evaluate every picker's placement with the
    refined rho_hat(y^k) and commit whichever finishes earliest.  Shared by
    SJF-BCO+ and the online path (where queueing delay IS the est-finish
    penalty)."""
    cands = []
    for picker in pickers:
        gpus = picker(state, job, rho_nom, u, theta)
        if gpus is not None:
            cands.append(np.asarray(gpus))
    best = None  # (est_finish, gpus, rho, start)
    for gpus, (rho, start) in zip(cands, state.refined_rho_many(job, cands)):
        if float(state.U[gpus].max()) + rho / u > theta + 1e-9:
            continue
        if best is None or start + rho < best[0]:
            best = (start + rho, gpus, rho, start)
    if best is None:
        return False
    _, gpus, rho, start = best
    state.commit(job, gpus, rho, start, u)
    return True


# Re-exported here so the columnar engine is reachable from the one
# scheduling surface (placed after ScheduleResult: columnar.py imports it
# lazily for result construction).
from repro.core.columnar import ColumnarPlacement  # noqa: E402

__all__ = [
    "ScheduleRequest", "ScheduleResult", "SchedulingPolicy",
    "register_policy", "get_policy", "list_policies",
    "register_chooser", "get_chooser", "list_choosers", "ChooserFactory",
    "PlacementState", "Picker", "Chooser", "SharedState",
    "ColumnarPlacement", "PLACEMENTS", "resolve_placement",
    "try_place", "try_place_group", "finalize", "bisect_theta",
    "probe_thetas", "schedule_arrivals",
    "pick_best_finish", "nominal_rho", "rho_hat",
]
