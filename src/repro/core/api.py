"""Unified scheduling API: one request/result pair, a policy registry, and
the shared busy-time machinery every policy builds on.

The paper's Fig. 3 loop is "search a placement -> evaluate it under
contention".  Every scheduler in this repo is an instance of that loop, so
the public surface is deliberately small:

  * :class:`ScheduleRequest` -- cluster, jobs, optional arrival times,
    horizon T, slack factor u, and policy-specific ``params``.  Batch
    scheduling (the paper's §4 setting, all jobs known at t=0) is the
    ``arrivals=None`` special case of the same code path that serves
    online streams.
  * :class:`ScheduleResult` -- placement + busy-time certificate, ready
    for :func:`repro.core.simulator.simulate`.
  * :func:`register_policy` / :func:`get_policy` / :func:`list_policies`
    -- a decorator-based registry; ``get_policy(name)(request)`` runs any
    registered policy through one signature.

Supported building blocks for policy authors (promoted out of
``sjf_bco.py``, which previously kept them private):

  * :class:`PlacementState` -- busy-time clocks U (Eq. 15/16), real-time
    clocks R, and the placed-job snapshot used by the rho_hat(y^k)
    refinement of Eq. (8).
  * :func:`try_place` -- nominal-filter -> refine -> re-check loop
    (the Fig. 3 "re-evaluate after the schedule is known" retry).
  * :func:`bisect_theta` -- Algorithm 1's bisection on the per-GPU
    execution-time budget theta_u, generic over the per-theta attempt.
  * :func:`schedule_arrivals` -- the online epoch loop: advance the real
    clocks to each arrival and greedily place with a policy-supplied
    chooser.
  * :func:`finalize`, :func:`nominal_rho`, :func:`rho_hat`.

A new policy is ~20 lines::

    @register_policy("my-policy")
    def my_policy(request: ScheduleRequest) -> ScheduleResult:
        def attempt(theta):
            state = PlacementState(request.cluster)
            for job in request.jobs:
                if not try_place(state, job, my_picker,
                                 nominal_rho(request.cluster, job),
                                 request.u, theta):
                    return None
            return finalize(state, len(request.jobs), theta, None, "MINE")
        return bisect_theta(attempt, request.horizon, "MINE")
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.cluster import Cluster
from repro.core.contention import evaluate, tau_bounds
from repro.core.jobs import Job

# --------------------------------------------------------------------------
# Request / result
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScheduleRequest:
    """One scheduling problem instance.

    ``arrivals`` (optional) gives the arrival slot of ``jobs[i]`` as
    ``arrivals[i]``; ``None`` -- or an all-zero array -- is the batch
    setting where every job is available at t=0.  ``params`` carries
    policy-specific knobs (e.g. ``{"kappas": [8]}`` for SJF-BCO,
    ``{"seed": 1}`` for RAND).
    """

    cluster: Cluster
    jobs: list[Job]
    arrivals: np.ndarray | None = None
    horizon: int = 1200
    u: float = 1.5
    params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.jobs:
            raise ValueError("request needs at least one job")
        for i, j in enumerate(self.jobs):
            # Assignments carry job ids and the simulator indexes ``jobs``
            # with them, so ids must be 0..n-1 in list order.
            if j.jid != i:
                raise ValueError(
                    f"jobs[{i}].jid == {j.jid}; job ids must equal their "
                    "list index (renumber with dataclasses.replace)")
        if self.arrivals is not None:
            arr = np.asarray(self.arrivals)
            if arr.shape != (len(self.jobs),):
                raise ValueError(
                    f"arrivals shape {arr.shape} != ({len(self.jobs)},)")
            if np.any(arr < 0):
                raise ValueError("arrival slots must be >= 0")
            object.__setattr__(self, "arrivals", arr)

    @property
    def is_batch(self) -> bool:
        """True when every job is available at t=0 (the paper's setting)."""
        return self.arrivals is None or not np.any(self.arrivals > 0)

    def arrival_of(self, job: Job) -> int:
        if self.arrivals is None:
            return 0
        return int(self.arrivals[self.jobs.index(job)])

    def arrival_items(self) -> list[tuple[Job, int]]:
        """(job, arrival) pairs, in request order."""
        if self.arrivals is None:
            return [(j, 0) for j in self.jobs]
        return [(j, int(t)) for j, t in zip(self.jobs, self.arrivals)]


@dataclasses.dataclass
class ScheduleResult:
    """Result of a scheduling policy, ready for the simulator.

    Subsumes the legacy ``Schedule``: ``assignment`` is the ordered
    (job id, gpu ids) placement, ``theta`` the busy-time budget the
    schedule was certified against (Eq. 16), ``max_busy_time`` the
    realised max U (== theta_tilde of Lemma 2 for the tightest feasible
    theta).
    """

    assignment: list[tuple[int, np.ndarray]]   # (job id, gpu ids), order
    est_start: np.ndarray
    est_finish: np.ndarray
    est_makespan: float
    theta: float
    kappa: int | None = None
    policy: str = ""
    max_busy_time: float = 0.0


@runtime_checkable
class SchedulingPolicy(Protocol):
    """A scheduling policy: one problem instance in, one schedule out."""

    def __call__(self, request: ScheduleRequest) -> ScheduleResult: ...


# --------------------------------------------------------------------------
# Policy registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, SchedulingPolicy] = {}
_BUILTINS_LOADED = False


def _load_builtins() -> None:
    """Import the built-in policy modules so their decorators run.

    Lazy so ``repro.core.api`` has no imports of the modules that import
    it -- this is what removes the old ``POLICIES["sjf-bco"] = None``
    import-cycle patch.
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from repro.core import baselines, extensions, sjf_bco  # noqa: F401


def register_policy(name: str, *aliases: str
                    ) -> Callable[[SchedulingPolicy], SchedulingPolicy]:
    """Decorator: make ``fn`` available as ``get_policy(name)``."""

    def deco(fn: SchedulingPolicy) -> SchedulingPolicy:
        for key in (name, *aliases):
            key = key.lower()        # lookups lowercase too
            if key in _REGISTRY and _REGISTRY[key] is not fn:
                raise ValueError(f"policy {key!r} already registered")
            _REGISTRY[key] = fn
        fn.policy_name = name.lower()   # type: ignore[attr-defined]
        return fn

    return deco


def get_policy(name: str) -> SchedulingPolicy:
    """Look up a registered policy by name (case-insensitive)."""
    _load_builtins()
    key = name.lower()
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown policy {name!r}; registered: {', '.join(list_policies())}")
    return _REGISTRY[key]


def list_policies() -> list[str]:
    """Sorted names of every registered policy."""
    _load_builtins()
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# Estimates (Table 1 / §5.1)
# --------------------------------------------------------------------------


def nominal_rho(cluster: Cluster, job: Job) -> float:
    """Contention-free lower estimate (tau at b_intra, single server)."""
    lo, _ = tau_bounds(cluster, job)
    phi = max(1, int(np.floor(1.0 / lo)))
    return float(int(np.ceil(job.iters / phi)))


def rho_hat(cluster: Cluster, job: Job) -> float:
    """Schedule-independent mid-bracket estimate, used by theory checks."""
    lo, hi = tau_bounds(cluster, job)
    tau = 0.5 * (lo + hi)
    phi = max(1, int(np.floor(1.0 / tau)))
    return float(int(np.ceil(job.iters / phi)))


# --------------------------------------------------------------------------
# Busy-time accounting (§5-3)
# --------------------------------------------------------------------------


class PlacementState:
    """Per-attempt scheduler state: busy clocks U, real clocks R, and the
    snapshot of placed jobs used for the rho_hat(y^k) refinement."""

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self.U = np.zeros(cluster.num_gpus)    # busy-time clock (Eq. 15/16)
        self.R = np.zeros(cluster.num_gpus)    # real-time clock (gang start)
        self.assignment: list[tuple[int, np.ndarray]] = []
        self.placed_jobs: list[Job] = []
        self.placed_y: list[np.ndarray] = []   # per-server GPU counts
        self.est_start: dict[int, float] = {}
        self.est_finish: dict[int, float] = {}

    def _y_of(self, gpus: np.ndarray) -> np.ndarray:
        y = np.zeros(self.cluster.num_servers, dtype=np.int64)
        np.add.at(y, self.cluster.gpu_server[gpus], 1)
        return y

    def advance_to(self, t: float) -> None:
        """Advance the real-time clocks to ``t`` (an arrival instant): a
        GPU idle before the arrival cannot have been used earlier."""
        np.maximum(self.R, float(t), out=self.R)

    def refined_rho(self, job: Job, gpus: np.ndarray) -> tuple[float, float]:
        """rho_hat_j(y^k): Eq. (8) against placed jobs overlapping the
        estimated gang start.  Returns (rho_hat, est_start)."""
        start = float(self.R[gpus].max()) if len(gpus) else 0.0
        y_j = self._y_of(gpus)
        overlap_jobs, overlap_y = [], []
        for jb, y in zip(self.placed_jobs, self.placed_y):
            if self.est_finish[jb.jid] > start + 1e-9:
                overlap_jobs.append(jb)
                overlap_y.append(y)
        Y = np.vstack(overlap_y + [y_j]) if overlap_y else y_j[None, :]
        model = evaluate(self.cluster, overlap_jobs + [job], Y)
        tau = float(model.tau[-1])
        phi = max(1, int(np.floor(1.0 / tau)))
        return float(int(np.ceil(job.iters / phi))), start

    def commit(self, job: Job, gpus: np.ndarray, rho: float, start: float,
               u: float) -> None:
        self.U[gpus] += rho / u
        self.R[gpus] = start + rho
        self.assignment.append((job.jid, gpus))
        self.placed_jobs.append(job)
        self.placed_y.append(self._y_of(gpus))
        self.est_start[job.jid] = start
        self.est_finish[job.jid] = start + rho


# A picker maps (state, job, rho_nom, u, theta) -> gpu ids or None.
Picker = Callable[[PlacementState, Job, float, float, float],
                  "np.ndarray | None"]


def try_place(state: PlacementState, job: Job, picker: Picker,
              rho_nom: float, u: float, theta: float, tries: int = 4) -> bool:
    """Pick GPUs with the nominal-estimate filter, refine rho_hat(y^k) for
    the chosen set, and re-check the Eq. (16) budget.  If the refined charge
    overflows theta on some GPU, re-filter with the refined estimate (which
    excludes the marginal GPUs) and retry -- mirroring the paper's
    "re-evaluate after the schedule is known" loop of Fig. 3."""
    rho_try = rho_nom
    for _ in range(tries):
        gpus = picker(state, job, rho_try, u, theta)
        if gpus is None:
            return False
        gpus = np.asarray(gpus)
        rho, start = state.refined_rho(job, gpus)
        if np.all(state.U[gpus] + rho / u <= theta + 1e-9):
            state.commit(job, gpus, rho, start, u)
            return True
        rho_try = max(rho, rho_try * 1.05)
    return False


def finalize(state: PlacementState, n_jobs: int, theta: float,
             kappa: int | None, policy: str) -> ScheduleResult:
    """Freeze a placement attempt into a :class:`ScheduleResult`."""
    est_start = np.full(n_jobs, -1.0)
    est_finish = np.full(n_jobs, -1.0)
    for j, s in state.est_start.items():
        est_start[j] = s
        est_finish[j] = state.est_finish[j]
    return ScheduleResult(assignment=state.assignment, est_start=est_start,
                          est_finish=est_finish,
                          est_makespan=float(est_finish.max(initial=0.0)),
                          theta=theta, kappa=kappa, policy=policy,
                          max_busy_time=float(state.U.max(initial=0.0)))


# --------------------------------------------------------------------------
# Generic control loops
# --------------------------------------------------------------------------


def bisect_theta(attempt: Callable[[float], "ScheduleResult | None"],
                 horizon: int, policy: str) -> ScheduleResult:
    """Algorithm 1's outer loop: bisection on the busy-time budget theta_u.

    ``attempt(theta)`` returns the best schedule feasible under that
    budget, or None.  Feasible => tighten (search below theta);
    infeasible => relax.  Matches the paper's "theta_u^f is the maximum
    execution time limit returned by policy f" for the baselines too.
    """
    best: ScheduleResult | None = None
    left, right = 1.0, float(horizon)
    while left <= right:
        theta = 0.5 * (left + right)
        cand = attempt(theta)
        if cand is not None:
            if best is None or cand.est_makespan <= best.est_makespan:
                best = cand
            right = theta - 1.0
        else:
            left = theta + 1.0
    if best is None:
        raise RuntimeError(f"{policy}: no feasible schedule within horizon; "
                           "increase T")
    return best


# An online chooser places (and commits) one arrived job, or returns False.
Chooser = Callable[[PlacementState, Job, float], bool]


def schedule_arrivals(request: ScheduleRequest, choose: Chooser,
                      policy: str) -> ScheduleResult:
    """The online epoch loop shared by every policy's ``arrivals`` path.

    Jobs are visited in (arrival, G_j) order; the real-time clocks are
    advanced to each arrival instant before the policy's ``choose``
    places-and-commits the job against the live busy-time clocks.  There
    is no theta bisection online (the stream is open-ended), so the
    budget is the horizon, matching the paper's RAND convention.
    """
    order = sorted(request.arrival_items(),
                   key=lambda it: (it[1], it[0].num_gpus, it[0].jid))
    state = PlacementState(request.cluster)
    theta = float(request.horizon)
    for job, arrival in order:
        state.advance_to(arrival)
        if not choose(state, job, theta):
            raise RuntimeError(f"{policy}: cannot place job {job.jid} "
                               f"arriving at slot {arrival}")
    return finalize(state, len(request.jobs), theta, None, policy)


def pick_best_finish(state: PlacementState, job: Job, pickers: list[Picker],
                     rho_nom: float, u: float, theta: float) -> bool:
    """Adaptive pack-or-spread: evaluate every picker's placement with the
    refined rho_hat(y^k) and commit whichever finishes earliest.  Shared by
    SJF-BCO+ and the online path (where queueing delay IS the est-finish
    penalty)."""
    best = None  # (est_finish, gpus, rho, start)
    for picker in pickers:
        gpus = picker(state, job, rho_nom, u, theta)
        if gpus is None:
            continue
        gpus = np.asarray(gpus)
        rho, start = state.refined_rho(job, gpus)
        if np.any(state.U[gpus] + rho / u > theta + 1e-9):
            continue
        if best is None or start + rho < best[0]:
            best = (start + rho, gpus, rho, start)
    if best is None:
        return False
    _, gpus, rho, start = best
    state.commit(job, gpus, rho, start, u)
    return True


__all__ = [
    "ScheduleRequest", "ScheduleResult", "SchedulingPolicy",
    "register_policy", "get_policy", "list_policies",
    "PlacementState", "Picker", "Chooser",
    "try_place", "finalize", "bisect_theta", "schedule_arrivals",
    "pick_best_finish", "nominal_rho", "rho_hat",
]
