"""Cluster model for RAR-DDLS (paper §4.1), with optional heterogeneity.

A multi-tenant GPU cluster: a set of servers ``s ∈ S``, each with GPU
capacity ``O_s``; fast intra-server interconnect bandwidth ``b_i`` (NVLink
class) and slow, contended inter-server bandwidth ``b_e`` (Ethernet class),
with ``b_i >> b_e``.  The paper assumes homogeneous GPUs with compute speed
``C`` (amount of gradient data reduced per time-slot) and a single shared
``b_e``; this module generalises both while keeping the homogeneous case
bit-identical:

  * ``gpu_speeds`` -- optional per-GPU compute speeds.  A ring is paced by
    its slowest member (Eq. (1) evaluates at the minimum ``C`` over the
    job's GPUs), so engines only ever need the per-server *speed floor*
    (slowest GPU on each server) and derive a job's effective speed from
    its occupancy row ``y_j``.
  * ``links`` -- optional per-server uplink classes ``(bandwidth, kind)``
    with ``kind in {"shared", "isolated"}``.  Shared uplinks contend and
    pay the Eq. (8) divisor ``f(alpha, k)``; isolated uplinks (private
    paths, arXiv:2308.05692) deliver their full bandwidth.  A straddling
    job's inter-server bandwidth is the worst over its occupied servers:
    ``min(min_iso_bw, min_shared_bw / f)``.

The contention-model constants (paper Eqs. 6-8):
  * ``xi1``  -- fraction of wall time a job actually contends (Eq. 7)
  * ``xi2``  -- per-server communication-overhead coefficient (gamma)
  * ``alpha`` -- bandwidth-sharing degradation slope, f(a,k) = k + a(k-1)
"""
from __future__ import annotations

import dataclasses
import functools
import numbers
from typing import Any, Sequence

import numpy as np

LINK_KINDS = ("shared", "isolated")


@dataclasses.dataclass(frozen=True)
class Cluster:
    """Static description of the multi-tenant GPU cluster.

    ``gpu_speed``/``b_inter`` remain the uniform defaults; ``gpu_speeds``
    (one entry per GPU) and ``links`` (one ``(bandwidth, kind)`` uplink per
    server) override them per-device.  ``b_intra`` stays a global scalar:
    intra-server fabrics are uncontended in the model and a single-server
    ring never crosses an uplink.
    """

    capacities: tuple[int, ...]      # O_s, GPUs per server
    b_intra: float = 300.0           # b^i, intra-server link bandwidth (GB/slot)
    b_inter: float = 1.25            # b^e, inter-server link bandwidth (GB/slot)
    gpu_speed: float = 50.0          # C, reduction throughput (GB/slot)
    xi1: float = 0.7                 # Eq. (7) contention duty-cycle
    xi2: float = 0.002               # gamma coefficient (slots per server spanned)
    alpha: float = 0.3               # degradation slope in f(alpha, k)
    gpu_speeds: tuple[float, ...] | None = None   # per-GPU C, len == num_gpus
    links: tuple[tuple[float, str], ...] | None = None  # per-server (bw, kind)

    def __post_init__(self) -> None:
        if not self.capacities:
            raise ValueError("cluster needs at least one server")
        if any(c <= 0 for c in self.capacities):
            raise ValueError("server capacities must be positive")
        for name in ("b_intra", "b_inter", "gpu_speed"):
            val = getattr(self, name)
            if not isinstance(val, numbers.Real):
                raise ValueError(
                    f"Cluster.{name} is the uniform scalar (got {type(val).__name__}); "
                    "per-device values go in 'gpu_speeds' (per GPU) or 'links' "
                    "(per server)"
                )
        if self.b_intra < self.b_inter:
            raise ValueError("paper assumes b_intra >> b_inter")
        if self.gpu_speeds is not None:
            if isinstance(self.gpu_speeds, numbers.Real):
                raise ValueError(
                    "Cluster.gpu_speeds is per-GPU (one entry per GPU); a single "
                    "uniform speed goes in the scalar 'gpu_speed' field"
                )
            speeds = tuple(float(v) for v in self.gpu_speeds)
            object.__setattr__(self, "gpu_speeds", speeds)
            if len(speeds) != self.num_gpus:
                raise ValueError(
                    f"Cluster.gpu_speeds has {len(speeds)} entries but the cluster "
                    f"has {self.num_gpus} GPUs (one speed per GPU)"
                )
            if any(v <= 0 for v in speeds):
                raise ValueError("Cluster.gpu_speeds entries must be positive")
        if self.links is not None:
            links = []
            for i, link in enumerate(self.links):
                try:
                    bw, kind = link
                except (TypeError, ValueError):
                    raise ValueError(
                        f"Cluster.links[{i}] must be a (bandwidth, kind) pair, "
                        f"got {link!r}"
                    ) from None
                if kind not in LINK_KINDS:
                    raise ValueError(
                        f"Cluster.links[{i}] kind must be one of {LINK_KINDS}, "
                        f"got {kind!r}"
                    )
                bw = float(bw)
                if bw <= 0:
                    raise ValueError(f"Cluster.links[{i}] bandwidth must be positive")
                if self.b_intra < bw:
                    raise ValueError(
                        f"Cluster.links[{i}] uplink bandwidth {bw} exceeds b_intra "
                        f"{self.b_intra}; the paper assumes b_intra >> uplink"
                    )
                links.append((bw, kind))
            object.__setattr__(self, "links", tuple(links))
            if len(links) != self.num_servers:
                raise ValueError(
                    f"Cluster.links has {len(links)} entries but the cluster has "
                    f"{self.num_servers} servers (one uplink per server)"
                )

    # ---- derived quantities -------------------------------------------------

    @functools.cached_property
    def num_servers(self) -> int:
        """Number of servers S."""
        return len(self.capacities)

    @functools.cached_property
    def num_gpus(self) -> int:
        """Total GPU count N = sum of the capacities."""
        return int(sum(self.capacities))

    # The derived arrays below are cached per instance (the scheduler and
    # simulator read them in every placement probe / event window).  The
    # dataclass is frozen, so the fields they derive from never change;
    # ``functools.cached_property`` writes straight to ``__dict__`` and
    # therefore works on frozen dataclasses.  Treat them as read-only.

    @functools.cached_property
    def capacities_array(self) -> np.ndarray:
        """Per-server GPU counts as an int64 array, shape [S]."""
        return np.asarray(self.capacities, dtype=np.int64)

    @functools.cached_property
    def gpu_server(self) -> np.ndarray:
        """Map global GPU id -> server id, shape [N]."""
        return np.repeat(np.arange(self.num_servers), self.capacities_array)

    @functools.cached_property
    def is_heterogeneous(self) -> bool:
        """True when any per-device value differs from the uniform scalars.

        Uniform arrays that merely restate ``gpu_speed``/``(b_inter,
        "shared")`` keep the fast scalar paths; uniform arrays at *other*
        values are heterogeneous (the scalar fields would price them wrong).
        """
        if self.gpu_speeds is not None and any(
            v != self.gpu_speed for v in self.gpu_speeds
        ):
            return True
        if self.links is not None and any(
            bw != self.b_inter or kind != "shared" for bw, kind in self.links
        ):
            return True
        return False

    @functools.cached_property
    def gpu_speeds_array(self) -> np.ndarray:
        """Per-GPU compute speed C, shape [N] (uniform fallback)."""
        if self.gpu_speeds is None:
            return np.full(self.num_gpus, float(self.gpu_speed))
        return np.asarray(self.gpu_speeds, dtype=np.float64)

    @functools.cached_property
    def server_speed_floor(self) -> np.ndarray:
        """Slowest GPU speed on each server, shape [S].

        Eq. (1) evaluates a ring at its slowest member; GPU assignment
        within a server is fungible, so the engines price a job at
        ``min(server_speed_floor[occupied servers])``.
        """
        return np.minimum.reduceat(
            self.gpu_speeds_array,
            np.concatenate([[0], np.cumsum(self.capacities_array)[:-1]]),
        )

    @functools.cached_property
    def uplink_bandwidth(self) -> np.ndarray:
        """Per-server uplink bandwidth, shape [S] (uniform b_inter fallback)."""
        if self.links is None:
            return np.full(self.num_servers, float(self.b_inter))
        return np.asarray([bw for bw, _ in self.links], dtype=np.float64)

    @functools.cached_property
    def uplink_isolated(self) -> np.ndarray:
        """Per-server bool: True when the uplink skips the f(alpha,k) divisor."""
        if self.links is None:
            return np.zeros(self.num_servers, dtype=bool)
        return np.asarray([kind == "isolated" for _, kind in self.links])

    @functools.cached_property
    def uplink_shared_or_inf(self) -> np.ndarray:
        """Shared-uplink bandwidth per server, +inf where isolated, shape [S]."""
        return np.where(self.uplink_isolated, np.inf, self.uplink_bandwidth)

    @functools.cached_property
    def uplink_isolated_or_inf(self) -> np.ndarray:
        """Isolated-uplink bandwidth per server, +inf where shared, shape [S]."""
        return np.where(self.uplink_isolated, self.uplink_bandwidth, np.inf)

    @functools.cached_property
    def _batch_key_cache(self) -> dict:
        """Scratch for :func:`repro.core.columnar.server_sums`: rows ->
        read-only flattened ``row * S + gpu_server`` bincount keys.  Purely
        derived from frozen fields, so caching on the instance is safe for
        the same reason as the properties above."""
        return {}

    def server_gpu_ids(self, s: int) -> np.ndarray:
        """Global GPU ids living on server ``s``."""
        offsets = np.concatenate([[0], np.cumsum(self.capacities_array)])
        return np.arange(offsets[s], offsets[s + 1])

    def placement_matrix(self, gpu_sets: Sequence[np.ndarray]) -> np.ndarray:
        """Build the Y matrix [J, S]: #GPUs of each job on each server."""
        srv = self.gpu_server
        out = np.zeros((len(gpu_sets), self.num_servers), dtype=np.int64)
        for j, gpus in enumerate(gpu_sets):
            if len(gpus) == 0:
                continue
            np.add.at(out[j], srv[np.asarray(gpus, dtype=np.int64)], 1)
        return out

    # ---- journal round-trip -------------------------------------------------

    def to_payload(self) -> dict[str, Any]:
        """JSON-safe description (``from_payload`` round-trips exactly)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_payload(cls, payload: dict[str, Any]) -> "Cluster":
        """Rebuild from :meth:`to_payload` output (JSON lists -> tuples)."""
        data = dict(payload)
        data["capacities"] = tuple(int(c) for c in data["capacities"])
        if data.get("gpu_speeds") is not None:
            data["gpu_speeds"] = tuple(float(v) for v in data["gpu_speeds"])
        if data.get("links") is not None:
            data["links"] = tuple(
                (float(bw), str(kind)) for bw, kind in data["links"]
            )
        return cls(**data)


def _draw_hetero(
    rng: np.random.Generator,
    capacities: tuple[int, ...],
    speed_tiers: Sequence[tuple[float, float]] | None,
    link_classes: Sequence[tuple[float, str, float]] | None,
) -> dict[str, Any]:
    """Per-server tier draws shared by ``philly_cluster`` and ``ClusterSpec``.

    ``speed_tiers`` is ``((speed, weight), ...)``: each server draws one
    tier and all its GPUs inherit it (servers are internally homogeneous,
    matching real multi-generation fleets).  ``link_classes`` is
    ``((bandwidth, kind, weight), ...)`` drawn per server uplink.
    """
    kwargs: dict[str, Any] = {}
    if speed_tiers:
        speeds = np.asarray([s for s, _ in speed_tiers], dtype=np.float64)
        w = np.asarray([w for _, w in speed_tiers], dtype=np.float64)
        pick = rng.choice(len(speeds), size=len(capacities), p=w / w.sum())
        kwargs["gpu_speeds"] = tuple(
            float(speeds[t]) for t, cap in zip(pick, capacities) for _ in range(cap)
        )
    if link_classes:
        w = np.asarray([w for _, _, w in link_classes], dtype=np.float64)
        pick = rng.choice(len(link_classes), size=len(capacities), p=w / w.sum())
        kwargs["links"] = tuple(
            (float(link_classes[t][0]), str(link_classes[t][1])) for t in pick
        )
    return kwargs


def philly_cluster(
    num_servers: int = 20,
    seed: int = 0,
    speed_tiers: Sequence[tuple[float, float]] | None = None,
    link_classes: Sequence[tuple[float, str, float]] | None = None,
) -> Cluster:
    """The §7 experiment cluster: ``num_servers`` servers, O_s ~ U{4,8,16,32}.

    Optional ``speed_tiers``/``link_classes`` add per-server heterogeneity
    draws (see :func:`_draw_hetero`); the default draw consumes the RNG
    identically to the homogeneous original, so existing seeds reproduce
    bit-identical clusters.
    """
    rng = np.random.default_rng(seed)
    caps = tuple(int(c) for c in rng.choice([4, 8, 16, 32], size=num_servers))
    return Cluster(capacities=caps, **_draw_hetero(rng, caps, speed_tiers, link_classes))
