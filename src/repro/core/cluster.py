"""Cluster model for RAR-DDLS (paper §4.1).

A multi-tenant GPU cluster: a set of servers ``s ∈ S``, each with GPU
capacity ``O_s``; fast intra-server interconnect bandwidth ``b_i`` (NVLink
class) and slow, contended inter-server bandwidth ``b_e`` (Ethernet class),
with ``b_i >> b_e``.  All GPUs are homogeneous with compute speed ``C``
(amount of gradient data reduced per time-slot).

The contention-model constants (paper Eqs. 6-8):
  * ``xi1``  -- fraction of wall time a job actually contends (Eq. 7)
  * ``xi2``  -- per-server communication-overhead coefficient (gamma)
  * ``alpha`` -- bandwidth-sharing degradation slope, f(a,k) = k + a(k-1)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Cluster:
    """Static description of the multi-tenant GPU cluster."""

    capacities: tuple[int, ...]      # O_s, GPUs per server
    b_intra: float = 300.0           # b^i, intra-server link bandwidth (GB/slot)
    b_inter: float = 1.25            # b^e, inter-server link bandwidth (GB/slot)
    gpu_speed: float = 50.0          # C, reduction throughput (GB/slot)
    xi1: float = 0.7                 # Eq. (7) contention duty-cycle
    xi2: float = 0.002               # gamma coefficient (slots per server spanned)
    alpha: float = 0.3               # degradation slope in f(alpha, k)

    def __post_init__(self) -> None:
        if not self.capacities:
            raise ValueError("cluster needs at least one server")
        if any(c <= 0 for c in self.capacities):
            raise ValueError("server capacities must be positive")
        if self.b_intra < self.b_inter:
            raise ValueError("paper assumes b_intra >> b_inter")

    # ---- derived quantities -------------------------------------------------

    @functools.cached_property
    def num_servers(self) -> int:
        return len(self.capacities)

    @functools.cached_property
    def num_gpus(self) -> int:
        return int(sum(self.capacities))

    # The derived arrays below are cached per instance (the scheduler and
    # simulator read them in every placement probe / event window).  The
    # dataclass is frozen, so the fields they derive from never change;
    # ``functools.cached_property`` writes straight to ``__dict__`` and
    # therefore works on frozen dataclasses.  Treat them as read-only.

    @functools.cached_property
    def capacities_array(self) -> np.ndarray:
        return np.asarray(self.capacities, dtype=np.int64)

    @functools.cached_property
    def gpu_server(self) -> np.ndarray:
        """Map global GPU id -> server id, shape [N]."""
        return np.repeat(np.arange(self.num_servers), self.capacities_array)

    def server_gpu_ids(self, s: int) -> np.ndarray:
        """Global GPU ids living on server ``s``."""
        offsets = np.concatenate([[0], np.cumsum(self.capacities_array)])
        return np.arange(offsets[s], offsets[s + 1])

    def placement_matrix(self, gpu_sets: Sequence[np.ndarray]) -> np.ndarray:
        """Build the Y matrix [J, S]: #GPUs of each job on each server."""
        srv = self.gpu_server
        out = np.zeros((len(gpu_sets), self.num_servers), dtype=np.int64)
        for j, gpus in enumerate(gpu_sets):
            if len(gpus) == 0:
                continue
            np.add.at(out[j], srv[np.asarray(gpus, dtype=np.int64)], 1)
        return out


def philly_cluster(num_servers: int = 20, seed: int = 0) -> Cluster:
    """The §7 experiment cluster: ``num_servers`` servers, O_s ~ U{4,8,16,32}."""
    rng = np.random.default_rng(seed)
    caps = tuple(int(c) for c in rng.choice([4, 8, 16, 32], size=num_servers))
    return Cluster(capacities=caps)
