"""Beyond-paper scheduler extensions (recorded separately from the
faithful SJF-BCO in benchmarks/ablations).

1. ``sjf-bco-adaptive`` — per-job *adaptive* subroutine choice: instead of
   the paper's hard kappa threshold between FA-FFP (pack) and LBSGF
   (spread), evaluate BOTH placements with the refined rho_hat(y^k)
   estimate and commit whichever finishes earlier.  This removes kappa
   from the inner loop entirely (the bisection on theta_u remains), at 2x
   the placement cost per job — still O(n_g |J| N log N log T).

2. ``contention_sweep`` — sensitivity analysis: scale the contention
   coefficient xi1 (and degradation slope alpha) and measure how the
   SJF-BCO advantage over contention-oblivious baselines changes.  The
   paper's thesis predicts the gap widens with contention.
"""
from __future__ import annotations

import dataclasses

from repro.core.api import (PlacementState, ScheduleRequest, ScheduleResult,
                            bisect_theta, finalize, get_policy, nominal_rho,
                            pick_best_finish, register_policy,
                            resolve_placement, schedule_arrivals)
from repro.core.jobs import Job
from repro.core.simulator import simulate
from repro.core.sjf_bco import fa_ffp, lbsgf, sjf_bco_chooser

__all__ = ["sjf_bco_adaptive_policy", "contention_sweep"]


@register_policy("sjf-bco-adaptive")
def sjf_bco_adaptive_policy(request: ScheduleRequest) -> ScheduleResult:
    """Bisection on theta_u with the adaptive pack-or-spread choice; with
    arrivals, the same choice runs in the online epoch loop (identical to
    SJF-BCO online, which is already adaptive).

    The ``placement`` param is validated for interface consistency, but
    the adaptive choice compares two refined scores per job
    (:func:`pick_best_finish`) rather than advancing one picker's pool,
    so both values run the scalar walk -- columnar == scalar trivially
    here."""
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    resolve_placement(request.params)

    if not request.is_batch:
        # Online, the adaptive choice IS SJF-BCO's epoch rule: one shared
        # chooser factory (registered in sjf_bco) serves both names.
        return schedule_arrivals(
            request, sjf_bco_chooser(cluster, u, request.params), "SJF-BCO+")

    rho_noms = {j.jid: nominal_rho(cluster, j) for j in request.jobs}

    def choose(state: PlacementState, job: Job, theta: float) -> bool:
        return pick_best_finish(state, job, [fa_ffp, lbsgf],
                                rho_noms[job.jid], u, theta)

    jobs_sorted = sorted(request.jobs, key=lambda j: (j.num_gpus, j.jid))

    def attempt(theta: float) -> ScheduleResult | None:
        state = PlacementState(cluster, engine=engine)
        for job in jobs_sorted:
            if not choose(state, job, theta):
                return None
        return finalize(state, len(request.jobs), theta, None, "SJF-BCO+")

    return bisect_theta(attempt, request.horizon, "SJF-BCO+")


def contention_sweep(seed: int = 1, xi1s=(0.2, 0.5, 0.7, 1.0),
                     horizon: int = 2400) -> list[dict]:
    """SJF-BCO vs LS (the strongest baseline) as contention intensifies."""
    from repro.core.cluster import philly_cluster
    from repro.core.jobs import philly_workload

    base = philly_cluster(20, seed=seed)
    jobs = philly_workload(seed=seed)
    rows = []
    for xi1 in xi1s:
        cluster = dataclasses.replace(base, xi1=xi1)
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=horizon)
        r = {"xi1": xi1}
        for name, policy in (("sjf", "sjf-bco"), ("sjf+", "sjf-bco-adaptive"),
                             ("ls", "ls")):
            sched = get_policy(policy)(request)
            sim = simulate(cluster, jobs, sched.assignment)
            r[f"{name}_makespan"] = sim.makespan
        r["advantage_vs_ls"] = r["ls_makespan"] / r["sjf_makespan"]
        rows.append(r)
    return rows
