"""Beyond-paper scheduler extensions (recorded separately from the
faithful SJF-BCO in benchmarks/ablations).

1. ``sjf_bco_adaptive`` — per-job *adaptive* subroutine choice: instead of
   the paper's hard kappa threshold between FA-FFP (pack) and LBSGF
   (spread), evaluate BOTH placements with the refined rho_hat(y^k)
   estimate and commit whichever finishes earlier.  This removes kappa
   from the inner loop entirely (the bisection on theta_u remains), at 2x
   the placement cost per job — still O(n_g |J| N log N log T).

2. ``contention_sweep`` — sensitivity analysis: scale the contention
   coefficient xi1 (and degradation slope alpha) and measure how the
   SJF-BCO advantage over contention-oblivious baselines changes.  The
   paper's thesis predicts the gap widens with contention.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.simulator import simulate
from repro.core.sjf_bco import (Schedule, _State, _finalize, fa_ffp, lbsgf,
                                nominal_rho)


def _adaptive_attempt(cluster: Cluster, jobs_sorted: list[Job],
                      rho_noms: dict[int, float], u: float, theta: float
                      ) -> _State | None:
    state = _State(cluster)
    for job in jobs_sorted:
        rho_nom = rho_noms[job.jid]
        best = None  # (est_finish, gpus, rho, start)
        for picker in (fa_ffp, lbsgf):
            gpus = picker(state, job, rho_nom, u, theta)
            if gpus is None:
                continue
            gpus = np.asarray(gpus)
            rho, start = state.refined_rho(job, gpus)
            if np.any(state.U[gpus] + rho / u > theta + 1e-9):
                continue
            if best is None or start + rho < best[0]:
                best = (start + rho, gpus, rho, start)
        if best is None:
            return None
        _, gpus, rho, start = best
        state.commit(job, gpus, rho, start, u)
    return state


def sjf_bco_adaptive(cluster: Cluster, jobs: list[Job], horizon: int,
                     u: float = 1.5) -> Schedule:
    """Bisection on theta_u with the adaptive pack-or-spread choice."""
    jobs_sorted = sorted(jobs, key=lambda j: (j.num_gpus, j.jid))
    rho_noms = {j.jid: nominal_rho(cluster, j) for j in jobs}
    best: Schedule | None = None
    left, right = 1.0, float(horizon)
    while left <= right:
        theta = 0.5 * (left + right)
        state = _adaptive_attempt(cluster, jobs_sorted, rho_noms, u, theta)
        if state is not None:
            cand = _finalize(state, len(jobs), theta, None, "SJF-BCO+")
            if best is None or cand.est_makespan <= best.est_makespan:
                best = cand
            right = theta - 1.0
        else:
            left = theta + 1.0
    if best is None:
        raise RuntimeError("SJF-BCO+: no feasible schedule within horizon")
    return best


def contention_sweep(seed: int = 1, xi1s=(0.2, 0.5, 0.7, 1.0),
                     horizon: int = 2400) -> list[dict]:
    """SJF-BCO vs LS (the strongest baseline) as contention intensifies."""
    from repro.core.baselines import list_scheduling
    from repro.core.cluster import philly_cluster
    from repro.core.jobs import philly_workload
    from repro.core.sjf_bco import sjf_bco

    base = philly_cluster(20, seed=seed)
    jobs = philly_workload(seed=seed)
    rows = []
    for xi1 in xi1s:
        cluster = dataclasses.replace(base, xi1=xi1)
        r = {"xi1": xi1}
        for name, policy in (("sjf", sjf_bco), ("sjf+", sjf_bco_adaptive),
                             ("ls", list_scheduling)):
            sched = policy(cluster, jobs, horizon)
            sim = simulate(cluster, jobs, sched.assignment)
            r[f"{name}_makespan"] = sim.makespan
        r["advantage_vs_ls"] = r["ls_makespan"] / r["sjf_makespan"]
        rows.append(r)
    return rows
