"""Baseline scheduling policies from §7-2: First-Fit, List-Scheduling, RAND.

All baselines share SJF-BCO's busy-time accounting (U clocks, refined
rho_hat(y^k)/u charging) so the comparison isolates the *placement policy*:

  * FF   -- walk servers in id order, take the first G_j feasible GPUs
            (packs into fewest servers; fragmentation-averse but
            contention/overhead-oblivious);
  * LS   -- globally least-loaded feasible GPUs (balances busy time but may
            span many servers => high overhead + contention);
  * RAND -- random servers/GPUs with theta_u = T (paper sets the RAND limit
            to the horizon to avoid long feasibility searches).

FF and LS bisect their own theta_u like SJF-BCO does, per the paper's
"theta_u^f is the maximum execution time limit returned by policy f".
Baselines keep the user-submitted arrival order (no SJF sort).
"""
from __future__ import annotations

import numpy as np

from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.sjf_bco import (Schedule, _State, _finalize, _try_place,
                                nominal_rho)


def _ff_pick(state: _State, job: Job, rho_nom: float, u: float, theta: float
             ) -> np.ndarray | None:
    # Server-major, GPU-id order == first fit from server to server.
    ids = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(ids) < job.num_gpus:
        return None
    return ids[: job.num_gpus]


def _ls_pick(state: _State, job: Job, rho_nom: float, u: float, theta: float
             ) -> np.ndarray | None:
    feasible = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(feasible) < job.num_gpus:
        return None
    order = feasible[np.argsort(state.U[feasible], kind="stable")]
    return order[: job.num_gpus]


def _run(cluster: Cluster, jobs: list[Job], picker, u: float, theta: float
         ) -> _State | None:
    state = _State(cluster)
    for job in jobs:
        if not _try_place(state, job, picker, nominal_rho(cluster, job), u, theta):
            return None
    return state


def _bisect(cluster: Cluster, jobs: list[Job], picker, horizon: int,
            u: float, name: str) -> Schedule:
    best: Schedule | None = None
    left, right = 1.0, float(horizon)
    while left <= right:
        theta = 0.5 * (left + right)
        state = _run(cluster, jobs, picker, u, theta)
        if state is not None:
            cand = _finalize(state, len(jobs), theta, None, name)
            if best is None or cand.est_makespan <= best.est_makespan:
                best = cand
            right = theta - 1.0
        else:
            left = theta + 1.0
    if best is None:
        raise RuntimeError(f"{name}: no feasible schedule within horizon")
    return best


def first_fit(cluster: Cluster, jobs: list[Job], horizon: int,
              u: float = 1.5) -> Schedule:
    return _bisect(cluster, jobs, _ff_pick, horizon, u, "FF")


def list_scheduling(cluster: Cluster, jobs: list[Job], horizon: int,
                    u: float = 1.5) -> Schedule:
    return _bisect(cluster, jobs, _ls_pick, horizon, u, "LS")


def random_policy(cluster: Cluster, jobs: list[Job], horizon: int,
                  u: float = 1.5, seed: int = 0) -> Schedule:
    rng = np.random.default_rng(seed)
    state = _State(cluster)
    theta = float(horizon)

    def picker(st, job, rho_nom, uu, th):
        feasible = np.flatnonzero(st.U + rho_nom / uu <= th + 1e-9)
        if len(feasible) < job.num_gpus:
            return None
        return rng.choice(feasible, size=job.num_gpus, replace=False)

    for job in jobs:
        if not _try_place(state, job, picker, nominal_rho(cluster, job), u, theta):
            raise RuntimeError("RAND: no feasible schedule within horizon")
    return _finalize(state, len(jobs), theta, None, "RAND")


def reserved_bandwidth(cluster: Cluster, jobs: list[Job], horizon: int,
                       u: float = 1.5) -> Schedule:
    """GADGET-style ablation [22]: schedule as if each job had reserved,
    contention-free bandwidth (rho charged at its nominal lower estimate,
    placement = least-loaded GPUs).  The simulator *does* model contention,
    so the actual makespan of this schedule exposes the optimism the paper
    argues against."""
    best: Schedule | None = None
    left, right = 1.0, float(horizon)
    while left <= right:
        theta = 0.5 * (left + right)
        state = _State(cluster)
        ok = True
        for job in jobs:
            rho = nominal_rho(cluster, job)
            gpus = _ls_pick(state, job, rho, u, theta)
            if gpus is None or np.any(state.U[gpus] + rho / u > theta + 1e-9):
                ok = False
                break
            start = float(state.R[gpus].max()) if len(gpus) else 0.0
            state.commit(job, np.asarray(gpus), rho, start, u)
        if ok:
            cand = _finalize(state, len(jobs), theta, None, "RESERVED")
            if best is None or cand.est_makespan <= best.est_makespan:
                best = cand
            right = theta - 1.0
        else:
            left = theta + 1.0
    assert best is not None
    return best


POLICIES = {
    "sjf-bco": None,  # filled in repro.core.__init__ to avoid import cycle
    "ff": first_fit,
    "ls": list_scheduling,
    "rand": random_policy,
    "reserved": reserved_bandwidth,
}
