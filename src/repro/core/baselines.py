"""Baseline scheduling policies from §7-2: First-Fit, List-Scheduling, RAND,
plus the GADGET-style reserved-bandwidth ablation.

All baselines share SJF-BCO's busy-time accounting (U clocks, refined
rho_hat(y^k)/u charging, via :mod:`repro.core.api`) so the comparison
isolates the *placement policy*:

  * FF   -- walk servers in id order, take the first G_j feasible GPUs
            (packs into fewest servers; fragmentation-averse but
            contention/overhead-oblivious);
  * LS   -- globally least-loaded feasible GPUs (balances busy time but may
            span many servers => high overhead + contention);
  * RAND -- random servers/GPUs with theta_u = T (paper sets the RAND limit
            to the horizon to avoid long feasibility searches).

FF and LS bisect their own theta_u like SJF-BCO does, per the paper's
"theta_u^f is the maximum execution time limit returned by policy f".
Baselines keep the user-submitted arrival order (no SJF sort).  With
``request.arrivals`` set, every baseline runs the shared online epoch loop
with its own picker (theta_u = T, as online has no bisection).
"""
from __future__ import annotations

import numpy as np

from repro.core.api import (Chooser, PlacementState, Picker, ScheduleRequest,
                            ScheduleResult, SharedState, bisect_theta,
                            finalize, nominal_rho, register_chooser,
                            register_policy, schedule_arrivals, try_place,
                            try_place_group)
from repro.core.jobs import Job

__all__ = ["first_fit_policy", "list_scheduling_policy", "random_policy_policy",
           "reserved_bandwidth_policy"]


def _ff_pick(state: PlacementState, job: Job, rho_nom: float, u: float,
             theta: float) -> np.ndarray | None:
    # Server-major, GPU-id order == first fit from server to server.
    ids = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(ids) < job.num_gpus:
        return None
    return ids[: job.num_gpus]


def _ls_pick(state: PlacementState, job: Job, rho_nom: float, u: float,
             theta: float) -> np.ndarray | None:
    feasible = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(feasible) < job.num_gpus:
        return None
    order = feasible[np.argsort(state.U[feasible], kind="stable")]
    return order[: job.num_gpus]


# theta enters both pickers only through the U + rho/u <= theta + 1e-9
# pool, so the speculative bisection may advance theta groups in lockstep.
_ff_pick.theta_pool = True
_ls_pick.theta_pool = True


def _picker_chooser(picker: Picker, cluster, u: float) -> Chooser:
    """Online chooser of a pure-picker baseline: try_place per arrival."""
    rho_noms: dict[int, float] = {}

    def choose(state: PlacementState, job: Job, theta: float) -> bool:
        if job.jid not in rho_noms:
            rho_noms[job.jid] = nominal_rho(cluster, job)
        return try_place(state, job, picker, rho_noms[job.jid], u, theta)

    return choose


@register_chooser("ff")
def ff_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online First-Fit: server-major first feasible GPUs per arrival."""
    return _picker_chooser(_ff_pick, cluster, u)


@register_chooser("ls")
def ls_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online List-Scheduling: least-loaded feasible GPUs per arrival."""
    return _picker_chooser(_ls_pick, cluster, u)


def _picker_policy(request: ScheduleRequest, picker: Picker, name: str
                   ) -> ScheduleResult:
    """Shared FF/LS skeleton: online epoch loop or batch theta bisection."""
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")

    if not request.is_batch:
        return schedule_arrivals(
            request, _picker_chooser(picker, cluster, u), name)

    rho_noms = {j.jid: nominal_rho(cluster, j) for j in request.jobs}

    jobs = request.jobs

    def attempt(theta: float,
                prev: ScheduleResult | None = None) -> ScheduleResult | None:
        hints = dict(prev.assignment) if prev is not None else {}
        state = PlacementState(cluster, engine=engine)
        for job in jobs:
            if not try_place(state, job, picker, rho_noms[job.jid], u, theta,
                             hint=hints.get(job.jid)):
                return None
        return finalize(state, len(jobs), theta, None, name)

    bisect_mode = request.params.get("bisect", "speculative")
    if bisect_mode not in ("speculative", "sequential"):
        raise ValueError(f"unknown bisect mode {bisect_mode!r}; "
                         "choose 'speculative' or 'sequential'")
    warm = bool(request.params.get("warm_start"))
    attempt_many = None
    if bisect_mode == "speculative" and not warm:
        def attempt_many(thetas: list[float]
                         ) -> "dict[float, ScheduleResult | None]":
            # One shared state for the whole probe ladder; theta groups
            # advance in lockstep and fork (copy-on-write) only where the
            # budgets change a placement decision.
            out: dict[float, ScheduleResult | None] = {}
            root = SharedState(PlacementState(cluster, engine=engine))
            work = [(np.asarray(sorted(thetas), dtype=np.float64), root, 0)]
            while work:
                th_g, holder, idx = work.pop()
                if idx == len(jobs):
                    for th in th_g:
                        out[float(th)] = finalize(holder.state, len(jobs),
                                                  float(th), None, name)
                    holder.release()
                    continue
                job = jobs[idx]
                for sub, sh, ok in try_place_group(
                        th_g, holder, job, picker, rho_noms[job.jid], u):
                    if ok:
                        work.append((sub, sh, idx + 1))
                    else:
                        for th in sub:
                            out[float(th)] = None
            return out

    return bisect_theta(attempt, request.horizon, name, warm_start=warm,
                        attempt_many=attempt_many,
                        levels=int(request.params.get("bisect_levels", 4)),
                        floor=max(rho_noms.values()) / u)


@register_policy("ff")
def first_fit_policy(request: ScheduleRequest) -> ScheduleResult:
    return _picker_policy(request, _ff_pick, "FF")


@register_policy("ls")
def list_scheduling_policy(request: ScheduleRequest) -> ScheduleResult:
    return _picker_policy(request, _ls_pick, "LS")


def _rand_picker(rng: np.random.Generator) -> Picker:
    """Random feasible GPUs, drawing from ``rng`` (stateful: see try_place)."""

    def picker(st, job, rho_nom, uu, th):
        feasible = np.flatnonzero(st.U + rho_nom / uu <= th + 1e-9)
        if len(feasible) < job.num_gpus:
            return None
        return rng.choice(feasible, size=job.num_gpus, replace=False)

    picker.stateful = True   # consumes rng draws; see try_place's ladder
    return picker


@register_chooser("rand")
def rand_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online RAND: random feasible GPUs per arrival.  Stateful (the rng
    advances with every attempt), so crash recovery cannot replay it
    decision-for-decision; ``repro.service`` flags this via the factory's
    ``stateful`` attribute."""
    picker = _rand_picker(np.random.default_rng(params.get("seed", 0)))

    def choose(state: PlacementState, job: Job, th: float) -> bool:
        return try_place(state, job, picker, nominal_rho(cluster, job), u, th)

    choose.stateful = True
    return choose


rand_chooser.stateful = True


@register_policy("rand")
def random_policy_policy(request: ScheduleRequest) -> ScheduleResult:
    """RAND with theta_u = T.  ``request.params``: ``seed`` (default 0)."""
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    theta = float(request.horizon)

    if not request.is_batch:
        return schedule_arrivals(
            request, rand_chooser(cluster, u, request.params), "RAND")

    rng = np.random.default_rng(request.params.get("seed", 0))
    picker = _rand_picker(rng)
    state = PlacementState(cluster, engine=engine)
    for job in request.jobs:
        if not try_place(state, job, picker, nominal_rho(cluster, job),
                         u, theta):
            raise RuntimeError("RAND: no feasible schedule within horizon")
    return finalize(state, len(request.jobs), theta, None, "RAND")


@register_chooser("reserved")
def reserved_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online RESERVED: least-loaded GPUs charged at the contention-free
    nominal estimate (the reserved-bandwidth optimism, per arrival)."""

    def place_nominal(state: PlacementState, job: Job, theta: float) -> bool:
        rho = nominal_rho(cluster, job)
        gpus = _ls_pick(state, job, rho, u, theta)
        if gpus is None or np.any(state.U[gpus] + rho / u > theta + 1e-9):
            return False
        start = float(state.R[gpus].max()) if len(gpus) else 0.0
        state.commit(job, np.asarray(gpus), rho, start, u)
        return True

    return place_nominal


@register_policy("reserved")
def reserved_bandwidth_policy(request: ScheduleRequest) -> ScheduleResult:
    """GADGET-style ablation [22]: schedule as if each job had reserved,
    contention-free bandwidth (rho charged at its nominal lower estimate,
    placement = least-loaded GPUs).  The simulator *does* model contention,
    so the actual makespan of this schedule exposes the optimism the paper
    argues against."""
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    place_nominal = reserved_chooser(cluster, u, request.params)

    if not request.is_batch:
        return schedule_arrivals(request, place_nominal, "RESERVED")

    jobs = request.jobs

    def attempt(theta: float) -> ScheduleResult | None:
        state = PlacementState(cluster, engine=engine)
        for job in jobs:
            if not place_nominal(state, job, theta):
                return None
        return finalize(state, len(jobs), theta, None, "RESERVED")

    return bisect_theta(attempt, request.horizon, "RESERVED")
