"""Baseline scheduling policies from §7-2: First-Fit, List-Scheduling, RAND,
plus the GADGET-style reserved-bandwidth ablation.

All baselines share SJF-BCO's busy-time accounting (U clocks, refined
rho_hat(y^k)/u charging, via :mod:`repro.core.api`) so the comparison
isolates the *placement policy*:

  * FF   -- walk servers in id order, take the first G_j feasible GPUs
            (packs into fewest servers; fragmentation-averse but
            contention/overhead-oblivious);
  * LS   -- globally least-loaded feasible GPUs (balances busy time but may
            span many servers => high overhead + contention);
  * RAND -- random servers/GPUs with theta_u = T (paper sets the RAND limit
            to the horizon to avoid long feasibility searches).

FF and LS bisect their own theta_u like SJF-BCO does, per the paper's
"theta_u^f is the maximum execution time limit returned by policy f".
Baselines keep the user-submitted arrival order (no SJF sort).  With
``request.arrivals`` set, every baseline runs the shared online epoch loop
with its own picker (theta_u = T, as online has no bisection).
"""
from __future__ import annotations

import numpy as np

from repro.core.api import (Chooser, PlacementState, Picker, ScheduleRequest,
                            ScheduleResult, SharedState, bisect_theta,
                            finalize, nominal_rho, register_chooser,
                            register_policy, resolve_columnar_backend,
                            resolve_placement, schedule_arrivals, try_place,
                            try_place_group)
from repro.core.columnar import ColumnarPlacement
from repro.core.jobs import Job

__all__ = ["first_fit_policy", "list_scheduling_policy", "random_policy_policy",
           "reserved_bandwidth_policy"]


def _ff_pick(state: PlacementState, job: Job, rho_nom: float, u: float,
             theta: float) -> np.ndarray | None:
    # Server-major, GPU-id order == first fit from server to server.
    ids = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(ids) < job.num_gpus:
        return None
    return ids[: job.num_gpus]


def _ls_pick(state: PlacementState, job: Job, rho_nom: float, u: float,
             theta: float) -> np.ndarray | None:
    feasible = np.flatnonzero(state.U + rho_nom / u <= theta + 1e-9)
    if len(feasible) < job.num_gpus:
        return None
    order = feasible[np.argsort(state.U[feasible], kind="stable")]
    return order[: job.num_gpus]


def _ff_pick_many(cluster, U: np.ndarray, feasible: np.ndarray,
                  job: Job) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised :func:`_ff_pick` over a batch of branch rows: per row,
    the first G_j feasible GPUs in id order.  A stable argsort of the
    negated mask lists feasible ids first, in id order -- exactly the
    scalar ``np.flatnonzero`` prefix."""
    ok = feasible.sum(axis=1) >= job.num_gpus
    gpus = np.argsort(~feasible, axis=1, kind="stable")[:, :job.num_gpus]
    return gpus, ok


def _ls_pick_many(cluster, U: np.ndarray, feasible: np.ndarray,
                  job: Job) -> tuple[np.ndarray, np.ndarray]:
    """Vectorised :func:`_ls_pick` over a batch of branch rows: per row,
    the G_j least-loaded feasible GPUs.  The stable argsort over
    inf-masked clocks orders ties by GPU id, exactly like the scalar
    subarray sort (pool members keep their relative index order)."""
    ok = feasible.sum(axis=1) >= job.num_gpus
    gpus = np.argsort(np.where(feasible, U, np.inf), axis=1,
                      kind="stable")[:, :job.num_gpus]
    return gpus, ok


# theta enters both pickers only through the U + rho/u <= theta + 1e-9
# pool, so the speculative bisection may advance theta groups in lockstep
# and the columnar engine may batch whole branch stacks per pick.
_ff_pick.theta_pool = True
_ls_pick.theta_pool = True
_ff_pick.pick_many = _ff_pick_many
_ls_pick.pick_many = _ls_pick_many


def _picker_chooser(picker: Picker, cluster, u: float) -> Chooser:
    """Online chooser of a pure-picker baseline: try_place per arrival."""
    rho_noms: dict[int, float] = {}

    def choose(state: PlacementState, job: Job, theta: float) -> bool:
        if job.jid not in rho_noms:
            rho_noms[job.jid] = nominal_rho(cluster, job)
        return try_place(state, job, picker, rho_noms[job.jid], u, theta)

    return choose


@register_chooser("ff")
def ff_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online First-Fit: server-major first feasible GPUs per arrival."""
    return _picker_chooser(_ff_pick, cluster, u)


@register_chooser("ls")
def ls_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online List-Scheduling: least-loaded feasible GPUs per arrival."""
    return _picker_chooser(_ls_pick, cluster, u)


def _columnar_attempts(cluster, jobs: list[Job], rho_noms: dict[int, float],
                       u: float, thetas: list[float], picker: Picker,
                       engine: str | None, name: str,
                       backend: str = "numpy"
                       ) -> "dict[float, ScheduleResult | None]":
    """All theta attempts of one picker as a single columnar program.

    One branch per theta of a :class:`ColumnarPlacement`; the whole
    ladder advances a job per :meth:`place` call, sharing (and
    re-merging) state rows wherever the budgets pick the same GPUs.
    Decision-for-decision identical to the scalar try_place loop per
    theta, hence bit-identical schedules.  ``backend`` selects where the
    step math runs (the FF/LS pickers carry no fused ranking, so "jit"/
    "kernel" fuse the probe scoring and keep per-step pick_many calls)."""
    ths = sorted(float(th) for th in thetas)
    col = ColumnarPlacement(cluster, ths, jobs, u, engine=engine,
                            backend=backend)
    for job in jobs:                       # request order (no SJF sort)
        col.place(job, rho_noms[job.jid], (picker,), 0)
        if not col.alive.any():
            break
    return {th: col.result(b, th, None, name) for b, th in enumerate(ths)}


def _picker_policy(request: ScheduleRequest, picker: Picker, name: str
                   ) -> ScheduleResult:
    """Shared FF/LS skeleton: online epoch loop or batch theta bisection.

    Honours the ``engine``/``bisect``/``warm_start``/``placement`` params
    exactly as ``sjf-bco`` does (``placement="scalar"``, the default, is
    the per-branch oracle walk and the fallback under ``warm_start``;
    ``"columnar"`` batches each attempt's theta ladder as one
    :class:`~repro.core.columnar.ColumnarPlacement` program)."""
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    placement = resolve_placement(
        request.params, len(request.jobs) if request.is_batch else None)

    if not request.is_batch:
        return schedule_arrivals(
            request, _picker_chooser(picker, cluster, u), name)

    rho_noms = {j.jid: nominal_rho(cluster, j) for j in request.jobs}

    jobs = request.jobs

    bisect_mode = request.params.get("bisect", "speculative")
    if bisect_mode not in ("speculative", "sequential"):
        raise ValueError(f"unknown bisect mode {bisect_mode!r}; "
                         "choose 'speculative' or 'sequential'")
    warm = bool(request.params.get("warm_start"))
    use_columnar = placement == "columnar" and not warm
    backend = resolve_columnar_backend(request.params) if use_columnar \
        else "numpy"

    def attempt(theta: float,
                prev: ScheduleResult | None = None) -> ScheduleResult | None:
        if use_columnar:
            return _columnar_attempts(cluster, jobs, rho_noms, u, [theta],
                                      picker, engine, name,
                                      backend)[float(theta)]
        hints = dict(prev.assignment) if prev is not None else {}
        state = PlacementState(cluster, engine=engine)
        for job in jobs:
            if not try_place(state, job, picker, rho_noms[job.jid], u, theta,
                             hint=hints.get(job.jid)):
                return None
        return finalize(state, len(jobs), theta, None, name)

    attempt_many = None
    if bisect_mode == "speculative" and not warm:
        def attempt_many(thetas: list[float]
                         ) -> "dict[float, ScheduleResult | None]":
            if use_columnar:
                return _columnar_attempts(cluster, jobs, rho_noms, u,
                                          thetas, picker, engine, name,
                                          backend)
            # One shared state for the whole probe ladder; theta groups
            # advance in lockstep and fork (copy-on-write) only where the
            # budgets change a placement decision.
            out: dict[float, ScheduleResult | None] = {}
            root = SharedState(PlacementState(cluster, engine=engine))
            work = [(np.asarray(sorted(thetas), dtype=np.float64), root, 0)]
            while work:
                th_g, holder, idx = work.pop()
                if idx == len(jobs):
                    for th in th_g:
                        out[float(th)] = finalize(holder.state, len(jobs),
                                                  float(th), None, name)
                    holder.release()
                    continue
                job = jobs[idx]
                for sub, sh, ok in try_place_group(
                        th_g, holder, job, picker, rho_noms[job.jid], u):
                    if ok:
                        work.append((sub, sh, idx + 1))
                    else:
                        for th in sub:
                            out[float(th)] = None
            return out

    return bisect_theta(attempt, request.horizon, name, warm_start=warm,
                        attempt_many=attempt_many,
                        levels=int(request.params.get("bisect_levels", 4)),
                        floor=max(rho_noms.values()) / u)


@register_policy("ff")
def first_fit_policy(request: ScheduleRequest) -> ScheduleResult:
    return _picker_policy(request, _ff_pick, "FF")


@register_policy("ls")
def list_scheduling_policy(request: ScheduleRequest) -> ScheduleResult:
    return _picker_policy(request, _ls_pick, "LS")


def _rand_picker(rng: np.random.Generator) -> Picker:
    """Random feasible GPUs, drawing from ``rng`` (stateful: see try_place)."""

    def picker(st, job, rho_nom, uu, th):
        feasible = np.flatnonzero(st.U + rho_nom / uu <= th + 1e-9)
        if len(feasible) < job.num_gpus:
            return None
        return rng.choice(feasible, size=job.num_gpus, replace=False)

    picker.stateful = True   # consumes rng draws; see try_place's ladder
    return picker


@register_chooser("rand")
def rand_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online RAND: random feasible GPUs per arrival.  Stateful (the rng
    advances with every attempt): the chooser carries a ``stateful``
    attribute plus ``get_state``/``set_state`` accessors exposing the
    generator's ``bit_generator.state`` (a JSON-safe dict of ints), which
    the service daemon journals after every decision so crash recovery
    replays RAND decision-for-decision too."""
    rng = np.random.default_rng(params.get("seed", 0))
    picker = _rand_picker(rng)

    def choose(state: PlacementState, job: Job, th: float) -> bool:
        return try_place(state, job, picker, nominal_rho(cluster, job), u, th)

    def get_state() -> dict:
        return rng.bit_generator.state

    def set_state(snapshot: dict) -> None:
        rng.bit_generator.state = snapshot

    choose.stateful = True
    choose.get_state = get_state
    choose.set_state = set_state
    return choose


rand_chooser.stateful = True


@register_policy("rand")
def random_policy_policy(request: ScheduleRequest) -> ScheduleResult:
    """RAND with theta_u = T.  ``request.params``: ``seed`` (default 0).
    The picker is stateful (rng draws per attempt), so there is no
    columnar path: the ``placement`` param is validated but both values
    run the scalar walk (columnar == scalar trivially)."""
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    resolve_placement(request.params)
    theta = float(request.horizon)

    if not request.is_batch:
        return schedule_arrivals(
            request, rand_chooser(cluster, u, request.params), "RAND")

    rng = np.random.default_rng(request.params.get("seed", 0))
    picker = _rand_picker(rng)
    state = PlacementState(cluster, engine=engine)
    for job in request.jobs:
        if not try_place(state, job, picker, nominal_rho(cluster, job),
                         u, theta):
            raise RuntimeError("RAND: no feasible schedule within horizon")
    return finalize(state, len(request.jobs), theta, None, "RAND")


@register_chooser("reserved")
def reserved_chooser(cluster, u: float, params: dict) -> Chooser:
    """Online RESERVED: least-loaded GPUs charged at the contention-free
    nominal estimate (the reserved-bandwidth optimism, per arrival)."""

    def place_nominal(state: PlacementState, job: Job, theta: float) -> bool:
        rho = nominal_rho(cluster, job)
        gpus = _ls_pick(state, job, rho, u, theta)
        if gpus is None or np.any(state.U[gpus] + rho / u > theta + 1e-9):
            return False
        start = float(state.R[gpus].max()) if len(gpus) else 0.0
        state.commit(job, np.asarray(gpus), rho, start, u)
        return True

    return place_nominal


@register_policy("reserved")
def reserved_bandwidth_policy(request: ScheduleRequest) -> ScheduleResult:
    """GADGET-style ablation [22]: schedule as if each job had reserved,
    contention-free bandwidth (rho charged at its nominal lower estimate,
    placement = least-loaded GPUs).  The simulator *does* model contention,
    so the actual makespan of this schedule exposes the optimism the paper
    argues against.  Commits at the nominal rho (no refined re-check
    ladder), so there is no columnar path: the ``placement`` param is
    validated but both values run the scalar walk."""
    cluster, u = request.cluster, request.u
    engine = request.params.get("engine")
    resolve_placement(request.params)
    place_nominal = reserved_chooser(cluster, u, request.params)

    if not request.is_batch:
        return schedule_arrivals(request, place_nominal, "RESERVED")

    jobs = request.jobs

    def attempt(theta: float) -> ScheduleResult | None:
        state = PlacementState(cluster, engine=engine)
        for job in jobs:
            if not place_nominal(state, job, theta):
                return None
        return finalize(state, len(jobs), theta, None, "RESERVED")

    return bisect_theta(attempt, request.horizon, "RESERVED")
