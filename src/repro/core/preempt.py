"""Preemption, migration, and elastic resizing on the incremental engines.

The paper's Eq. (3) pins every job to one gang placement for its whole
life (no preemption).  This module relaxes exactly that constraint with a
checkpoint-restart migration primitive on :class:`PlacementState` and
three policies built on it:

  * :func:`evict` -- stop a placed job at an instant ``t``: the committed
    entry is truncated to the work already executed (or removed outright
    if it had not started), the Eq. (15/16) busy-time charge of the
    un-run remainder is refunded, the real-time clocks and the Eq. (6)
    straddler suffix-count lists are pulled back, and the residual work
    comes back as a new :class:`Job` (iterations prorated from the
    committed rho snapshot -- the same progress accounting a
    ``repro.ckpt`` step counter would checkpoint).
  * :func:`replace` -- re-place a residual job on an explicit GPU set
    under the Eq. (16) budget; together with ``evict`` this is migration.
  * :func:`resize` -- ``evict`` with a different worker count, then
    re-place: GADGET-style elastic scaling (arXiv:2202.01158).

Policies (each with a ``@register_chooser`` online form, so the service
daemon drains them decision-for-decision identically to
:func:`~repro.core.api.schedule_arrivals`):

  * ``sjf-bco-dynamic`` -- dynamic re-packing (arXiv:1908.08082).
    Online: each arrival may preempt the latest-finishing running job
    when the trial (on a clone) strictly improves the summed finish of
    {arrival, victim}.  Batch: re-runs the SJF re-pack over the not-yet
    -started jobs at the first few estimated completion instants and
    keeps the better of {SJF-BCO, re-pack} by simulated makespan -- so it
    is <= SJF-BCO on the Fig. 4 grids by construction.
  * ``gadget-elastic`` -- when an arrival cannot be placed, shrink the
    widest running job toward ``elastic_min`` (its marginal-utility
    window's lower edge; the requested G_j is the upper edge) and retry.
  * ``wang-ca`` -- contention-aware ordering baseline (arXiv:2002.10105):
    jobs ordered by descending ring communication share, each placed on
    the candidate minimising (probed contention level p, est finish).
    Non-preemptive -- the control for the leaderboard.

Everything here runs on the bit-identical engine axes: eviction
arithmetic never touches the contention model (pure clock/quota surgery),
and every probe goes through ``refined_rho`` / ``_probe_p``, which are
pinned identical across reference / batched / incremental.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.core import contention
from repro.core.api import (Chooser, PlacementState, ScheduleRequest,
                            ScheduleResult, bisect_theta, finalize,
                            nominal_rho, register_chooser, register_policy,
                            resolve_placement, schedule_arrivals)
from repro.core.cluster import Cluster
from repro.core.jobs import Job

__all__ = ["evict", "replace", "resize", "evictable"]


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------


def evictable(state: PlacementState, jid: int, t: float) -> bool:
    """Whether :func:`evict` would succeed for ``jid`` at instant ``t``:
    the job has a live entry and at least one full iteration left."""
    e = state._entry_of.get(jid, -1)
    if e < 0:
        return False
    rho, start = state.seg_rho[e], state.seg_start[e]
    t_ev = min(max(float(t), start), start + rho)
    job = state.placed_jobs[state.seg_row[e]]
    iters_done = job.iters * ((t_ev - start) / rho) if rho > 0 \
        else float(job.iters)
    return job.iters - iters_done >= 1.0


def _drop_straddle_fin(state: PlacementState, y: np.ndarray, G: int,
                       old: float, new: float | None) -> None:
    """Remove ``old`` from the straddled servers' sorted finish lists
    (copy-on-write, like commit/observe_finish) and insert ``new``."""
    for s, ys in enumerate(y.tolist()):
        if 0 < ys < G:
            if not state._fin_owned[s]:
                state._straddle_fin[s] = list(state._straddle_fin[s])
                state._fin_owned[s] = True
            fin = state._straddle_fin[s]
            i = bisect.bisect_left(fin, old)
            if i < len(fin) and fin[i] == old:
                fin.pop(i)
            if new is not None:
                bisect.insort(fin, new)


def _remove_entry(state: PlacementState, e: int) -> None:
    """Physically drop assignment entry ``e`` (a never-started segment),
    remapping the entry-indexed links.  The placed ROW stays (marked dead
    via ``placed_fin = -inf`` by the caller) so row indices in
    ``seg_row`` remain stable; evictions are rare, so the O(entries)
    rebuild is fine."""
    del state.assignment[e]
    del state.seg_rho[e]
    del state.seg_start[e]
    del state.seg_quota[e]
    del state.seg_prev[e]
    del state.seg_row[e]
    state.seg_prev = [p - 1 if p > e else p for p in state.seg_prev]
    state._entry_of = {j: (k - 1 if k > e else k)
                       for j, k in state._entry_of.items()}


def evict(state: PlacementState, jid: int, t: float, u: float,
          num_gpus: int | None = None) -> Job | None:
    """Stop job ``jid`` at instant ``t``; return its residual Job.

    The eviction instant is clamped into the entry's committed window
    ``[start, start + rho]``.  Progress is prorated from the committed
    rho snapshot: ``iters_done = F_j * (t_ev - start) / rho`` -- the same
    step-counter arithmetic a ``repro.ckpt`` checkpoint would record.
    Refuses (returns None) when less than one full iteration remains:
    migrating a nearly-done job can only lose work.

    State surgery (all exact float arithmetic, so a journal replay of the
    same call is bit-identical):

      * ``U[gpus] -= (rho - done) / u`` -- refund the un-run remainder of
        the Eq. (15) charge;
      * ``R`` entries still equal to the planned finish pull back to
        ``t_ev`` (the GPUs free at the eviction, like
        :meth:`~repro.core.api.PlacementState.observe_finish`);
      * the Eq. (6) straddler suffix lists replace the planned finish
        with ``t_ev`` (or just drop it when the segment never started);
      * a started entry is truncated: its quota becomes ``iters_done``
        and its row finish ``t_ev``; a never-started entry is removed
        outright and the previous segment (if any) becomes the job's
        live entry again.

    ``num_gpus`` resizes the residual (elastic scaling); by default the
    residual keeps the victim's worker count (pure migration).
    """
    e = state._entry_of.get(jid, -1)
    if e < 0:
        return None
    _, gpus = state.assignment[e]
    rho, start = state.seg_rho[e], state.seg_start[e]
    row = state.seg_row[e]
    job = state.placed_jobs[row]
    t_ev = min(max(float(t), start), start + rho)
    done = t_ev - start
    iters_done = job.iters * (done / rho) if rho > 0 else float(job.iters)
    iters_left = job.iters - iters_done
    if iters_left < 1.0:
        return None
    residual = dataclasses.replace(
        job, iters=iters_left,
        num_gpus=int(num_gpus) if num_gpus is not None else job.num_gpus)
    fin_old = start + rho                       # exact committed float
    y = state.placed_y[row]
    G = job.num_gpus
    state.U[gpus] -= (rho - done) / u
    mask = state.R[gpus] == fin_old
    state.R[gpus[mask]] = t_ev
    if done > 0.0:
        _drop_straddle_fin(state, y, G, fin_old, t_ev)
        state.seg_quota[e] = iters_done
        state.placed_fin[row] = t_ev
        state.est_finish[jid] = t_ev
    else:
        _drop_straddle_fin(state, y, G, fin_old, None)
        state.placed_fin[row] = -np.inf         # dead row: never overlaps
        prev = state.seg_prev[e]
        _remove_entry(state, e)
        if prev >= 0:
            state._entry_of[jid] = prev
            state.est_finish[jid] = state.placed_fin[state.seg_row[prev]]
        else:
            del state._entry_of[jid]
            del state.est_start[jid]
            del state.est_finish[jid]
    state.preempted = True
    contention.EVAL_COUNTS["evictions"] += 1
    if state.evict_hook is not None:
        state.evict_hook(job, t_ev, residual)
    return residual


def replace(state: PlacementState, job: Job, gpus: np.ndarray,
            theta: float, u: float) -> bool:
    """Re-place a residual job on an explicit GPU set under Eq. (16).

    ``refined_rho`` prices the residual against the live snapshot; the
    commit links it to the evicted entry (``seg_prev``), so the job's
    est_start survives and the simulator runs the segments in order.
    Callers must have advanced the state to the eviction instant
    (``advance_to``), which :func:`evict` guarantees never exceeds."""
    gpus = np.asarray(gpus)
    rho, start = state.refined_rho(job, gpus)
    if float(state.U[gpus].max()) + rho / u > theta + 1e-9:
        return False
    state.commit(job, gpus, rho, start, u)
    return True


def resize(state: PlacementState, jid: int, t: float, num_gpus: int,
           gpus: np.ndarray, theta: float, u: float) -> bool:
    """Elastic resize: evict ``jid`` at ``t`` with a new worker count and
    re-place the residual on ``gpus``.  All-or-nothing via a clone trial:
    the state is untouched unless both halves succeed."""
    trial = state.clone()
    residual = evict(trial, jid, t, u, num_gpus=num_gpus)
    if residual is None or not replace(trial, residual, gpus, theta, u):
        return False
    residual = evict(state, jid, t, u, num_gpus=num_gpus)
    return replace(state, residual, gpus, theta, u)


# --------------------------------------------------------------------------
# Shared candidate scoring (pick_best_finish without the commit)
# --------------------------------------------------------------------------


def _best_candidate(state: PlacementState, job: Job, rho_nom: float,
                    u: float, theta: float
                    ) -> tuple[float, np.ndarray, float, float] | None:
    """The finish-minimising FA-FFP/LBSGF candidate, NOT committed:
    (est_finish, gpus, rho, start) -- exactly the pick
    :func:`~repro.core.api.pick_best_finish` would commit."""
    from repro.core.sjf_bco import fa_ffp, lbsgf
    cands = []
    for picker in (fa_ffp, lbsgf):
        gpus = picker(state, job, rho_nom, u, theta)
        if gpus is not None:
            cands.append(np.asarray(gpus))
    best = None
    for gpus, (rho, start) in zip(cands, state.refined_rho_many(job, cands)):
        if float(state.U[gpus].max()) + rho / u > theta + 1e-9:
            continue
        if best is None or start + rho < best[0]:
            best = (start + rho, gpus, rho, start)
    return best


def _commit_best(state: PlacementState, job: Job, rho_nom: float,
                 u: float, theta: float) -> float | None:
    """Commit :func:`_best_candidate`; return its est finish or None."""
    best = _best_candidate(state, job, rho_nom, u, theta)
    if best is None:
        return None
    fin, gpus, rho, start = best
    state.commit(job, gpus, rho, start, u)
    return fin


# --------------------------------------------------------------------------
# sjf-bco-dynamic (arXiv:1908.08082): re-pack on completions / arrivals
# --------------------------------------------------------------------------


def _pick_victim(state: PlacementState, t: float,
                 exclude: int) -> int | None:
    """The latest-finishing job still running (estimated) at ``t`` --
    the one whose tail the re-pack can most plausibly improve.  Ties by
    jid; deterministic across engines (est_finish is bit-identical)."""
    victim, fin = None, -np.inf
    for jid, f in state.est_finish.items():
        if jid == exclude or f <= t + 1e-9:
            continue
        if f > fin or (f == fin and (victim is None or jid > victim)):
            victim, fin = jid, f
    return victim


def _trial_preempt(state: PlacementState, job: Job, victim: int, t: float,
                   rho_nom: float, u: float, theta: float,
                   cluster: Cluster) -> float | None:
    """Score {evict victim, place job, re-place residual} on a clone;
    return new finish + residual finish (the pair's summed JCT, the
    quantity SJF preemption improves) or None if infeasible.  The
    arrival commits before the residual -- that IS the preemption: the
    shorter job jumps the queue, and the residual resumes behind it on
    whatever the clocks then say -- and the order here is the order the
    live replay (and the daemon's journal bracket) uses."""
    trial = state.clone()                       # hooks cleared by clone
    residual = evict(trial, victim, t, u)
    if residual is None:
        return None
    new_fin = _commit_best(trial, job, rho_nom, u, theta)
    if new_fin is None:
        return None
    res_fin = _commit_best(trial, residual, nominal_rho(cluster, residual),
                           u, theta)
    if res_fin is None:
        return None
    return new_fin + res_fin


@register_chooser("sjf-bco-dynamic")
def sjf_bco_dynamic_chooser(cluster: Cluster, u: float,
                            params: dict) -> Chooser:
    """Online dynamic re-packing: each arrival considers preempting the
    latest-finishing running job.  The preemptive branch is trialled on a
    clone and taken only when it strictly improves the pair's summed
    finish times (arrival + victim) -- shortest-remaining-work-first in
    the two-job restriction, the quantity SJF preemption exists to
    improve -- over the non-preemptive SJF-BCO pick.  Deterministic: the
    accepted trial is re-run on the live state with identical floats,
    which is also what makes the daemon's EVICT journal replay exact."""
    rho_noms: dict[int, float] = {}

    def choose(state: PlacementState, job: Job, theta: float) -> bool:
        """Place ``job``, preempting a running victim when the summed
        pair JCT improves on the plain placement."""
        if job.jid not in rho_noms:
            rho_noms[job.jid] = nominal_rho(cluster, job)
        rho_nom = rho_noms[job.jid]
        base = _best_candidate(state, job, rho_nom, u, theta)
        t = state.now
        victim = _pick_victim(state, t, exclude=job.jid)
        plan = None
        if victim is not None:
            plan = _trial_preempt(state, job, victim, t, rho_nom, u, theta,
                                  cluster)
        base_score = np.inf if base is None \
            else base[0] + state.est_finish[victim] \
            if victim is not None else base[0]
        if plan is not None and plan + 1e-9 < base_score:
            residual = evict(state, victim, t, u)
            _commit_best(state, job, rho_nom, u, theta)
            _commit_best(state, residual, nominal_rho(cluster, residual),
                         u, theta)
            return True
        if base is None:
            return False
        _, gpus, rho, start = base
        state.commit(job, gpus, rho, start, u)
        return True

    return choose


def _replay_assignment(request: ScheduleRequest,
                       base: ScheduleResult) -> PlacementState:
    """Rebuild a live state from a committed schedule: replaying the
    assignment in order through ``refined_rho`` + ``commit`` reproduces
    the exact clocks every entry was committed against."""
    state = PlacementState(request.cluster,
                           engine=request.params.get("engine"))
    for jid, gpus in base.assignment:
        job = request.jobs[jid]
        rho, start = state.refined_rho(job, np.asarray(gpus))
        state.commit(job, np.asarray(gpus), rho, start, request.u)
    return state


def _repack_on_completions(request: ScheduleRequest, base: ScheduleResult
                           ) -> ScheduleResult | None:
    """Batch dynamic re-pack: at each of the first few estimated
    completion instants, evict every job that has not yet started and
    re-place the lot in SJF order against the then-live clocks.  Each
    event is trialled on a clone and adopted only when it tightens the
    estimated makespan.  Evicting a never-started job is a clean removal
    (done == 0), so the result is a pure re-pack -- no job is split."""
    cluster, u = request.cluster, request.u
    jobs = request.jobs
    state = _replay_assignment(request, base)
    theta = base.theta
    events = sorted(set(state.est_finish.values()))
    changed = False
    for t_c in events[: int(request.params.get("repack_events", 4))]:
        trial = state.clone()
        trial.advance_to(t_c)
        pend = [j for j, s in trial.est_start.items() if s > t_c + 1e-9]
        if not pend:
            continue
        ok = True
        residuals = []
        for j in sorted(pend, key=lambda j: (jobs[j].num_gpus, j)):
            r = evict(trial, j, t_c, u)
            if r is None:
                ok = False
                break
            residuals.append(r)
        if ok:
            for r in residuals:
                if _commit_best(trial, r, nominal_rho(cluster, r), u,
                                theta) is None:
                    ok = False
                    break
        if ok and max(trial.est_finish.values()) + 1e-9 \
                < max(state.est_finish.values()):
            state = trial
            changed = True
    if not changed:
        return None
    return finalize(state, len(jobs), theta, base.kappa, "SJF-BCO-DYN")


@register_policy("sjf-bco-dynamic")
def sjf_bco_dynamic_policy(request: ScheduleRequest) -> ScheduleResult:
    """Dynamic re-packing on completions (arXiv:1908.08082).

    Batch: a portfolio over {SJF-BCO, completion-event re-pack} decided
    by *simulated* makespan, so the policy is never worse than SJF-BCO
    on the batch grids.  Online: :func:`sjf_bco_dynamic_chooser`.
    ``params``: everything sjf-bco takes, plus ``repack_events`` (how
    many completion instants the batch re-pack examines, default 4)."""
    from repro.core.simulator import simulate
    from repro.core.sjf_bco import sjf_bco_policy
    if not request.is_batch:
        return schedule_arrivals(
            request,
            sjf_bco_dynamic_chooser(request.cluster, request.u,
                                    request.params),
            "SJF-BCO-DYN")
    base = sjf_bco_policy(request)
    repack = _repack_on_completions(request, base)
    if repack is None:
        return dataclasses.replace(base, policy="SJF-BCO-DYN")
    sim_base = simulate(request.cluster, request.jobs, base.assignment,
                        quotas=base.quotas)
    sim_re = simulate(request.cluster, request.jobs, repack.assignment,
                      quotas=repack.quotas)
    if sim_re.makespan < sim_base.makespan:
        return repack
    return dataclasses.replace(base, policy="SJF-BCO-DYN")


# --------------------------------------------------------------------------
# gadget-elastic (arXiv:2202.01158): shrink-on-pressure worker scaling
# --------------------------------------------------------------------------


def _pick_widest(state: PlacementState, t: float, emin: int) -> int | None:
    """The widest job still running (estimated) at ``t`` whose worker
    count can shrink toward ``emin``.  Ties by jid."""
    victim, width = None, 0
    for jid, e in state._entry_of.items():
        if state.est_finish.get(jid, -np.inf) <= t + 1e-9:
            continue
        g = state.placed_jobs[state.seg_row[e]].num_gpus
        if g // 2 >= emin and g > emin and \
                (g > width or (g == width and (victim is None
                                               or jid > victim))):
            victim, width = jid, g
        # (the g // 2 >= emin guard keeps the shrink meaningful)
    return victim


@register_chooser("gadget-elastic")
def gadget_elastic_chooser(cluster: Cluster, u: float,
                           params: dict) -> Chooser:
    """Online GADGET-style elasticity: place like sjf-bco; on placement
    failure, shrink the widest running job to max(elastic_min, G // 2)
    -- the lower edge of its marginal-utility window (the requested G_j
    is the upper edge) -- and place {arrival, shrunk residual}.  The
    elastic branch is all-or-nothing via a clone trial."""
    rho_noms: dict[int, float] = {}
    emin = int(params.get("elastic_min", 1))

    def choose(state: PlacementState, job: Job, theta: float) -> bool:
        """Place ``job``; on failure, shrink the widest running job and
        place {arrival, shrunk residual} all-or-nothing."""
        if job.jid not in rho_noms:
            rho_noms[job.jid] = nominal_rho(cluster, job)
        if _commit_best(state, job, rho_noms[job.jid], u, theta) is not None:
            return True
        t = state.now
        victim = _pick_widest(state, t, emin)
        if victim is None:
            return False
        width = state.placed_jobs[
            state.seg_row[state._entry_of[victim]]].num_gpus
        shrunk = max(emin, width // 2)
        trial = state.clone()
        residual = evict(trial, victim, t, u, num_gpus=shrunk)
        if residual is None:
            return False
        if _commit_best(trial, job, rho_noms[job.jid], u, theta) is None:
            return False
        if _commit_best(trial, residual, nominal_rho(cluster, residual),
                        u, theta) is None:
            return False
        residual = evict(state, victim, t, u, num_gpus=shrunk)
        _commit_best(state, job, rho_noms[job.jid], u, theta)
        _commit_best(state, residual, nominal_rho(cluster, residual),
                     u, theta)
        return True

    return choose


@register_policy("gadget-elastic")
def gadget_elastic_policy(request: ScheduleRequest) -> ScheduleResult:
    """GADGET-style elastic scheduling (arXiv:2202.01158): the epoch loop
    with :func:`gadget_elastic_chooser` -- batch is the arrivals == 0
    special case, like RAND.  ``params``: ``elastic_min`` (smallest
    worker count a job may shrink to, default 1), plus ``engine``."""
    resolve_placement(request.params)           # validate, scalar-only
    return schedule_arrivals(
        request,
        gadget_elastic_chooser(request.cluster, request.u, request.params),
        "GADGET-ELASTIC")


# --------------------------------------------------------------------------
# wang-ca (arXiv:2002.10105): contention-aware ordering baseline
# --------------------------------------------------------------------------


def _comm_share(job: Job) -> float:
    """Ring communication share: per-worker exchanged bytes
    2 * (G-1)/G * grad_size -- the quantity Wang et al. order by."""
    return 2.0 * job.grad_size * (job.num_gpus - 1) / job.num_gpus


def _wang_place(state: PlacementState, job: Job, rho_nom: float, u: float,
                theta: float) -> bool:
    """Place ``job`` on the FA-FFP/LBSGF candidate minimising the probed
    Eq. (6) contention level p first, est finish second.  ``_probe_p``
    is engine-independent, so the pick is bit-identical across engines."""
    from repro.core.sjf_bco import fa_ffp, lbsgf
    cands = []
    for picker in (fa_ffp, lbsgf):
        gpus = picker(state, job, rho_nom, u, theta)
        if gpus is not None:
            cands.append(np.asarray(gpus))
    best = None                   # (p, est_finish, gpus, rho, start)
    for gpus, (rho, start) in zip(cands, state.refined_rho_many(job, cands)):
        if float(state.U[gpus].max()) + rho / u > theta + 1e-9:
            continue
        p, _ = state._probe_p(job, state._y_of(gpus), start)
        key = (p, start + rho)
        if best is None or key < best[:2]:
            best = (p, start + rho, gpus, rho, start)
    if best is None:
        return False
    _, _, gpus, rho, start = best
    state.commit(job, gpus, rho, start, u)
    return True


@register_chooser("wang-ca")
def wang_ca_chooser(cluster: Cluster, u: float, params: dict) -> Chooser:
    """Online Wang et al. contention-aware rule: the arrival order is the
    stream's own; each job takes the minimum-contention candidate."""
    rho_noms: dict[int, float] = {}

    def choose(state: PlacementState, job: Job, theta: float) -> bool:
        """Place ``job`` on its minimum-(probed p, est finish) candidate."""
        if job.jid not in rho_noms:
            rho_noms[job.jid] = nominal_rho(cluster, job)
        return _wang_place(state, job, rho_noms[job.jid], u, theta)

    return choose


@register_policy("wang-ca")
def wang_ca_policy(request: ScheduleRequest) -> ScheduleResult:
    """Contention-aware ordering baseline (arXiv:2002.10105).

    Batch: theta bisection over an attempt that places jobs in descending
    ring-communication-share order (heaviest communicators first, while
    the cluster is emptiest), each on the candidate minimising (probed
    contention level, est finish).  Non-preemptive; the leaderboard's
    ordering-only control."""
    cluster, u = request.cluster, request.u
    resolve_placement(request.params)           # validate, scalar-only
    engine = request.params.get("engine")
    if not request.is_batch:
        return schedule_arrivals(
            request, wang_ca_chooser(cluster, u, request.params), "WANG-CA")
    jobs = request.jobs
    order = sorted(jobs, key=lambda j: (-_comm_share(j), j.jid))
    rho_noms = {j.jid: nominal_rho(cluster, j) for j in jobs}

    def attempt(theta: float) -> ScheduleResult | None:
        """One Alg. 1 trial at ``theta`` over the comm-share order."""
        state = PlacementState(cluster, engine=engine)
        for job in order:
            if not _wang_place(state, job, rho_noms[job.jid], u, theta):
                return None
        return finalize(state, len(jobs), theta, None, "WANG-CA")

    return bisect_theta(attempt, request.horizon, "WANG-CA",
                        floor=max(rho_noms.values()) / u)
