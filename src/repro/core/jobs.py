"""RAR-based DDL job model (paper §4.1) and the §7 Philly-trace workload.

Each job j requests ``G_j`` GPUs (its RAR ring width ``w_j = G_j``) and
``F_j`` training iterations.  Its per-iteration cost is governed by the
gradient size ``m_j`` (GB), mini-batch size ``M_j``, per-sample forward time
``dt_fwd`` (Delta_f) and fixed backward time ``dt_bwd`` (Delta_b).
``lam`` is the LBSGF server-spread tuning parameter lambda_j >= 1.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Job:
    jid: int
    num_gpus: int          # G_j == ring width w_j
    iters: int             # F_j, requested training iterations
    grad_size: float       # m_j, gradient bytes (GB) exchanged per iteration
    batch: int             # M_j, mini-batch size
    dt_fwd: float          # Delta_f, FP time per sample (slots)
    dt_bwd: float          # Delta_b, fixed BP time (slots)
    lam: float = 1.0       # lambda_j for LBSGF

    def __post_init__(self) -> None:
        if self.num_gpus < 1 or self.iters < 1:
            raise ValueError("job must request >=1 GPU and >=1 iteration")


# §7: 160 jobs scaled from the Microsoft Philly trace, by job-type share.
PHILLY_MIX: tuple[tuple[int, int], ...] = (
    (1, 80), (2, 14), (4, 26), (8, 30), (16, 8), (32, 2),
)


def philly_workload(
    seed: int = 0,
    mix: tuple[tuple[int, int], ...] = PHILLY_MIX,
    iters_range: tuple[int, int] = (1000, 6000),
    grad_range: tuple[float, float] = (0.5e-3, 2.0e-3),
    batch_range: tuple[int, int] = (16, 64),
    dt_fwd_per_sample: tuple[float, float] = (2.0e-4, 5.0e-4),
    dt_bwd_range: tuple[float, float] = (4.0e-3, 1.2e-2),
    lam: float = 1.0,
) -> list[Job]:
    """Generate the §7 workload (160 jobs by default).

    Constants are calibrated so that the contention-free per-iteration time
    tau_j lands in the paper's [0.01, 0.05] slots and the communication +
    overhead share is ~<=15% of the total at mild contention (§7.1).
    """
    rng = np.random.default_rng(seed)
    jobs: list[Job] = []
    jid = 0
    for gpus, count in mix:
        for _ in range(count):
            jobs.append(
                Job(
                    jid=jid,
                    num_gpus=gpus,
                    iters=int(rng.integers(*iters_range)),
                    grad_size=float(rng.uniform(*grad_range)),
                    batch=int(rng.integers(*batch_range)),
                    dt_fwd=float(rng.uniform(*dt_fwd_per_sample)),
                    dt_bwd=float(rng.uniform(*dt_bwd_range)),
                    lam=lam,
                )
            )
            jid += 1
    # Randomise arrival order within the batch (all arrive at t=0 in §7).
    order = rng.permutation(len(jobs))
    return [dataclasses.replace(jobs[i], jid=k) for k, i in enumerate(order)]


def jobs_field(jobs: list[Job], name: str) -> np.ndarray:
    """Vectorised accessor: np.array of a field across jobs."""
    return np.asarray([getattr(j, name) for j in jobs])
