"""Discrete-time execution engine for RAR-DDLS schedules.

The paper's Fig. 3 loop needs the *actual* execution time rho(y) of a
schedule, which has no closed form because contention (Eq. 6) depends on the
time-varying set of concurrently active jobs.  This simulator evaluates it:

  * a schedule is an ordered assignment [(job, gpu_ids), ...];
  * each GPU serves its assigned jobs FIFO in schedule order;
  * a job starts (gang-scheduled, non-preemptive, Eqs. 1-5) when it reaches
    the head of *all* its GPUs' queues;
  * while active, it progresses phi_j[t] = floor(1/tau_j[t]) iterations per
    slot, with tau recomputed from Eq. (8) every time the active set changes;
  * it completes once F_j iterations are accumulated (Eq. 9) and releases
    its GPUs simultaneously.

Event-driven between active-set changes (contention is piecewise constant),
so the engine is exact w.r.t. the slot model but runs in O(events).  Under
the default ``"incremental"`` engine the Eq. (6)-(8) terms are maintained
by an :class:`~repro.core.contention.IncrementalEval` across windows --
each start/finish is one O(S + affected) row update instead of a full
[J, S] re-evaluation -- with bit-identical results to the ``"reference"``
per-window :func:`~repro.core.contention.evaluate`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import Cluster
from repro.core.contention import IncrementalEval, evaluate, resolve_engine
from repro.core.jobs import Job

Assignment = list[tuple[int, np.ndarray]]  # (job index, global GPU ids)


@dataclasses.dataclass(frozen=True)
class SimEvent:
    """One piecewise-constant contention window of the execution."""

    t: int                     # window start (slot)
    dt: int                    # window length (slots)
    active: int                # #concurrently running jobs
    contention: int            # max p_j over the active set (Eq. 6)
    busy_gpus: int             # #GPUs occupied during the window


@dataclasses.dataclass
class SimResult:
    start: np.ndarray          # a_j per job (slot), -1 if never started
    finish: np.ndarray         # T_j per job (slot), -1 if never finished
    makespan: float
    avg_jct: float
    completed: int
    horizon_hit: bool
    peak_contention: int       # max p_j[t] observed
    busy_gpu_slots: float      # sum over jobs of in-service duration * G_j
    total_gpu_slots: float     # makespan * N
    events: list[SimEvent] = dataclasses.field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.busy_gpu_slots / max(self.total_gpu_slots, 1e-12)

    @property
    def mean_contention(self) -> float:
        """Time-weighted mean of the per-window max contention level."""
        total = sum(e.dt for e in self.events)
        if not total:
            return 0.0
        return sum(e.contention * e.dt for e in self.events) / total


def simulate(cluster: Cluster, jobs: list[Job], assignment: Assignment,
             horizon: int = 10**7,
             arrivals: np.ndarray | None = None,
             engine: str | None = None) -> SimResult:
    """Execute ``assignment`` on ``cluster`` and return actual timings.

    ``arrivals[j]`` (optional) forbids starting job j before its arrival
    slot (online scheduling, core/online.py).  ``engine`` selects the
    contention-model evaluation strategy: ``"reference"`` re-evaluates
    each window from scratch; anything else (``"incremental"``, and
    ``"batched"`` -- which has no meaning for the one-placement-per-window
    simulator) maintains the active set incrementally across windows.
    Results are identical either way."""
    n_jobs = len(jobs)
    incremental = resolve_engine(engine) != "reference"
    queues: list[list[int]] = [[] for _ in range(cluster.num_gpus)]
    gpu_sets: dict[int, np.ndarray] = {}
    srv_of = cluster.gpu_server
    y_rows: dict[int, np.ndarray] = {}   # per-server GPU counts per job
    for j, gpus in assignment:
        gpus = np.asarray(gpus, dtype=np.int64)
        if len(gpus) != jobs[j].num_gpus:
            raise ValueError(f"job {j}: got {len(gpus)} GPUs, wants {jobs[j].num_gpus}")
        if len(np.unique(gpus)) != len(gpus):
            raise ValueError(f"job {j}: duplicate GPUs in assignment")
        gpu_sets[j] = gpus
        y = np.zeros(cluster.num_servers, dtype=np.int64)
        np.add.at(y, srv_of[gpus], 1)
        y_rows[j] = y
        for g in gpus:
            queues[int(g)].append(j)

    remaining = np.asarray([j.iters for j in jobs], dtype=np.float64)
    start = np.full(n_jobs, -1, dtype=np.int64)
    finish = np.full(n_jobs, -1, dtype=np.int64)
    scheduled = set(gpu_sets)
    active: list[int] = []
    inc = IncrementalEval(cluster) if incremental else None
    rows: dict[int, int] = {}            # job -> IncrementalEval row handle
    t = 0
    peak_p = 0
    busy_gpu_slots = 0.0
    events: list[SimEvent] = []

    def ready_jobs(now: int) -> list[int]:
        # Iterate in sorted job order: ``scheduled`` is a set, and set order
        # would make start order -- hence FIFO tie-breaks -- depend on hash
        # seeding rather than on the schedule.
        out = []
        for j in sorted(scheduled):
            if start[j] >= 0:
                continue
            if arrivals is not None and now < arrivals[j]:
                continue
            if all(queues[int(g)] and queues[int(g)][0] == j for g in gpu_sets[j]):
                out.append(j)
        return out

    while t < horizon:
        for j in ready_jobs(t):
            start[j] = t
            active.append(j)
            if inc is not None:
                rows[j] = inc.add(jobs[j], y_rows[j])
        if not active:
            pending = [j for j in scheduled if start[j] < 0]
            if not pending:
                break
            if arrivals is not None:
                nxt = min(int(arrivals[j]) for j in pending)
                if nxt > t:
                    # Idle until the next arrival, but never past the
                    # horizon (the cutoff bounds makespan/total_gpu_slots).
                    t = min(nxt, horizon)
                    continue
            # Unstartable remainder (should not happen with FIFO queues).
            break
        sub_jobs = [jobs[j] for j in active]
        if inc is not None:
            model = inc.model([rows[j] for j in active])
        else:
            Y = cluster.placement_matrix([gpu_sets[j] for j in active])
            model = evaluate(cluster, sub_jobs, Y)
        peak_p = max(peak_p, int(model.p.max(initial=0)))
        phi = model.phi.astype(np.float64)
        if np.any(phi < 1):
            # tau > 1 slot/iteration: degenerate calibration; progress
            # fractionally so the simulation still terminates.
            phi = np.maximum(phi, 1.0 / model.tau)
        rem = remaining[active]
        slots_to_done = np.ceil(rem / phi)
        # Clamp the event window at the horizon so a job cannot "finish"
        # beyond it — horizon_hit runs stop exactly at the cutoff.
        dt = int(max(1, min(slots_to_done.min(), horizon - t)))
        remaining[active] = rem - phi * dt
        events.append(SimEvent(t=t, dt=dt, active=len(active),
                               contention=int(model.p.max(initial=0)),
                               busy_gpus=int(sum(j.num_gpus for j in sub_jobs))))
        t += dt
        done = [j for idx, j in enumerate(active) if remaining[j] <= 1e-9]
        for j in done:
            finish[j] = t
            busy_gpu_slots += (t - start[j]) * jobs[j].num_gpus
            for g in gpu_sets[j]:
                queues[int(g)].pop(0)
            if inc is not None:
                inc.remove(rows.pop(j))
        active = [j for j in active if j not in done]

    # Charge partial busy slots for jobs that started but never finished
    # (horizon hit): without this, utilization is overstated because
    # total_gpu_slots counts their window while busy_gpu_slots ignores it.
    for j in sorted(scheduled):
        if start[j] >= 0 and finish[j] < 0:
            busy_gpu_slots += (t - start[j]) * jobs[j].num_gpus

    completed = int((finish >= 0).sum())
    horizon_hit = t >= horizon
    makespan = float(finish.max(initial=0)) if not horizon_hit \
        else float(max(t, finish.max(initial=0)))
    jct = finish[finish >= 0]
    return SimResult(
        start=start, finish=finish, makespan=makespan,
        avg_jct=float(jct.mean()) if len(jct) else float("inf"),
        completed=completed,
        horizon_hit=horizon_hit,
        peak_contention=peak_p,
        busy_gpu_slots=busy_gpu_slots,
        total_gpu_slots=makespan * cluster.num_gpus,
        events=events,
    )
