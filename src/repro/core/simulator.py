"""Discrete-time execution engine for RAR-DDLS schedules.

The paper's Fig. 3 loop needs the *actual* execution time rho(y) of a
schedule, which has no closed form because contention (Eq. 6) depends on the
time-varying set of concurrently active jobs.  This simulator evaluates it:

  * a schedule is an ordered assignment [(job, gpu_ids), ...];
  * each GPU serves its assigned entries FIFO in schedule order;
  * an entry starts (gang-scheduled, Eqs. 1-5) when it reaches the head of
    *all* its GPUs' queues;
  * while active, it progresses phi_j[t] = floor(1/tau_j[t]) iterations per
    slot, with tau recomputed from Eq. (8) every time the active set changes;
  * it completes once its iteration quota is accumulated (Eq. 9) and
    releases its GPUs simultaneously.

In the paper's non-preemptive Eq. (3) setting every job is exactly one
assignment entry with quota F_j.  Preemptive schedules
(:mod:`repro.core.preempt`) may list a job id several times -- its
checkpointed SEGMENTS, each carrying an iteration quota (the
``quotas`` argument, produced by ``ScheduleResult.quotas``); segments of
one job execute in assignment order (a segment cannot start before its
predecessor completes -- the checkpoint-restart dependency), may sit on
different GPU sets (migration) and even different worker counts (elastic
resize; the contention terms use the segment's width).  The job starts
at its first segment's start and finishes at its last segment's finish.
All internal bookkeeping is keyed by assignment entry; for
single-segment schedules (quotas=None) every ordering tie-break reduces
to the job-id FIFO order of earlier releases, so results are
bit-identical to the non-preemptive engine.

Event-driven between active-set changes (contention is piecewise constant),
so the engine is exact w.r.t. the slot model but runs in O(events).  Under
the default ``"incremental"`` engine the Eq. (6)-(8) terms are maintained
by an :class:`~repro.core.contention.IncrementalEval` across windows --
each start/finish is one O(S + affected) row update instead of a full
[J, S] re-evaluation -- with bit-identical results to the ``"reference"``
per-window :func:`~repro.core.contention.evaluate`.

Readiness tracking (which queued entries may start at an event boundary)
also has two bit-identical modes, selected with ``readiness``:

  * ``"tracked"`` (default) -- incremental: per-GPU queue-head pointers and
    a per-entry "GPUs-at-head" counter, updated only when an entry finishes
    (O(G) per completion), plus arrival-sorted heaps.  Each event touches
    only the entries it affects.  Segment precedence enters as one extra
    gate: an entry whose GPUs are all at head but whose predecessor segment
    is unfinished parks until that completion re-checks it.
  * ``"rescan"`` -- the reference O(E * G) per-event rescan of every
    scheduled entry against every queue head, kept as the semantics oracle
    (``tests/test_simulator_equivalence.py`` pins event-for-event
    equality).

Both modes start ready entries in sorted (job id, segment) order (the
FIFO tie-break), so the SimEvent stream, start/finish arrays and all
derived metrics are identical.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import contention
from repro.core.cluster import Cluster
from repro.core.contention import (IncrementalEval, evaluate, ladder_terms,
                                   resolve_engine, tau_ladder)
from repro.core.jobs import Job

Assignment = list[tuple[int, np.ndarray]]  # (job index, global GPU ids)

READINESS_MODES = ("tracked", "rescan")
STEPPING_MODES = ("multi", "single")

# Cap on how many completion stages ahead a multi-window ladder
# precomputes per stack_model call.  The actual depth ramps adaptively:
# shallow while job starts keep invalidating ladders (each start changes
# every row's contention), doubling whenever a ladder is exhausted by a
# long start-free run of windows.
LADDER_DEPTH = 32


@dataclasses.dataclass(frozen=True)
class SimEvent:
    """One piecewise-constant contention window of the execution.

    Idle windows (the cluster waiting for the next arrival) are recorded
    too, with ``active == 0`` and ``busy_gpus == 0``, so time-weighted
    statistics over the event stream cover the whole run, not just busy
    time."""

    t: int                     # window start (slot)
    dt: int                    # window length (slots)
    active: int                # #concurrently running entries (0 = idle gap)
    contention: int            # max p_j over the active set (Eq. 6)
    busy_gpus: int             # #GPUs occupied during the window


@dataclasses.dataclass
class SimResult:
    start: np.ndarray          # a_j per job (slot), -1 if never started
    finish: np.ndarray         # T_j per job (slot), -1 if never finished
    makespan: float
    avg_jct: float             # mean(finish - arrival) over completed jobs
    avg_queueing_delay: float  # mean(start - arrival) over completed jobs
    completed: int
    horizon_hit: bool
    peak_contention: int       # max p_j[t] observed
    busy_gpu_slots: float      # sum over entries of in-service time * width
    total_gpu_slots: float     # makespan * N

    events: list[SimEvent] = dataclasses.field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.busy_gpu_slots / max(self.total_gpu_slots, 1e-12)

    @property
    def mean_contention(self) -> float:
        """Time-weighted mean of the per-window max contention level.

        Weighted over the full event stream -- including zero-active idle
        windows -- so the mean reflects wall-clock time, not busy time."""
        total = sum(e.dt for e in self.events)
        if not total:
            return 0.0
        return sum(e.contention * e.dt for e in self.events) / total


def simulate(cluster: Cluster, jobs: list[Job], assignment: Assignment,
             horizon: int = 10**7,
             arrivals: np.ndarray | None = None,
             engine: str | None = None,
             readiness: str = "tracked",
             stepping: str | None = None,
             quotas: np.ndarray | list | None = None) -> SimResult:
    """Execute ``assignment`` on ``cluster`` and return actual timings.

    ``arrivals[j]`` (optional) forbids starting job j before its arrival
    slot (online scheduling); ``avg_jct`` is then the mean of
    ``finish - arrival`` over completed jobs (with ``arrivals=None``
    every job arrives at slot 0, so it reduces to the mean finish slot).

    ``quotas`` (optional) gives the iteration quota of each assignment
    entry (same length/order as ``assignment``) and unlocks the
    preemptive interpretation: a job id may then appear in several
    entries -- its checkpoint-restart segments, executed in assignment
    order -- and an entry's GPU count may differ from the job's
    requested G_j (elastic resize).  Without it (the default), every
    job must appear exactly once with exactly its requested GPUs and
    its quota is F_j -- the paper's Eq. (3) setting, bit-identical to
    the pre-preemption engine.

    ``engine`` selects the contention-model evaluation strategy:
    ``"reference"`` re-evaluates each window from scratch; anything else
    (``"incremental"``, and ``"batched"`` -- which has no meaning for the
    one-placement-per-window simulator) maintains the active set
    incrementally across windows.  ``readiness`` selects how queue-ready
    entries are discovered (``"tracked"`` incremental counters, the
    default, vs the ``"rescan"`` reference; see the module docstring).

    ``stepping`` selects how window models are produced between active-set
    changes:

      * ``"multi"`` -- speculative multi-window ladders: while the
        tracked-readiness bookkeeping shows no arrivals or queue-head
        promotions, the Eq. (6)-(8) terms for the next ``LADDER_DEPTH``
        completion stages are computed in one vectorised
        :func:`~repro.core.contention.stack_model` batch over a
        [M, A, S] stack with shrinking row masks (guessed completion
        order, verified window by window, rebuilt on mispredict);
      * ``"single"`` -- one model per window (the IncrementalEval /
        reference path of earlier releases);
      * ``None`` (default) -- ``"multi"`` whenever both oracle axes are
        off (tracked readiness, non-reference engine), else ``"single"``.

    Results are identical across engines, readiness and stepping modes
    (pinned by ``tests/test_simulator_equivalence.py``,
    ``tests/test_preempt_equivalence.py`` and
    ``tests/test_bisect_equivalence.py``)."""
    n_jobs = len(jobs)
    incremental = resolve_engine(engine) != "reference"
    if readiness not in READINESS_MODES:
        raise ValueError(
            f"unknown readiness mode {readiness!r}; choose from {READINESS_MODES}")
    tracked = readiness == "tracked"
    if stepping is not None and stepping not in STEPPING_MODES:
        raise ValueError(
            f"unknown stepping mode {stepping!r}; choose from {STEPPING_MODES}")
    if stepping == "multi" and not (tracked and incremental):
        raise ValueError(
            'stepping="multi" needs readiness="tracked" and a non-reference '
            "engine (the rescan/reference combinations are the "
            "event-for-event oracle and step one window at a time)")
    multiwindow = (tracked and incremental) if stepping is None \
        else stepping == "multi"
    if arrivals is not None:
        arrivals = np.asarray(arrivals)
    E = len(assignment)
    if quotas is not None:
        quotas = np.asarray(quotas, dtype=np.float64)
        if quotas.shape != (E,):
            raise ValueError(
                f"quotas shape {quotas.shape} != ({E},): one iteration "
                "quota per assignment entry")

    # ----- entry-keyed schedule bookkeeping --------------------------------
    # ekey = (jid, segment index) orders every tie-break; single-segment
    # schedules make it (jid, 0), i.e. the legacy jid order.
    queues: list[list[int]] = [[] for _ in range(cluster.num_gpus)]
    gpu_sets: list[np.ndarray] = []
    entry_jobs: list[Job] = []
    ent_jid = np.empty(E, dtype=np.int64)
    ent_seg = np.empty(E, dtype=np.int64)
    seg_count: dict[int, int] = {}
    srv_of = cluster.gpu_server
    flat_ent: list[int] = []
    flat_gpu: list[int] = []
    for e, (j, gpus) in enumerate(assignment):
        gpus = np.asarray(gpus, dtype=np.int64)
        if len(gpus) != jobs[j].num_gpus:
            if quotas is None:
                raise ValueError(
                    f"job {j}: got {len(gpus)} GPUs, wants {jobs[j].num_gpus}")
            # Elastic segment: the contention terms use its actual width.
            entry_jobs.append(dataclasses.replace(jobs[j],
                                                  num_gpus=len(gpus)))
        else:
            entry_jobs.append(jobs[j])
        ids = gpus.tolist()
        if len(set(ids)) != len(ids):
            raise ValueError(f"job {j}: duplicate GPUs in assignment")
        gpu_sets.append(gpus)
        ent_jid[e] = j
        ent_seg[e] = seg_count.get(j, 0)
        seg_count[j] = int(ent_seg[e]) + 1
        for g in ids:
            queues[g].append(e)
            flat_ent.append(e)
            flat_gpu.append(g)
    if quotas is None:
        for j, c in seg_count.items():
            if c > 1:
                raise ValueError(
                    f"job {j} appears in {c} assignment entries; "
                    "preemptive (multi-segment) schedules must pass quotas")
    # Segment precedence: pred/succ chains in assignment order.
    pred = np.full(E, -1, dtype=np.int64)
    succ = np.full(E, -1, dtype=np.int64)
    last_entry: dict[int, int] = {}
    for e in range(E):
        j = int(ent_jid[e])
        if j in last_entry:
            pred[e] = last_entry[j]
            succ[last_entry[j]] = e
        last_entry[j] = e
    # All entries' per-server GPU counts in one bincount over
    # (entry, server) pairs -- same integer counts as a per-entry
    # bincount, one C call.
    S = cluster.num_servers
    y_ent = np.bincount(
        np.asarray(flat_ent, dtype=np.int64) * S
        + srv_of[np.asarray(flat_gpu, dtype=np.int64)],
        minlength=E * S).reshape(E, S)

    rem_ent = quotas.copy() if quotas is not None else np.asarray(
        [entry_jobs[e].iters for e in range(E)], dtype=np.float64)
    widths = np.asarray([len(g) for g in gpu_sets], dtype=np.int64)
    e_start = np.full(E, -1, dtype=np.int64)
    e_finish = np.full(E, -1, dtype=np.int64)
    start = np.full(n_jobs, -1, dtype=np.int64)
    finish = np.full(n_jobs, -1, dtype=np.int64)
    ents_sorted = sorted(range(E),
                         key=lambda e: (ent_jid[e], ent_seg[e]))
    active: list[int] = []
    inc = IncrementalEval(cluster) if incremental and not multiwindow else None
    rows: dict[int, int] = {}          # entry -> IncrementalEval row handle
    t = 0
    peak_p = 0
    busy_now = 0                       # GPUs occupied by active entries
    busy_gpu_slots = 0.0
    events: list[SimEvent] = []

    def pred_done(e: int) -> bool:
        p = pred[e]
        return p < 0 or e_finish[p] >= 0

    ladder: dict | None = None           # multi-window stage cache
    model_vals: tuple | None = None      # (p, tau, phi) for `active` order
    if multiwindow:
        # Placement-independent Eq. (6)/(8) terms, computed once per run,
        # per assignment entry; ladder stacks gather rows of them.
        terms = ladder_terms(cluster, entry_jobs, y_ent)
        phi_last = np.ones(E)            # ordering hint for the guess
        ladder_ramp = 2                  # adaptive stage depth (see below)

        def build_ladder(act: list[int]) -> dict:
            """One stack_model batch covering the next LADDER_DEPTH
            completion stages of ``act``: stage s masks out the first s
            entries of the guessed completion order (ascending slots-to-
            finish at current rates, stable on the active order).  The
            guess only selects which stacks exist -- each window's
            completions are computed from the stage values and verified
            against the guess, so a mispredicted order costs one rebuild
            and never changes results."""
            act_arr = np.asarray(act, dtype=np.int64)
            A = len(act)
            keys = np.ceil(rem_ent[act_arr] / phi_last[act_arr])
            order = np.lexsort((np.arange(A), keys))
            ents = [act[i] for i in order]
            depth = min(A - 1, ladder_ramp)
            ent_arr = act_arr[order]
            p, tau, phi = tau_ladder(cluster, terms, ent_arr, depth)
            contention.EVAL_COUNTS["ladder_calls"] += 1
            contention.EVAL_COUNTS["ladder_rows"] += depth + 1
            # "rem" caches `rem_ent` in ladder order so window updates
            # are contiguous slice writes; flushed back on invalidation.
            return {"ents": ents, "ent_arr": ent_arr, "stage": 0,
                    "depth": depth, "p": p, "tau": tau, "phi": phi,
                    "rem": rem_ent[ent_arr]}

        def flush_ladder(lad: dict | None) -> None:
            """Write the ladder-ordered remaining cache back before the
            ladder is dropped (build_ladder reads ``rem_ent``)."""
            if lad is not None:
                rem_ent[lad["ent_arr"]] = lad["rem"]

    def _arrival_of(e: int) -> int:
        return int(arrivals[ent_jid[e]]) if arrivals is not None else 0

    if tracked:
        # Incremental readiness: head pointer per GPU queue, and for each
        # unstarted entry the count of its GPUs where it is at the head.
        # An entry is queue-ready when that count reaches its width, which
        # happens exactly once; if its predecessor segment is unfinished
        # it parks (``head_ready``) until that completion re-checks it,
        # otherwise it waits (if needed) in an arrival-sorted heap until
        # its arrival slot.  Startable entries pop in ascending
        # (jid, segment) order -- the same FIFO tie-break as the rescan
        # reference (and plain jid order for single-segment schedules).
        qpos = [0] * cluster.num_gpus
        at_head = [0] * E
        head_ready = [False] * E     # queue-ready, parked on predecessor
        for q in queues:
            if q:
                at_head[q[0]] += 1
        startable: list[tuple[int, int, int]] = []   # (jid, seg, e) heap
        arrival_wait: list[tuple[int, int, int, int]] = []  # + arrival key
        for e in ents_sorted:
            if at_head[e] == widths[e]:
                if pred_done(e):
                    heapq.heappush(arrival_wait,
                                   (_arrival_of(e), int(ent_jid[e]),
                                    int(ent_seg[e]), e))
                else:
                    head_ready[e] = True
        # All unstarted entries, arrival-sorted, for the idle-gap jump;
        # started entries are discarded lazily.
        pending_heap = [(_arrival_of(e), int(ent_jid[e]), int(ent_seg[e]), e)
                        for e in range(E)]
        heapq.heapify(pending_heap)
        n_unstarted = E

        def ready_jobs(now: int) -> list[int]:
            while arrival_wait and arrival_wait[0][0] <= now:
                _, j, s, e = heapq.heappop(arrival_wait)
                heapq.heappush(startable, (j, s, e))
            out = []
            while startable:
                out.append(heapq.heappop(startable)[2])
            return out

        def _now_head_ready(e2: int) -> None:
            if pred_done(e2):
                heapq.heappush(arrival_wait,
                               (_arrival_of(e2), int(ent_jid[e2]),
                                int(ent_seg[e2]), e2))
            else:
                head_ready[e2] = True

        def release_gpus(e: int) -> None:
            # Advance the head pointer on each freed GPU; the new head
            # entry gains one GPU-at-head (it cannot already be running:
            # it was not at the head of this queue until now).
            for g in gpu_sets[e]:
                gi = int(g)
                qpos[gi] += 1
                q = queues[gi]
                if qpos[gi] < len(q):
                    e2 = q[qpos[gi]]
                    at_head[e2] += 1
                    if at_head[e2] == widths[e2]:
                        _now_head_ready(e2)

        def next_pending_arrival() -> int:
            while pending_heap and e_start[pending_heap[0][3]] >= 0:
                heapq.heappop(pending_heap)
            return pending_heap[0][0]
    else:
        def ready_jobs(now: int) -> list[int]:
            # Iterate in sorted (jid, segment) order so start order --
            # hence FIFO tie-breaks -- depends on the schedule, not on
            # set/hash ordering.
            out = []
            for e in ents_sorted:
                if e_start[e] >= 0:
                    continue
                if arrivals is not None and now < arrivals[ent_jid[e]]:
                    continue
                if not pred_done(e):
                    continue
                if all(queues[int(g)] and queues[int(g)][0] == e
                       for g in gpu_sets[e]):
                    out.append(e)
            return out

        def release_gpus(e: int) -> None:
            for g in gpu_sets[e]:
                queues[int(g)].pop(0)

        def next_pending_arrival() -> int:
            return min(_arrival_of(e) for e in range(E) if e_start[e] < 0)

    while t < horizon:
        if tracked and not startable \
                and not (arrival_wait and arrival_wait[0][0] <= t):
            starters = ()        # fast path: provably nothing to start
        else:
            starters = ready_jobs(t)
        for e in starters:
            e_start[e] = t
            j = int(ent_jid[e])
            if start[j] < 0:     # first segment sets the job's start
                start[j] = t
            active.append(e)
            busy_now += int(widths[e])
            if tracked:
                n_unstarted -= 1
            if inc is not None:
                rows[e] = inc.add(entry_jobs[e], y_ent[e])
            elif multiwindow:
                # A start changes every row's contention; precomputed
                # stages for the old active set no longer apply.  Frequent
                # starts also mean deep ladders would mostly be wasted,
                # so the ramp decays back towards shallow batches.
                if ladder is not None and ladder["stage"] == 0:
                    ladder_ramp = max(2, ladder_ramp // 2)
                flush_ladder(ladder)
                ladder = None
                model_vals = None
        if not active:
            has_pending = (n_unstarted > 0) if tracked \
                else bool((e_start < 0).any())
            if not has_pending:
                break
            if arrivals is not None:
                nxt = next_pending_arrival()
                if nxt > t:
                    # Idle until the next arrival, but never past the
                    # horizon (the cutoff bounds makespan/total_gpu_slots).
                    # Recorded as a zero-active window so time-weighted
                    # stats cover the gap.
                    nt = min(nxt, horizon)
                    events.append(SimEvent(t=t, dt=nt - t, active=0,
                                           contention=0, busy_gpus=0))
                    t = nt
                    continue
            # Unstartable remainder (cannot happen with FIFO queues: the
            # earliest-committed unfinished entry is at the head of all
            # its queues and its predecessor -- committed earlier -- has
            # finished).
            break
        if multiwindow:
            if model_vals is None:
                if ladder is None:
                    ladder = build_ladder(active)
                    # Keep the active list in ladder (guessed-completion)
                    # order: a stage's surviving rows are then contiguous
                    # slices of the stage arrays, so per-window model
                    # access is a view, not a gather.  Active order never
                    # affects outputs (all window quantities are
                    # aggregates or per-entry values).
                    active = list(ladder["ents"])
                s = ladder["stage"]
                model_vals = (ladder["p"][s, s:], ladder["tau"][s, s:],
                              ladder["phi"][s, s:])
            p_arr, tau_arr, phi_raw = model_vals
        elif inc is not None:
            p_arr, tau_arr, phi_raw = inc.window([rows[e] for e in active])
        else:
            sub_jobs = [entry_jobs[e] for e in active]
            Y = cluster.placement_matrix([gpu_sets[e] for e in active])
            model = evaluate(cluster, sub_jobs, Y)
            p_arr, tau_arr, phi_raw = model.p, model.tau, model.phi
        pmax = int(p_arr.max(initial=0))
        peak_p = max(peak_p, pmax)
        if (phi_raw < 1).any():
            # tau > 1 slot/iteration: degenerate calibration; progress
            # fractionally so the simulation still terminates.  (Integer
            # phi upcasts exactly to float64, so skipping the astype on
            # the common path changes nothing downstream.)
            phi = np.maximum(phi_raw.astype(np.float64), 1.0 / tau_arr)
        else:
            phi = phi_raw
        if multiwindow:
            s0 = ladder["stage"]
            act = ladder["ent_arr"][s0:]
            phi_last[act] = phi          # ordering hint for ladder guesses
            rem = ladder["rem"][s0:]
        else:
            act = np.asarray(active, dtype=np.int64)
            rem = rem_ent[act]
        # min of ceils == ceil of min (ceil is monotone), so one scalar
        # ceil after the reduction replaces the array-wide one.
        # Clamp the event window at the horizon so a job cannot "finish"
        # beyond it — horizon_hit runs stop exactly at the cutoff.
        dt = int(max(1, min(np.ceil((rem / phi).min()), horizon - t)))
        rem_after = rem - phi * dt
        if multiwindow:
            ladder["rem"][s0:] = rem_after
        else:
            rem_ent[act] = rem_after
        events.append(SimEvent(t=t, dt=dt, active=len(active),
                               contention=pmax, busy_gpus=busy_now))
        t += dt
        done_mask = rem_after <= 1e-9
        if done_mask.any():
            keep: list[int] = []
            done_now: list[int] = []
            for e, done in zip(active, done_mask):
                if not done:
                    keep.append(e)
                    continue
                done_now.append(e)
                e_finish[e] = t
                if succ[e] < 0:      # last segment completes the job
                    finish[ent_jid[e]] = t
                busy_gpu_slots += (t - e_start[e]) * int(widths[e])
                busy_now -= int(widths[e])
                release_gpus(e)
                if tracked and succ[e] >= 0 and head_ready[succ[e]]:
                    # The successor segment was parked on this completion
                    # (its GPUs were already all at head).
                    s2 = int(succ[e])
                    head_ready[s2] = False
                    heapq.heappush(arrival_wait,
                                   (_arrival_of(s2), int(ent_jid[s2]),
                                    int(ent_seg[s2]), s2))
                if inc is not None:
                    inc.remove(rows.pop(e))
            active = keep
            if multiwindow:
                # Advance the ladder past this window's completions when
                # they match the guessed prefix (stacks depend only on
                # the removed SET, so order within the prefix is free);
                # otherwise drop it and rebuild from the live state.  A
                # ladder exhausted by a long start-free run doubles the
                # ramp so the next batch covers more stages per call.
                model_vals = None
                if active and ladder is not None:
                    k, c = ladder["stage"], len(done_now)
                    if k + c <= ladder["depth"] and \
                            set(ladder["ents"][k:k + c]) == set(done_now):
                        ladder["stage"] = k + c
                    else:
                        if k + c > ladder["depth"] >= len(active):
                            pass          # depth already spans the run
                        elif k + c > ladder["depth"]:
                            ladder_ramp = min(LADDER_DEPTH, ladder_ramp * 2)
                        flush_ladder(ladder)
                        ladder = None
                else:
                    flush_ladder(ladder)
                    ladder = None

    # Charge partial busy slots for entries that started but never finished
    # (horizon hit): without this, utilization is overstated because
    # total_gpu_slots counts their window while busy_gpu_slots ignores it.
    for e in ents_sorted:
        if e_start[e] >= 0 and e_finish[e] < 0:
            busy_gpu_slots += (t - e_start[e]) * int(widths[e])

    completed_mask = finish >= 0
    completed = int(completed_mask.sum())
    horizon_hit = t >= horizon
    makespan = float(finish.max(initial=0)) if not horizon_hit \
        else float(max(t, finish.max(initial=0)))
    if arrivals is not None:
        # JCT is time-in-system: finish minus arrival, not the absolute
        # finish slot (those only coincide when everything arrives at 0).
        jct = (finish[completed_mask]
               - arrivals[completed_mask]).astype(np.float64)
        # Queueing delay is time-to-service: start minus arrival.  Over
        # the same completed set, avg_jct == avg_queueing_delay + the
        # mean in-service time (finish - start) by construction.
        qd = (start[completed_mask]
              - arrivals[completed_mask]).astype(np.float64)
    else:
        jct = finish[completed_mask]
        qd = start[completed_mask].astype(np.float64)
    return SimResult(
        start=start, finish=finish, makespan=makespan,
        avg_jct=float(jct.mean()) if len(jct) else float("inf"),
        avg_queueing_delay=float(qd.mean()) if len(qd) else float("inf"),
        completed=completed,
        horizon_hit=horizon_hit,
        peak_contention=peak_p,
        busy_gpu_slots=busy_gpu_slots,
        total_gpu_slots=makespan * cluster.num_gpus,
        events=events,
    )
