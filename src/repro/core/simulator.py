"""Discrete-time execution engine for RAR-DDLS schedules.

The paper's Fig. 3 loop needs the *actual* execution time rho(y) of a
schedule, which has no closed form because contention (Eq. 6) depends on the
time-varying set of concurrently active jobs.  This simulator evaluates it:

  * a schedule is an ordered assignment [(job, gpu_ids), ...];
  * each GPU serves its assigned jobs FIFO in schedule order;
  * a job starts (gang-scheduled, non-preemptive, Eqs. 1-5) when it reaches
    the head of *all* its GPUs' queues;
  * while active, it progresses phi_j[t] = floor(1/tau_j[t]) iterations per
    slot, with tau recomputed from Eq. (8) every time the active set changes;
  * it completes once F_j iterations are accumulated (Eq. 9) and releases
    its GPUs simultaneously.

Event-driven between active-set changes (contention is piecewise constant),
so the engine is exact w.r.t. the slot model but runs in O(events).  Under
the default ``"incremental"`` engine the Eq. (6)-(8) terms are maintained
by an :class:`~repro.core.contention.IncrementalEval` across windows --
each start/finish is one O(S + affected) row update instead of a full
[J, S] re-evaluation -- with bit-identical results to the ``"reference"``
per-window :func:`~repro.core.contention.evaluate`.

Readiness tracking (which queued jobs may start at an event boundary) also
has two bit-identical modes, selected with ``readiness``:

  * ``"tracked"`` (default) -- incremental: per-GPU queue-head pointers and
    a per-job "GPUs-at-head" counter, updated only when a job finishes
    (O(G_j) per completion), plus arrival-sorted heaps.  Each event touches
    only the jobs it affects.
  * ``"rescan"`` -- the reference O(J * G) per-event rescan of every
    scheduled job against every queue head, kept as the semantics oracle
    (``tests/test_simulator_equivalence.py`` pins event-for-event
    equality).

Both modes start ready jobs in sorted job-id order (the FIFO tie-break),
so the SimEvent stream, start/finish arrays and all derived metrics are
identical.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.cluster import Cluster
from repro.core.contention import IncrementalEval, evaluate, resolve_engine
from repro.core.jobs import Job

Assignment = list[tuple[int, np.ndarray]]  # (job index, global GPU ids)

READINESS_MODES = ("tracked", "rescan")


@dataclasses.dataclass(frozen=True)
class SimEvent:
    """One piecewise-constant contention window of the execution.

    Idle windows (the cluster waiting for the next arrival) are recorded
    too, with ``active == 0`` and ``busy_gpus == 0``, so time-weighted
    statistics over the event stream cover the whole run, not just busy
    time."""

    t: int                     # window start (slot)
    dt: int                    # window length (slots)
    active: int                # #concurrently running jobs (0 = idle gap)
    contention: int            # max p_j over the active set (Eq. 6)
    busy_gpus: int             # #GPUs occupied during the window


@dataclasses.dataclass
class SimResult:
    start: np.ndarray          # a_j per job (slot), -1 if never started
    finish: np.ndarray         # T_j per job (slot), -1 if never finished
    makespan: float
    avg_jct: float             # mean(finish - arrival) over completed jobs
    completed: int
    horizon_hit: bool
    peak_contention: int       # max p_j[t] observed
    busy_gpu_slots: float      # sum over jobs of in-service duration * G_j
    total_gpu_slots: float     # makespan * N
    events: list[SimEvent] = dataclasses.field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.busy_gpu_slots / max(self.total_gpu_slots, 1e-12)

    @property
    def mean_contention(self) -> float:
        """Time-weighted mean of the per-window max contention level.

        Weighted over the full event stream -- including zero-active idle
        windows -- so the mean reflects wall-clock time, not busy time."""
        total = sum(e.dt for e in self.events)
        if not total:
            return 0.0
        return sum(e.contention * e.dt for e in self.events) / total


def simulate(cluster: Cluster, jobs: list[Job], assignment: Assignment,
             horizon: int = 10**7,
             arrivals: np.ndarray | None = None,
             engine: str | None = None,
             readiness: str = "tracked") -> SimResult:
    """Execute ``assignment`` on ``cluster`` and return actual timings.

    ``arrivals[j]`` (optional) forbids starting job j before its arrival
    slot (online scheduling, core/online.py); ``avg_jct`` is then the mean
    of ``finish - arrival`` over completed jobs (with ``arrivals=None``
    every job arrives at slot 0, so it reduces to the mean finish slot).

    ``engine`` selects the contention-model evaluation strategy:
    ``"reference"`` re-evaluates each window from scratch; anything else
    (``"incremental"``, and ``"batched"`` -- which has no meaning for the
    one-placement-per-window simulator) maintains the active set
    incrementally across windows.  ``readiness`` selects how queue-ready
    jobs are discovered (``"tracked"`` incremental counters, the default,
    vs the ``"rescan"`` reference; see the module docstring).  Results are
    identical across engines and readiness modes."""
    n_jobs = len(jobs)
    incremental = resolve_engine(engine) != "reference"
    if readiness not in READINESS_MODES:
        raise ValueError(
            f"unknown readiness mode {readiness!r}; choose from {READINESS_MODES}")
    tracked = readiness == "tracked"
    if arrivals is not None:
        arrivals = np.asarray(arrivals)
    queues: list[list[int]] = [[] for _ in range(cluster.num_gpus)]
    gpu_sets: dict[int, np.ndarray] = {}
    srv_of = cluster.gpu_server
    y_rows: dict[int, np.ndarray] = {}   # per-server GPU counts per job
    for j, gpus in assignment:
        gpus = np.asarray(gpus, dtype=np.int64)
        if len(gpus) != jobs[j].num_gpus:
            raise ValueError(f"job {j}: got {len(gpus)} GPUs, wants {jobs[j].num_gpus}")
        if len(np.unique(gpus)) != len(gpus):
            raise ValueError(f"job {j}: duplicate GPUs in assignment")
        gpu_sets[j] = gpus
        y = np.zeros(cluster.num_servers, dtype=np.int64)
        np.add.at(y, srv_of[gpus], 1)
        y_rows[j] = y
        for g in gpus:
            queues[int(g)].append(j)

    remaining = np.asarray([j.iters for j in jobs], dtype=np.float64)
    start = np.full(n_jobs, -1, dtype=np.int64)
    finish = np.full(n_jobs, -1, dtype=np.int64)
    scheduled = set(gpu_sets)
    active: list[int] = []
    inc = IncrementalEval(cluster) if incremental else None
    rows: dict[int, int] = {}            # job -> IncrementalEval row handle
    t = 0
    peak_p = 0
    busy_now = 0                         # GPUs occupied by active jobs
    busy_gpu_slots = 0.0
    events: list[SimEvent] = []

    def _arrival_of(j: int) -> int:
        return int(arrivals[j]) if arrivals is not None else 0

    if tracked:
        # Incremental readiness: head pointer per GPU queue, and for each
        # unstarted job the count of its GPUs where it is at the head.
        # A job is queue-ready when that count reaches G_j, which happens
        # exactly once; it then waits (if needed) in an arrival-sorted
        # heap until its arrival slot.  Startable jobs pop in ascending
        # jid order -- the same FIFO tie-break as the rescan reference.
        qpos = [0] * cluster.num_gpus
        n_gpus_of = {j: len(gpu_sets[j]) for j in scheduled}
        at_head = dict.fromkeys(scheduled, 0)
        for q in queues:
            if q:
                at_head[q[0]] += 1
        startable: list[int] = []              # jid min-heap: ready + arrived
        arrival_wait: list[tuple[int, int]] = []   # (arrival, jid) min-heap
        for j in sorted(scheduled):
            if at_head[j] == n_gpus_of[j]:
                heapq.heappush(arrival_wait, (_arrival_of(j), j))
        # All unstarted jobs, arrival-sorted, for the idle-gap jump; started
        # entries are discarded lazily.
        pending_heap = [(_arrival_of(j), j) for j in scheduled]
        heapq.heapify(pending_heap)
        n_unstarted = len(scheduled)

        def ready_jobs(now: int) -> list[int]:
            while arrival_wait and arrival_wait[0][0] <= now:
                heapq.heappush(startable, heapq.heappop(arrival_wait)[1])
            out = []
            while startable:
                out.append(heapq.heappop(startable))
            return out

        def release_gpus(j: int) -> None:
            # Advance the head pointer on each freed GPU; the new head job
            # gains one GPU-at-head (it cannot already be running: it was
            # not at the head of this queue until now).
            for g in gpu_sets[j]:
                gi = int(g)
                qpos[gi] += 1
                q = queues[gi]
                if qpos[gi] < len(q):
                    j2 = q[qpos[gi]]
                    at_head[j2] += 1
                    if at_head[j2] == n_gpus_of[j2]:
                        heapq.heappush(arrival_wait, (_arrival_of(j2), j2))

        def next_pending_arrival() -> int:
            while pending_heap and start[pending_heap[0][1]] >= 0:
                heapq.heappop(pending_heap)
            return pending_heap[0][0]
    else:
        def ready_jobs(now: int) -> list[int]:
            # Iterate in sorted job order: ``scheduled`` is a set, and set
            # order would make start order -- hence FIFO tie-breaks --
            # depend on hash seeding rather than on the schedule.
            out = []
            for j in sorted(scheduled):
                if start[j] >= 0:
                    continue
                if arrivals is not None and now < arrivals[j]:
                    continue
                if all(queues[int(g)] and queues[int(g)][0] == j
                       for g in gpu_sets[j]):
                    out.append(j)
            return out

        def release_gpus(j: int) -> None:
            for g in gpu_sets[j]:
                queues[int(g)].pop(0)

        def next_pending_arrival() -> int:
            return min(_arrival_of(j) for j in scheduled if start[j] < 0)

    while t < horizon:
        for j in ready_jobs(t):
            start[j] = t
            active.append(j)
            busy_now += jobs[j].num_gpus
            if tracked:
                n_unstarted -= 1
            if inc is not None:
                rows[j] = inc.add(jobs[j], y_rows[j])
        if not active:
            has_pending = (n_unstarted > 0) if tracked \
                else any(start[j] < 0 for j in scheduled)
            if not has_pending:
                break
            if arrivals is not None:
                nxt = next_pending_arrival()
                if nxt > t:
                    # Idle until the next arrival, but never past the
                    # horizon (the cutoff bounds makespan/total_gpu_slots).
                    # Recorded as a zero-active window so time-weighted
                    # stats cover the gap.
                    nt = min(nxt, horizon)
                    events.append(SimEvent(t=t, dt=nt - t, active=0,
                                           contention=0, busy_gpus=0))
                    t = nt
                    continue
            # Unstartable remainder (should not happen with FIFO queues).
            break
        if inc is not None:
            p_arr, tau_arr, phi_raw = inc.window([rows[j] for j in active])
        else:
            sub_jobs = [jobs[j] for j in active]
            Y = cluster.placement_matrix([gpu_sets[j] for j in active])
            model = evaluate(cluster, sub_jobs, Y)
            p_arr, tau_arr, phi_raw = model.p, model.tau, model.phi
        pmax = int(p_arr.max(initial=0))
        peak_p = max(peak_p, pmax)
        phi = phi_raw.astype(np.float64)
        if np.any(phi < 1):
            # tau > 1 slot/iteration: degenerate calibration; progress
            # fractionally so the simulation still terminates.
            phi = np.maximum(phi, 1.0 / tau_arr)
        act = np.asarray(active, dtype=np.int64)
        rem = remaining[act]
        slots_to_done = np.ceil(rem / phi)
        # Clamp the event window at the horizon so a job cannot "finish"
        # beyond it — horizon_hit runs stop exactly at the cutoff.
        dt = int(max(1, min(slots_to_done.min(), horizon - t)))
        rem_after = rem - phi * dt
        remaining[act] = rem_after
        events.append(SimEvent(t=t, dt=dt, active=len(active),
                               contention=pmax, busy_gpus=busy_now))
        t += dt
        done_mask = rem_after <= 1e-9
        if done_mask.any():
            keep: list[int] = []
            for j, done in zip(active, done_mask):
                if not done:
                    keep.append(j)
                    continue
                finish[j] = t
                busy_gpu_slots += (t - start[j]) * jobs[j].num_gpus
                busy_now -= jobs[j].num_gpus
                release_gpus(j)
                if inc is not None:
                    inc.remove(rows.pop(j))
            active = keep

    # Charge partial busy slots for jobs that started but never finished
    # (horizon hit): without this, utilization is overstated because
    # total_gpu_slots counts their window while busy_gpu_slots ignores it.
    for j in sorted(scheduled):
        if start[j] >= 0 and finish[j] < 0:
            busy_gpu_slots += (t - start[j]) * jobs[j].num_gpus

    completed_mask = finish >= 0
    completed = int(completed_mask.sum())
    horizon_hit = t >= horizon
    makespan = float(finish.max(initial=0)) if not horizon_hit \
        else float(max(t, finish.max(initial=0)))
    if arrivals is not None:
        # JCT is time-in-system: finish minus arrival, not the absolute
        # finish slot (those only coincide when everything arrives at 0).
        jct = (finish[completed_mask]
               - arrivals[completed_mask]).astype(np.float64)
    else:
        jct = finish[completed_mask]
    return SimResult(
        start=start, finish=finish, makespan=makespan,
        avg_jct=float(jct.mean()) if len(jct) else float("inf"),
        completed=completed,
        horizon_hit=horizon_hit,
        peak_contention=peak_p,
        busy_gpu_slots=busy_gpu_slots,
        total_gpu_slots=makespan * cluster.num_gpus,
        events=events,
    )
