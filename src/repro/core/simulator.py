"""Discrete-time execution engine for RAR-DDLS schedules.

The paper's Fig. 3 loop needs the *actual* execution time rho(y) of a
schedule, which has no closed form because contention (Eq. 6) depends on the
time-varying set of concurrently active jobs.  This simulator evaluates it:

  * a schedule is an ordered assignment [(job, gpu_ids), ...];
  * each GPU serves its assigned jobs FIFO in schedule order;
  * a job starts (gang-scheduled, non-preemptive, Eqs. 1-5) when it reaches
    the head of *all* its GPUs' queues;
  * while active, it progresses phi_j[t] = floor(1/tau_j[t]) iterations per
    slot, with tau recomputed from Eq. (8) every time the active set changes;
  * it completes once F_j iterations are accumulated (Eq. 9) and releases
    its GPUs simultaneously.

Event-driven between active-set changes (contention is piecewise constant),
so the engine is exact w.r.t. the slot model but runs in O(events).  Under
the default ``"incremental"`` engine the Eq. (6)-(8) terms are maintained
by an :class:`~repro.core.contention.IncrementalEval` across windows --
each start/finish is one O(S + affected) row update instead of a full
[J, S] re-evaluation -- with bit-identical results to the ``"reference"``
per-window :func:`~repro.core.contention.evaluate`.

Readiness tracking (which queued jobs may start at an event boundary) also
has two bit-identical modes, selected with ``readiness``:

  * ``"tracked"`` (default) -- incremental: per-GPU queue-head pointers and
    a per-job "GPUs-at-head" counter, updated only when a job finishes
    (O(G_j) per completion), plus arrival-sorted heaps.  Each event touches
    only the jobs it affects.
  * ``"rescan"`` -- the reference O(J * G) per-event rescan of every
    scheduled job against every queue head, kept as the semantics oracle
    (``tests/test_simulator_equivalence.py`` pins event-for-event
    equality).

Both modes start ready jobs in sorted job-id order (the FIFO tie-break),
so the SimEvent stream, start/finish arrays and all derived metrics are
identical.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import contention
from repro.core.cluster import Cluster
from repro.core.contention import (IncrementalEval, evaluate, ladder_terms,
                                   resolve_engine, tau_ladder)
from repro.core.jobs import Job

Assignment = list[tuple[int, np.ndarray]]  # (job index, global GPU ids)

READINESS_MODES = ("tracked", "rescan")
STEPPING_MODES = ("multi", "single")

# Cap on how many completion stages ahead a multi-window ladder
# precomputes per stack_model call.  The actual depth ramps adaptively:
# shallow while job starts keep invalidating ladders (each start changes
# every row's contention), doubling whenever a ladder is exhausted by a
# long start-free run of windows.
LADDER_DEPTH = 32


@dataclasses.dataclass(frozen=True)
class SimEvent:
    """One piecewise-constant contention window of the execution.

    Idle windows (the cluster waiting for the next arrival) are recorded
    too, with ``active == 0`` and ``busy_gpus == 0``, so time-weighted
    statistics over the event stream cover the whole run, not just busy
    time."""

    t: int                     # window start (slot)
    dt: int                    # window length (slots)
    active: int                # #concurrently running jobs (0 = idle gap)
    contention: int            # max p_j over the active set (Eq. 6)
    busy_gpus: int             # #GPUs occupied during the window


@dataclasses.dataclass
class SimResult:
    start: np.ndarray          # a_j per job (slot), -1 if never started
    finish: np.ndarray         # T_j per job (slot), -1 if never finished
    makespan: float
    avg_jct: float             # mean(finish - arrival) over completed jobs
    avg_queueing_delay: float  # mean(start - arrival) over completed jobs
    completed: int
    horizon_hit: bool
    peak_contention: int       # max p_j[t] observed
    busy_gpu_slots: float      # sum over jobs of in-service duration * G_j
    total_gpu_slots: float     # makespan * N
    events: list[SimEvent] = dataclasses.field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.busy_gpu_slots / max(self.total_gpu_slots, 1e-12)

    @property
    def mean_contention(self) -> float:
        """Time-weighted mean of the per-window max contention level.

        Weighted over the full event stream -- including zero-active idle
        windows -- so the mean reflects wall-clock time, not busy time."""
        total = sum(e.dt for e in self.events)
        if not total:
            return 0.0
        return sum(e.contention * e.dt for e in self.events) / total


def simulate(cluster: Cluster, jobs: list[Job], assignment: Assignment,
             horizon: int = 10**7,
             arrivals: np.ndarray | None = None,
             engine: str | None = None,
             readiness: str = "tracked",
             stepping: str | None = None) -> SimResult:
    """Execute ``assignment`` on ``cluster`` and return actual timings.

    ``arrivals[j]`` (optional) forbids starting job j before its arrival
    slot (online scheduling, core/online.py); ``avg_jct`` is then the mean
    of ``finish - arrival`` over completed jobs (with ``arrivals=None``
    every job arrives at slot 0, so it reduces to the mean finish slot).

    ``engine`` selects the contention-model evaluation strategy:
    ``"reference"`` re-evaluates each window from scratch; anything else
    (``"incremental"``, and ``"batched"`` -- which has no meaning for the
    one-placement-per-window simulator) maintains the active set
    incrementally across windows.  ``readiness`` selects how queue-ready
    jobs are discovered (``"tracked"`` incremental counters, the default,
    vs the ``"rescan"`` reference; see the module docstring).

    ``stepping`` selects how window models are produced between active-set
    changes:

      * ``"multi"`` -- speculative multi-window ladders: while the
        tracked-readiness bookkeeping shows no arrivals or queue-head
        promotions, the Eq. (6)-(8) terms for the next ``LADDER_DEPTH``
        completion stages are computed in one vectorised
        :func:`~repro.core.contention.stack_model` batch over a
        [M, A, S] stack with shrinking row masks (guessed completion
        order, verified window by window, rebuilt on mispredict);
      * ``"single"`` -- one model per window (the IncrementalEval /
        reference path of earlier releases);
      * ``None`` (default) -- ``"multi"`` whenever both oracle axes are
        off (tracked readiness, non-reference engine), else ``"single"``.

    Results are identical across engines, readiness and stepping modes
    (pinned by ``tests/test_simulator_equivalence.py`` and
    ``tests/test_bisect_equivalence.py``)."""
    n_jobs = len(jobs)
    incremental = resolve_engine(engine) != "reference"
    if readiness not in READINESS_MODES:
        raise ValueError(
            f"unknown readiness mode {readiness!r}; choose from {READINESS_MODES}")
    tracked = readiness == "tracked"
    if stepping is not None and stepping not in STEPPING_MODES:
        raise ValueError(
            f"unknown stepping mode {stepping!r}; choose from {STEPPING_MODES}")
    if stepping == "multi" and not (tracked and incremental):
        raise ValueError(
            'stepping="multi" needs readiness="tracked" and a non-reference '
            "engine (the rescan/reference combinations are the "
            "event-for-event oracle and step one window at a time)")
    multiwindow = (tracked and incremental) if stepping is None \
        else stepping == "multi"
    if arrivals is not None:
        arrivals = np.asarray(arrivals)
    queues: list[list[int]] = [[] for _ in range(cluster.num_gpus)]
    gpu_sets: dict[int, np.ndarray] = {}
    srv_of = cluster.gpu_server
    y_rows: dict[int, np.ndarray] = {}   # per-server GPU counts per job
    flat_jid: list[int] = []
    flat_gpu: list[int] = []
    for j, gpus in assignment:
        gpus = np.asarray(gpus, dtype=np.int64)
        if len(gpus) != jobs[j].num_gpus:
            raise ValueError(f"job {j}: got {len(gpus)} GPUs, wants {jobs[j].num_gpus}")
        ids = gpus.tolist()
        if len(set(ids)) != len(ids):
            raise ValueError(f"job {j}: duplicate GPUs in assignment")
        gpu_sets[j] = gpus
        for g in ids:
            queues[g].append(j)
            flat_jid.append(j)
            flat_gpu.append(g)
    # All jobs' per-server GPU counts in one bincount over (job, server)
    # pairs -- same integer counts as a per-job bincount, one C call.
    S = cluster.num_servers
    y_all = np.bincount(
        np.asarray(flat_jid, dtype=np.int64) * S
        + srv_of[np.asarray(flat_gpu, dtype=np.int64)],
        minlength=n_jobs * S).reshape(n_jobs, S)
    for j in gpu_sets:
        y_rows[j] = y_all[j]

    remaining = np.asarray([j.iters for j in jobs], dtype=np.float64)
    start = np.full(n_jobs, -1, dtype=np.int64)
    finish = np.full(n_jobs, -1, dtype=np.int64)
    scheduled = set(gpu_sets)
    active: list[int] = []
    inc = IncrementalEval(cluster) if incremental and not multiwindow else None
    rows: dict[int, int] = {}            # job -> IncrementalEval row handle
    t = 0
    peak_p = 0
    busy_now = 0                         # GPUs occupied by active jobs
    busy_gpu_slots = 0.0
    events: list[SimEvent] = []

    ladder: dict | None = None           # multi-window stage cache
    model_vals: tuple | None = None      # (p, tau, phi) for `active` order
    if multiwindow:
        # Placement-independent Eq. (6)/(8) terms, computed once per run;
        # ladder stacks gather rows of them (unscheduled jobs keep zero
        # placement rows and never enter a ladder).
        terms = ladder_terms(cluster, jobs, y_all)
        phi_last = np.ones(n_jobs)       # ordering hint for the guess
        ladder_ramp = 2                  # adaptive stage depth (see below)

        def build_ladder(act: list[int]) -> dict:
            """One stack_model batch covering the next LADDER_DEPTH
            completion stages of ``act``: stage s masks out the first s
            jobs of the guessed completion order (ascending slots-to-
            finish at current rates, stable on the active order).  The
            guess only selects which stacks exist -- each window's
            completions are computed from the stage values and verified
            against the guess, so a mispredicted order costs one rebuild
            and never changes results."""
            act_arr = np.asarray(act, dtype=np.int64)
            A = len(act)
            keys = np.ceil(remaining[act_arr] / phi_last[act_arr])
            order = np.lexsort((np.arange(A), keys))
            jids = [act[i] for i in order]
            depth = min(A - 1, ladder_ramp)
            jid_arr = act_arr[order]
            p, tau, phi = tau_ladder(cluster, terms, jid_arr, depth)
            contention.EVAL_COUNTS["ladder_calls"] += 1
            contention.EVAL_COUNTS["ladder_rows"] += depth + 1
            # "rem" caches `remaining` in ladder order so window updates
            # are contiguous slice writes; flushed back on invalidation.
            return {"jids": jids, "jid_arr": jid_arr, "stage": 0,
                    "depth": depth, "p": p, "tau": tau, "phi": phi,
                    "rem": remaining[jid_arr]}

        def flush_ladder(lad: dict | None) -> None:
            """Write the ladder-ordered remaining cache back before the
            ladder is dropped (build_ladder reads ``remaining``)."""
            if lad is not None:
                remaining[lad["jid_arr"]] = lad["rem"]

    def _arrival_of(j: int) -> int:
        return int(arrivals[j]) if arrivals is not None else 0

    if tracked:
        # Incremental readiness: head pointer per GPU queue, and for each
        # unstarted job the count of its GPUs where it is at the head.
        # A job is queue-ready when that count reaches G_j, which happens
        # exactly once; it then waits (if needed) in an arrival-sorted
        # heap until its arrival slot.  Startable jobs pop in ascending
        # jid order -- the same FIFO tie-break as the rescan reference.
        qpos = [0] * cluster.num_gpus
        n_gpus_of = {j: len(gpu_sets[j]) for j in scheduled}
        at_head = dict.fromkeys(scheduled, 0)
        for q in queues:
            if q:
                at_head[q[0]] += 1
        startable: list[int] = []              # jid min-heap: ready + arrived
        arrival_wait: list[tuple[int, int]] = []   # (arrival, jid) min-heap
        for j in sorted(scheduled):
            if at_head[j] == n_gpus_of[j]:
                heapq.heappush(arrival_wait, (_arrival_of(j), j))
        # All unstarted jobs, arrival-sorted, for the idle-gap jump; started
        # entries are discarded lazily.
        pending_heap = [(_arrival_of(j), j) for j in scheduled]
        heapq.heapify(pending_heap)
        n_unstarted = len(scheduled)

        def ready_jobs(now: int) -> list[int]:
            while arrival_wait and arrival_wait[0][0] <= now:
                heapq.heappush(startable, heapq.heappop(arrival_wait)[1])
            out = []
            while startable:
                out.append(heapq.heappop(startable))
            return out

        def release_gpus(j: int) -> None:
            # Advance the head pointer on each freed GPU; the new head job
            # gains one GPU-at-head (it cannot already be running: it was
            # not at the head of this queue until now).
            for g in gpu_sets[j]:
                gi = int(g)
                qpos[gi] += 1
                q = queues[gi]
                if qpos[gi] < len(q):
                    j2 = q[qpos[gi]]
                    at_head[j2] += 1
                    if at_head[j2] == n_gpus_of[j2]:
                        heapq.heappush(arrival_wait, (_arrival_of(j2), j2))

        def next_pending_arrival() -> int:
            while pending_heap and start[pending_heap[0][1]] >= 0:
                heapq.heappop(pending_heap)
            return pending_heap[0][0]
    else:
        def ready_jobs(now: int) -> list[int]:
            # Iterate in sorted job order: ``scheduled`` is a set, and set
            # order would make start order -- hence FIFO tie-breaks --
            # depend on hash seeding rather than on the schedule.
            out = []
            for j in sorted(scheduled):
                if start[j] >= 0:
                    continue
                if arrivals is not None and now < arrivals[j]:
                    continue
                if all(queues[int(g)] and queues[int(g)][0] == j
                       for g in gpu_sets[j]):
                    out.append(j)
            return out

        def release_gpus(j: int) -> None:
            for g in gpu_sets[j]:
                queues[int(g)].pop(0)

        def next_pending_arrival() -> int:
            return min(_arrival_of(j) for j in scheduled if start[j] < 0)

    while t < horizon:
        if tracked and not startable \
                and not (arrival_wait and arrival_wait[0][0] <= t):
            starters = ()        # fast path: provably nothing to start
        else:
            starters = ready_jobs(t)
        for j in starters:
            start[j] = t
            active.append(j)
            busy_now += jobs[j].num_gpus
            if tracked:
                n_unstarted -= 1
            if inc is not None:
                rows[j] = inc.add(jobs[j], y_rows[j])
            elif multiwindow:
                # A start changes every row's contention; precomputed
                # stages for the old active set no longer apply.  Frequent
                # starts also mean deep ladders would mostly be wasted,
                # so the ramp decays back towards shallow batches.
                if ladder is not None and ladder["stage"] == 0:
                    ladder_ramp = max(2, ladder_ramp // 2)
                flush_ladder(ladder)
                ladder = None
                model_vals = None
        if not active:
            has_pending = (n_unstarted > 0) if tracked \
                else any(start[j] < 0 for j in scheduled)
            if not has_pending:
                break
            if arrivals is not None:
                nxt = next_pending_arrival()
                if nxt > t:
                    # Idle until the next arrival, but never past the
                    # horizon (the cutoff bounds makespan/total_gpu_slots).
                    # Recorded as a zero-active window so time-weighted
                    # stats cover the gap.
                    nt = min(nxt, horizon)
                    events.append(SimEvent(t=t, dt=nt - t, active=0,
                                           contention=0, busy_gpus=0))
                    t = nt
                    continue
            # Unstartable remainder (should not happen with FIFO queues).
            break
        if multiwindow:
            if model_vals is None:
                if ladder is None:
                    ladder = build_ladder(active)
                    # Keep the active list in ladder (guessed-completion)
                    # order: a stage's surviving rows are then contiguous
                    # slices of the stage arrays, so per-window model
                    # access is a view, not a gather.  Active order never
                    # affects outputs (all window quantities are
                    # aggregates or per-job values).
                    active = list(ladder["jids"])
                s = ladder["stage"]
                model_vals = (ladder["p"][s, s:], ladder["tau"][s, s:],
                              ladder["phi"][s, s:])
            p_arr, tau_arr, phi_raw = model_vals
        elif inc is not None:
            p_arr, tau_arr, phi_raw = inc.window([rows[j] for j in active])
        else:
            sub_jobs = [jobs[j] for j in active]
            Y = cluster.placement_matrix([gpu_sets[j] for j in active])
            model = evaluate(cluster, sub_jobs, Y)
            p_arr, tau_arr, phi_raw = model.p, model.tau, model.phi
        pmax = int(p_arr.max(initial=0))
        peak_p = max(peak_p, pmax)
        if (phi_raw < 1).any():
            # tau > 1 slot/iteration: degenerate calibration; progress
            # fractionally so the simulation still terminates.  (Integer
            # phi upcasts exactly to float64, so skipping the astype on
            # the common path changes nothing downstream.)
            phi = np.maximum(phi_raw.astype(np.float64), 1.0 / tau_arr)
        else:
            phi = phi_raw
        if multiwindow:
            s0 = ladder["stage"]
            act = ladder["jid_arr"][s0:]
            phi_last[act] = phi          # ordering hint for ladder guesses
            rem = ladder["rem"][s0:]
        else:
            act = np.asarray(active, dtype=np.int64)
            rem = remaining[act]
        # min of ceils == ceil of min (ceil is monotone), so one scalar
        # ceil after the reduction replaces the array-wide one.
        # Clamp the event window at the horizon so a job cannot "finish"
        # beyond it — horizon_hit runs stop exactly at the cutoff.
        dt = int(max(1, min(np.ceil((rem / phi).min()), horizon - t)))
        rem_after = rem - phi * dt
        if multiwindow:
            ladder["rem"][s0:] = rem_after
        else:
            remaining[act] = rem_after
        events.append(SimEvent(t=t, dt=dt, active=len(active),
                               contention=pmax, busy_gpus=busy_now))
        t += dt
        done_mask = rem_after <= 1e-9
        if done_mask.any():
            keep: list[int] = []
            done_now: list[int] = []
            for j, done in zip(active, done_mask):
                if not done:
                    keep.append(j)
                    continue
                done_now.append(j)
                finish[j] = t
                busy_gpu_slots += (t - start[j]) * jobs[j].num_gpus
                busy_now -= jobs[j].num_gpus
                release_gpus(j)
                if inc is not None:
                    inc.remove(rows.pop(j))
            active = keep
            if multiwindow:
                # Advance the ladder past this window's completions when
                # they match the guessed prefix (stacks depend only on
                # the removed SET, so order within the prefix is free);
                # otherwise drop it and rebuild from the live state.  A
                # ladder exhausted by a long start-free run doubles the
                # ramp so the next batch covers more stages per call.
                model_vals = None
                if active and ladder is not None:
                    k, c = ladder["stage"], len(done_now)
                    if k + c <= ladder["depth"] and \
                            set(ladder["jids"][k:k + c]) == set(done_now):
                        ladder["stage"] = k + c
                    else:
                        if k + c > ladder["depth"] >= len(active):
                            pass          # depth already spans the run
                        elif k + c > ladder["depth"]:
                            ladder_ramp = min(LADDER_DEPTH, ladder_ramp * 2)
                        flush_ladder(ladder)
                        ladder = None
                else:
                    flush_ladder(ladder)
                    ladder = None

    # Charge partial busy slots for jobs that started but never finished
    # (horizon hit): without this, utilization is overstated because
    # total_gpu_slots counts their window while busy_gpu_slots ignores it.
    for j in sorted(scheduled):
        if start[j] >= 0 and finish[j] < 0:
            busy_gpu_slots += (t - start[j]) * jobs[j].num_gpus

    completed_mask = finish >= 0
    completed = int(completed_mask.sum())
    horizon_hit = t >= horizon
    makespan = float(finish.max(initial=0)) if not horizon_hit \
        else float(max(t, finish.max(initial=0)))
    if arrivals is not None:
        # JCT is time-in-system: finish minus arrival, not the absolute
        # finish slot (those only coincide when everything arrives at 0).
        jct = (finish[completed_mask]
               - arrivals[completed_mask]).astype(np.float64)
        # Queueing delay is time-to-service: start minus arrival.  Over
        # the same completed set, avg_jct == avg_queueing_delay + the
        # mean in-service time (finish - start) by construction.
        qd = (start[completed_mask]
              - arrivals[completed_mask]).astype(np.float64)
    else:
        jct = finish[completed_mask]
        qd = start[completed_mask].astype(np.float64)
    return SimResult(
        start=start, finish=finish, makespan=makespan,
        avg_jct=float(jct.mean()) if len(jct) else float("inf"),
        avg_queueing_delay=float(qd.mean()) if len(qd) else float("inf"),
        completed=completed,
        horizon_hit=horizon_hit,
        peak_contention=peak_p,
        busy_gpu_slots=busy_gpu_slots,
        total_gpu_slots=makespan * cluster.num_gpus,
        events=events,
    )
