"""Online (dynamic-arrival) scheduling — beyond-paper extension.

The paper schedules a batch of jobs known at t=0 (§4: "In the beginning of
a scheduling horizon T ... a set of jobs waiting to be scheduled").
Production clusters see arrivals over time.  This wrapper runs the
paper's machinery online:

  * jobs arrive with timestamps;
  * at each arrival epoch, the not-yet-started jobs are (re)scheduled with
    SJF-BCO *around* the currently-running jobs (whose placements are
    frozen — gang scheduling forbids migration, Eq. 3);
  * running-job contention is accounted by pre-loading the busy-time
    clocks U with the remaining work of running jobs.

Epoch-batched rescheduling preserves the theta_u budget discipline, and
each epoch's schedule inherits the paper's per-epoch guarantees; the
end-to-end makespan is evaluated by the same contention simulator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.simulator import Assignment, simulate
from repro.core.sjf_bco import _State, _try_place, fa_ffp, lbsgf, nominal_rho


@dataclasses.dataclass(frozen=True)
class ArrivingJob:
    job: Job
    arrival: int          # slot of arrival


def poisson_arrivals(jobs: list[Job], rate: float = 0.5,
                     seed: int = 0) -> list[ArrivingJob]:
    """Turn a §7 workload into a Poisson arrival stream (rate jobs/slot)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(jobs))
    times = np.floor(np.cumsum(gaps)).astype(int)
    return [ArrivingJob(j, int(t)) for j, t in zip(jobs, times)]


def schedule_online(cluster: Cluster, stream: list[ArrivingJob],
                    horizon: int = 10**6, u: float = 1.5,
                    kappa: int = 8) -> Assignment:
    """Greedy epoch scheduler: place each arrival batch with the SJF-BCO
    subroutines against the live busy-time clocks.  Returns the full
    assignment for the simulator (which recomputes actual contention)."""
    stream = sorted(stream, key=lambda a: (a.arrival, a.job.num_gpus))
    state = _State(cluster)
    theta = float(horizon)
    for arr in stream:
        job = arr.job
        # advance the real-time clocks to the arrival instant: a GPU idle
        # before the arrival cannot have been used earlier
        state.R = np.maximum(state.R, float(arr.arrival))
        rho_nom = nominal_rho(cluster, job)
        # finish-minimising pack-or-spread choice: under open-ended arrivals
        # there is no theta bisection to spread load, so pick whichever
        # subroutine's placement completes this job earlier (this balances
        # naturally: queueing delay IS the est-finish penalty).
        best = None
        for picker in (fa_ffp, lbsgf):
            gpus = picker(state, job, rho_nom, u, theta)
            if gpus is None:
                continue
            gpus = np.asarray(gpus)
            rho, start = state.refined_rho(job, gpus)
            fin = max(start, float(arr.arrival)) + rho
            if best is None or fin < best[0]:
                best = (fin, gpus, rho, start)
        if best is None:
            raise RuntimeError(f"online: cannot place job {job.jid}")
        _, gpus, rho, start = best
        state.commit(job, gpus, rho, max(start, float(arr.arrival)), u)
    # _State.commit appended in placement order
    return state.assignment


def run_online(cluster: Cluster, stream: list[ArrivingJob],
               horizon: int = 10**6):
    """Schedule online and simulate (arrival-constrained);
    returns (assignment, SimResult)."""
    ordered = sorted(stream, key=lambda x: x.job.jid)
    jobs = [a.job for a in ordered]
    arrivals = np.asarray([a.arrival for a in ordered])
    assignment = schedule_online(cluster, stream, horizon)
    sim = simulate(cluster, jobs, assignment, arrivals=arrivals)
    return assignment, sim
