"""Online (dynamic-arrival) scheduling — beyond-paper extension.

The paper schedules a batch of jobs known at t=0 (§4: "In the beginning of
a scheduling horizon T ... a set of jobs waiting to be scheduled").
Production clusters see arrivals over time.  In the unified API this is
simply a :class:`~repro.core.api.ScheduleRequest` with ``arrivals`` set:
every registered policy then runs the shared epoch loop
(:func:`~repro.core.api.schedule_arrivals`), which

  * visits jobs in (arrival, G_j) order;
  * advances the real-time clocks to each arrival instant (a GPU idle
    before an arrival cannot have been used earlier);
  * places each job against the live busy-time clocks — for SJF-BCO with
    the finish-minimising pack-or-spread choice between FA-FFP and LBSGF
    (gang scheduling forbids migration, Eq. 3, so placements are final).

The end-to-end makespan is evaluated by the same contention simulator
(``simulate(..., arrivals=...)``).  This module keeps the arrival-stream
helpers (Poisson streams, request building, the run_online convenience).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.api import ScheduleRequest, get_policy
from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.simulator import Assignment, simulate

__all__ = ["ArrivingJob", "poisson_arrivals", "stream_request", "run_online"]


@dataclasses.dataclass(frozen=True)
class ArrivingJob:
    job: Job
    arrival: int          # slot of arrival


def poisson_arrivals(jobs: list[Job], rate: float = 0.5,
                     seed: int = 0) -> list[ArrivingJob]:
    """Turn a §7 workload into a Poisson arrival stream (rate jobs/slot)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(jobs))
    times = np.floor(np.cumsum(gaps)).astype(int)
    return [ArrivingJob(j, int(t)) for j, t in zip(jobs, times)]


def stream_request(cluster: Cluster, stream: list[ArrivingJob],
                   horizon: int = 10**6, u: float = 1.5,
                   params: dict | None = None) -> ScheduleRequest:
    """Build a :class:`ScheduleRequest` from an arrival stream.

    Jobs are ordered by jid so simulator indexing (``jobs[j]`` for
    assignment entry j) lines up with the job ids."""
    ordered = sorted(stream, key=lambda a: a.job.jid)
    return ScheduleRequest(
        cluster=cluster,
        jobs=[a.job for a in ordered],
        arrivals=np.asarray([a.arrival for a in ordered], dtype=np.int64),
        horizon=horizon, u=u, params=params or {})


def run_online(cluster: Cluster, stream: list[ArrivingJob],
               horizon: int = 10**6, policy: str = "sjf-bco"
               ) -> tuple[Assignment, "object"]:
    """Schedule an arrival stream and simulate (arrival-constrained);
    returns (assignment, SimResult)."""
    request = stream_request(cluster, stream, horizon)
    assignment = get_policy(policy)(request).assignment
    sim = simulate(cluster, request.jobs, assignment,
                   arrivals=request.arrivals)
    return assignment, sim
