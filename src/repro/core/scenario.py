"""Declarative scenarios: cluster + workload + arrival process + policy,
run end-to-end through the unified scheduling API.

A :class:`Scenario` is a plain-data description of one experiment — the
§7 Philly setting, an online Poisson stream, a contention sweep point —
that :func:`run_scenario` turns into (schedule, simulation, contention
stats) with one call::

    report = run_scenario(Scenario(
        cluster=ClusterSpec(num_servers=8, seed=1),
        workload=WorkloadSpec(num_jobs=40, seed=1),
        policy="sjf-bco", horizon=1200))
    print(report.sim.makespan, report.contention.peak)

Every spec is seeded and frozen, so a scenario is a reproducible value:
two runs of the same Scenario produce identical reports.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.api import ScheduleRequest, ScheduleResult, get_policy
from repro.core.cluster import Cluster, _draw_hetero, philly_cluster
from repro.core.jobs import Job, philly_workload
from repro.core.simulator import SimResult, simulate
from repro.core.trace import load_trace

__all__ = ["ClusterSpec", "WorkloadSpec", "ArrivalSpec", "Scenario",
           "ContentionStats", "RunReport", "run_scenario"]


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Cluster description: explicit ``capacities`` or a seeded Philly
    draw of ``num_servers`` servers; optional contention-constant
    overrides (xi1/xi2/alpha/bandwidths) and per-server heterogeneity
    draws -- ``speed_tiers`` ``((speed, weight), ...)`` assigns each
    server's GPUs one drawn speed tier, ``link_classes`` ``((bandwidth,
    kind, weight), ...)`` draws each server's uplink class (``kind`` is
    ``"shared"`` or ``"isolated"``; see :mod:`repro.core.cluster`)."""

    num_servers: int = 20
    seed: int = 0
    capacities: tuple[int, ...] | None = None
    overrides: tuple[tuple[str, float], ...] = ()
    speed_tiers: tuple[tuple[float, float], ...] | None = None
    link_classes: tuple[tuple[float, str, float], ...] | None = None

    def build(self) -> Cluster:
        if self.capacities is not None:
            caps = tuple(int(c) for c in self.capacities)
            rng = np.random.default_rng(self.seed)
            cluster = Cluster(capacities=caps, **_draw_hetero(
                rng, caps, self.speed_tiers, self.link_classes))
        else:
            cluster = philly_cluster(self.num_servers, seed=self.seed,
                                     speed_tiers=self.speed_tiers,
                                     link_classes=self.link_classes)
        if self.overrides:
            valid = {f.name for f in dataclasses.fields(Cluster)}
            unknown = sorted(k for k, _ in self.overrides if k not in valid)
            if unknown:
                raise ValueError(
                    f"unknown Cluster override field(s) {unknown}; valid "
                    f"fields are {sorted(valid)} (per-device heterogeneity "
                    "goes in ClusterSpec.speed_tiers / link_classes, not "
                    "overrides)")
            cluster = dataclasses.replace(cluster, **dict(self.overrides))
        return cluster


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Workload description.  ``kind="philly"`` draws the §7 Philly-mix
    jobs; ``kind="trace"`` parses the job shapes out of a recorded CSV
    log at ``path`` (see :mod:`repro.core.trace` -- pair it with an
    ``ArrivalSpec(kind="trace")`` on the same path to replay the recorded
    arrivals too).  ``num_jobs`` truncates (jobs are re-numbered so
    jid == index, which the simulator's assignment indexing relies on)."""

    kind: str = "philly"
    seed: int = 0
    num_jobs: int | None = None
    lam: float = 1.0
    path: str | None = None

    def build(self) -> list[Job]:
        if self.kind == "trace":
            if self.path is None:
                raise ValueError("trace workload needs a path")
            jobs, _ = load_trace(self.path)
        elif self.kind == "philly":
            jobs = philly_workload(seed=self.seed, lam=self.lam)
        else:
            raise ValueError(f"unknown workload kind {self.kind!r}")
        if self.num_jobs is not None:
            jobs = [dataclasses.replace(j, jid=i)
                    for i, j in enumerate(jobs[: self.num_jobs])]
        return jobs


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Arrival process.  ``kind="poisson"`` draws i.i.d. exponential gaps
    at ``rate`` jobs/slot; ``kind="pareto"`` draws heavy-tailed Pareto
    gaps (bursty: many near-zero gaps punctuated by long lulls) with tail
    index ``shape``, mean-normalised so ``rate`` still sets the long-run
    jobs/slot; ``kind="fixed"`` uses explicit ``times``;
    ``kind="trace"`` replays the recorded ``start_time`` column of the
    CSV log at ``path`` (see :mod:`repro.core.trace` -- typically paired
    with a ``WorkloadSpec(kind="trace")`` on the same path, so the job
    count matches by construction)."""

    kind: str = "poisson"
    rate: float = 0.5
    seed: int = 0
    times: tuple[int, ...] | None = None
    path: str | None = None
    shape: float = 1.5         # Pareto tail index (finite mean needs > 1)

    def build(self, jobs: list[Job]) -> np.ndarray:
        if self.kind == "trace":
            if self.path is None:
                raise ValueError("trace arrivals need a path")
            _, arrivals = load_trace(self.path)
            if len(arrivals) < len(jobs):
                raise ValueError(
                    f"trace {self.path!r} has {len(arrivals)} arrivals "
                    f"for {len(jobs)} jobs")
            return arrivals[: len(jobs)]
        if self.kind == "fixed":
            if self.times is None or len(self.times) != len(jobs):
                raise ValueError("fixed arrivals need one time per job")
            return np.asarray(self.times, dtype=np.int64)
        if self.kind == "pareto":
            # Lomax (Pareto II) inter-arrival gaps: mean is scale/(shape-1)
            # for shape > 1, so scale = (shape-1)/rate keeps the long-run
            # arrival rate at ``rate`` while the tail index ``shape``
            # controls burstiness (smaller -> heavier tail).
            if self.shape <= 1.0:
                raise ValueError(
                    f"pareto arrivals need shape > 1 for a finite mean "
                    f"gap (got shape={self.shape})")
            rng = np.random.default_rng(self.seed)
            scale = (self.shape - 1.0) / self.rate
            gaps = rng.pareto(self.shape, size=len(jobs)) * scale
            return np.floor(np.cumsum(gaps)).astype(np.int64)
        if self.kind != "poisson":
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        rng = np.random.default_rng(self.seed)
        gaps = rng.exponential(1.0 / self.rate, size=len(jobs))
        return np.floor(np.cumsum(gaps)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One reproducible experiment: what to schedule, with which policy."""

    cluster: ClusterSpec = ClusterSpec()
    workload: WorkloadSpec = WorkloadSpec()
    arrivals: ArrivalSpec | None = None
    policy: str = "sjf-bco"
    policy_params: tuple[tuple[str, object], ...] = ()
    horizon: int = 1200
    u: float = 1.5
    name: str = ""


@dataclasses.dataclass(frozen=True)
class ContentionStats:
    """Per-slot contention summary of a simulated run (from the
    piecewise-constant simulator events).

    The event stream includes zero-active idle windows (waiting for the
    next arrival), so every time-weighted statistic here is weighted by
    wall-clock time over the whole run -- an idle cluster pulls
    ``mean_active``/``mean`` down instead of being silently skipped."""

    peak: int                  # max p_j[t] over the run (Eq. 6)
    mean: float                # time-weighted mean of per-window max p
    mean_active: float         # time-weighted mean #concurrent jobs
    contended_frac: float      # fraction of wall-clock time with p >= 2

    @classmethod
    def from_sim(cls, sim: SimResult) -> "ContentionStats":
        total = sum(e.dt for e in sim.events)
        if not total:
            return cls(peak=sim.peak_contention, mean=0.0,
                       mean_active=0.0, contended_frac=0.0)
        mean_active = sum(e.active * e.dt for e in sim.events) / total
        contended = sum(e.dt for e in sim.events if e.contention >= 2)
        return cls(peak=sim.peak_contention, mean=sim.mean_contention,
                   mean_active=float(mean_active),
                   contended_frac=contended / total)


@dataclasses.dataclass(frozen=True)
class RunReport:
    """Everything :func:`run_scenario` learned about one scenario."""

    scenario: Scenario
    schedule: ScheduleResult
    sim: SimResult
    contention: ContentionStats

    @property
    def makespan(self) -> float:
        return self.sim.makespan

    @property
    def avg_jct(self) -> float:
        return self.sim.avg_jct

    @property
    def avg_queueing_delay(self) -> float:
        """Mean start - arrival over completed jobs (time spent waiting
        for GPUs; ``avg_jct == avg_queueing_delay + mean service time``)."""
        return self.sim.avg_queueing_delay


def build_request(scenario: Scenario) -> ScheduleRequest:
    """Materialise the scenario's specs into a :class:`ScheduleRequest`."""
    cluster = scenario.cluster.build()
    jobs = scenario.workload.build()
    arrivals = (scenario.arrivals.build(jobs)
                if scenario.arrivals is not None else None)
    return ScheduleRequest(cluster=cluster, jobs=jobs, arrivals=arrivals,
                           horizon=scenario.horizon, u=scenario.u,
                           params=dict(scenario.policy_params))


def run_scenario(scenario: Scenario, sim_horizon: int = 10**7) -> RunReport:
    """Schedule and simulate one scenario: the Fig. 3 loop end-to-end."""
    request = build_request(scenario)
    schedule = get_policy(scenario.policy)(request)
    sim = simulate(request.cluster, request.jobs, schedule.assignment,
                   horizon=sim_horizon, arrivals=request.arrivals,
                   quotas=schedule.quotas)
    return RunReport(scenario=scenario, schedule=schedule, sim=sim,
                     contention=ContentionStats.from_sim(sim))
