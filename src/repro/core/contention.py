"""The paper's analytical model: Eqs. (6)-(8), vectorised over jobs.

Given a placement matrix Y[t] (rows = active jobs, cols = servers, entries =
#GPUs of that job on that server), compute

  p_j[t]   (Eq. 6)  largest #concurrent jobs sharing an inter-server link
  k_j[t]   (Eq. 7)  effective contention, k = xi1 * p (clamped >= 1)
  f(a, k)           bandwidth-sharing degradation, linear form k + a(k-1)
  B_j(y[t])         bottleneck bandwidth: b_i if single-server else b_e/f
  gamma_j           comm overhead, xi2 * #servers spanned
  tau_j[t] (Eq. 8)  per-iteration RAR time
  phi_j[t]          iterations completed per slot, floor(1/tau)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import Cluster
from repro.core.jobs import Job


@dataclasses.dataclass(frozen=True)
class IterModel:
    """Per-slot evaluation of the Eq. (8) terms for a set of active jobs."""

    p: np.ndarray          # Eq. (6), int [J]
    k: np.ndarray          # Eq. (7), float [J]
    bandwidth: np.ndarray  # B_j(y[t]), float [J]
    gamma: np.ndarray      # comm overhead, float [J]
    exchange: np.ndarray   # information-exchange term, float [J]
    reduce: np.ndarray     # reduction-compute term, float [J]
    compute: np.ndarray    # Delta_f * M + Delta_b, float [J]
    tau: np.ndarray        # Eq. (8), float [J]
    phi: np.ndarray        # iterations per slot, int [J]


def degradation(alpha: float, k: np.ndarray) -> np.ndarray:
    """Bandwidth-sharing degradation factor f(alpha, k).

    Linear model from §4.1: f = k + alpha * (k - 1); f(alpha, 1) = 1 and
    increasing in k, as the paper requires.
    """
    k = np.maximum(np.asarray(k, dtype=np.float64), 1.0)
    return k + alpha * (k - 1.0)


def contention_level(Y: np.ndarray, G: np.ndarray) -> np.ndarray:
    """p_j per Eq. (6).

    A job *straddles* server s iff 0 < y_js < G_j (it uses inter-server
    links through s).  p_j = max over straddled servers of the number of
    straddling jobs on that server (including j itself).
    """
    Y = np.asarray(Y)
    if Y.ndim != 2:
        raise ValueError("Y must be [J, S]")
    straddle = (Y > 0) & (Y < G[:, None])          # [J, S]
    per_server = straddle.sum(axis=0)              # [S], #contenders per server
    p = np.where(straddle, per_server[None, :], 0).max(axis=1)
    return p.astype(np.int64)


def evaluate(cluster: Cluster, jobs: list[Job], Y: np.ndarray) -> IterModel:
    """Evaluate Eqs. (6)-(8) for the active-job placement ``Y`` [J, S]."""
    J = len(jobs)
    if Y.shape != (J, cluster.num_servers):
        raise ValueError(f"Y shape {Y.shape} != ({J}, {cluster.num_servers})")
    G = np.asarray([j.num_gpus for j in jobs], dtype=np.int64)
    if not np.array_equal(Y.sum(axis=1), G):
        raise ValueError("placement does not cover every job's GPUs (Eq. 1)")

    m = np.asarray([j.grad_size for j in jobs], dtype=np.float64)
    w = G.astype(np.float64)
    M = np.asarray([j.batch for j in jobs], dtype=np.float64)
    dfw = np.asarray([j.dt_fwd for j in jobs], dtype=np.float64)
    dbw = np.asarray([j.dt_bwd for j in jobs], dtype=np.float64)

    p = contention_level(Y, G)
    k = np.maximum(cluster.xi1 * p, 1.0)
    multi = (Y > 0).sum(axis=1) > 1
    f = degradation(cluster.alpha, k)
    bandwidth = np.where(multi, cluster.b_inter / f, cluster.b_intra)

    n_srv = (Y > 0).sum(axis=1).astype(np.float64)
    gamma = cluster.xi2 * n_srv

    # Eq. (8): single-GPU jobs (w=1) have no exchange/reduction terms.
    share = np.where(w > 1, (m / w) * (w - 1.0), 0.0)
    exchange = 2.0 * share / bandwidth
    reduce_t = share / cluster.gpu_speed
    compute = dfw * M + dbw
    tau = exchange + reduce_t + gamma + compute
    phi = np.floor(1.0 / tau).astype(np.int64)
    return IterModel(p=p, k=k, bandwidth=bandwidth, gamma=gamma,
                     exchange=exchange, reduce=reduce_t, compute=compute,
                     tau=tau, phi=phi)


def tau_bounds(cluster: Cluster, job: Job) -> tuple[float, float]:
    """[tau_lo, tau_hi] per §5.1: B in [b_e/f(a, max_s O_s), b_i], spread in
    [1, G_j] servers.  Used to derive the l/u estimate bracket."""
    w = float(job.num_gpus)
    share = (job.grad_size / w) * (w - 1.0) if w > 1 else 0.0
    compute = job.dt_fwd * job.batch + job.dt_bwd
    k_max = max(1.0, cluster.xi1 * max(cluster.capacities))
    b_lo = cluster.b_inter / float(degradation(cluster.alpha, np.array(k_max)))
    tau_lo = 2.0 * share / cluster.b_intra + share / cluster.gpu_speed \
        + cluster.xi2 * 1.0 + compute
    tau_hi = 2.0 * share / b_lo + share / cluster.gpu_speed \
        + cluster.xi2 * min(w, cluster.num_servers) + compute
    return tau_lo, tau_hi


def estimate_exec_time(cluster: Cluster, job: Job, Y_snapshot: np.ndarray,
                       jobs_snapshot: list[Job], y_j: np.ndarray) -> float:
    """rho_hat(y^k): estimated execution time (slots) of ``job`` if placed as
    ``y_j`` [S] while the jobs in ``jobs_snapshot`` are placed as
    ``Y_snapshot`` [J', S].

    This is the scheduler-side estimate of Fig. 3: evaluate Eq. (8) against
    the current placement snapshot and multiply by F_j.  The true rho is
    later produced by the slot simulator (contention evolves over time).
    """
    Y = np.vstack([Y_snapshot, y_j[None, :]]) if len(jobs_snapshot) else y_j[None, :]
    model = evaluate(cluster, jobs_snapshot + [job], Y)
    tau = float(model.tau[-1])
    # slots needed at phi iterations/slot
    phi = max(1, int(np.floor(1.0 / tau)))
    return float(int(np.ceil(job.iters / phi)))
