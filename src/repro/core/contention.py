"""The paper's analytical model: Eqs. (6)-(8), vectorised over jobs.

Given a placement matrix Y[t] (rows = active jobs, cols = servers, entries =
#GPUs of that job on that server), compute

  p_j[t]   (Eq. 6)  largest #concurrent jobs sharing an inter-server link
  k_j[t]   (Eq. 7)  effective contention, k = xi1 * p (clamped >= 1)
  f(a, k)           bandwidth-sharing degradation, linear form k + a(k-1)
  B_j(y[t])         bottleneck bandwidth: b_i if single-server else b_e/f
  gamma_j           comm overhead, xi2 * #servers spanned
  tau_j[t] (Eq. 8)  per-iteration RAR time
  phi_j[t]          iterations completed per slot, floor(1/tau)

Three evaluation engines share these formulas (and are bit-identical):

  * :func:`evaluate` -- one placement [J, S], the reference path;
  * :func:`evaluate_many` -- a stack of C candidate placements [C, J, S]
    scored in a single vectorised pass (the straddle/per-server reductions
    are shared across candidates; no per-candidate Python loop);
  * :class:`IncrementalEval` -- maintains p/k/tau under single-row
    add/remove in O(S + |affected rows|) instead of recomputing all J rows,
    for hot loops (scheduler placement probes, the slot simulator) where
    the active set changes one job at a time.

``EVAL_COUNTS`` tallies how often each engine runs so benchmarks can report
"full-model evaluations saved" (see ``benchmarks/bench_contention.py``).

Heterogeneous clusters (per-GPU ``gpu_speeds`` / per-server uplink
``links`` on :class:`~repro.core.cluster.Cluster`) generalise B_j and the
reduction speed: a job's compute speed is the minimum server speed floor
over its occupied servers (Eq. (1) paces a ring at its slowest member),
and its inter-server bandwidth is ``min(min_iso_bw, min_shared_bw / f)``
-- isolated uplinks skip the Eq. (8) sharing divisor.  Every engine
derives these from the occupancy rows via :func:`_hetero_mins`, and the
degenerate case (uniform speeds, all-shared links) runs today's scalar
expressions bit-identically.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math

import numpy as np

from repro.core.cluster import Cluster
from repro.core.jobs import Job

# --------------------------------------------------------------------------
# Engine selection + instrumentation
# --------------------------------------------------------------------------

ENGINES = ("incremental", "batched", "reference")

# Module-wide default used by PlacementState and the simulator when no
# explicit engine is requested.  "incremental" is the fast path;
# "reference" is the original per-candidate evaluate() loop kept for
# equivalence testing and as the semantics oracle.
DEFAULT_ENGINE = "incremental"

EVAL_COUNTS = {
    "full": 0,              # evaluate() calls (one full [J, S] model pass)
    "batched_calls": 0,     # evaluate_many() calls (one vectorised pass)
    "batched_rows": 0,      # total candidates scored across those calls
    "incremental_updates": 0,  # IncrementalEval row add/remove operations
    "incremental_removes": 0,  # the remove() subset of those operations
    "probes": 0,            # O(S) single-job tau probes (no full pass)
    "ladder_calls": 0,      # simulator multi-window tau_ladder batches
    "ladder_rows": 0,       # total completion stages across those batches
    "evictions": 0,         # preempt.evict() live-schedule row removals
}


def reset_eval_counts() -> None:
    """Zero the per-engine full-model-evaluation counters."""
    for key in EVAL_COUNTS:
        EVAL_COUNTS[key] = 0


def eval_counts() -> dict[str, int]:
    """Snapshot of the model-evaluation counters."""
    return dict(EVAL_COUNTS)


@contextlib.contextmanager
def evaluation_engine(name: str):
    """Temporarily set the module-wide default evaluation engine."""
    global DEFAULT_ENGINE
    if name not in ENGINES:
        raise ValueError(f"unknown engine {name!r}; choose from {ENGINES}")
    prev, DEFAULT_ENGINE = DEFAULT_ENGINE, name
    try:
        yield
    finally:
        DEFAULT_ENGINE = prev


def resolve_engine(name: str | None) -> str:
    """An explicit engine name, or the module-wide default."""
    if name is None:
        return DEFAULT_ENGINE
    if name not in ENGINES:
        raise ValueError(f"unknown engine {name!r}; choose from {ENGINES}")
    return name


# Backend for stack_model's inner tau reduction: "numpy" (default) or
# "kernel" (the jitted Pallas kernel in repro.kernels.tau; interpret mode
# on CPU, compiled Mosaic on TPU).  On CPU the kernel exists for numerics
# parity and TPU forward-compat, not speed -- hence the opt-in.
TAU_BACKENDS = ("numpy", "kernel")
TAU_BACKEND = "numpy"


@contextlib.contextmanager
def tau_backend(name: str):
    """Temporarily select the stack-model tau backend ("numpy"/"kernel")."""
    global TAU_BACKEND
    if name not in TAU_BACKENDS:
        raise ValueError(f"unknown tau backend {name!r}; "
                         f"choose from {TAU_BACKENDS}")
    prev, TAU_BACKEND = TAU_BACKEND, name
    try:
        yield
    finally:
        TAU_BACKEND = prev


# --------------------------------------------------------------------------
# Model terms
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IterModel:
    """Per-slot evaluation of the Eq. (8) terms for a set of active jobs.

    Arrays are [J] from :func:`evaluate` / :meth:`IncrementalEval.model`,
    or [C, J] from :func:`evaluate_many` (leading candidate axis)."""

    p: np.ndarray          # Eq. (6), int
    k: np.ndarray          # Eq. (7), float
    bandwidth: np.ndarray  # B_j(y[t]), float
    gamma: np.ndarray      # comm overhead, float
    exchange: np.ndarray   # information-exchange term, float
    reduce: np.ndarray     # reduction-compute term, float
    compute: np.ndarray    # Delta_f * M + Delta_b, float
    tau: np.ndarray        # Eq. (8), float
    phi: np.ndarray        # iterations per slot, int


def degradation(alpha: float, k):
    """Bandwidth-sharing degradation factor f(alpha, k).

    Linear model from §4.1: f = k + alpha * (k - 1); f(alpha, 1) = 1 and
    increasing in k, as the paper requires.  Accepts scalars or arrays and
    returns a matching float / ndarray.
    """
    arr = np.maximum(np.asarray(k, dtype=np.float64), 1.0)
    out = arr + alpha * (arr - 1.0)
    if np.ndim(k) == 0:
        return float(out)
    return out


def contention_level(Y: np.ndarray, G: np.ndarray) -> np.ndarray:
    """p_j per Eq. (6).

    A job *straddles* server s iff 0 < y_js < G_j (it uses inter-server
    links through s).  p_j = max over straddled servers of the number of
    straddling jobs on that server (including j itself).
    """
    Y = np.asarray(Y)
    if Y.ndim != 2:
        raise ValueError("Y must be [J, S]")
    straddle = (Y > 0) & (Y < G[:, None])          # [J, S]
    per_server = straddle.sum(axis=0)              # [S], #contenders per server
    p = np.where(straddle, per_server[None, :], 0).max(axis=1)
    return p.astype(np.int64)


def _job_terms(jobs: list[Job]) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Placement-independent per-job terms of Eq. (8): (G, share, compute)
    where share = m(w-1)/w is the per-GPU exchanged volume."""
    G = np.asarray([j.num_gpus for j in jobs], dtype=np.int64)
    m = np.asarray([j.grad_size for j in jobs], dtype=np.float64)
    w = G.astype(np.float64)
    M = np.asarray([j.batch for j in jobs], dtype=np.float64)
    dfw = np.asarray([j.dt_fwd for j in jobs], dtype=np.float64)
    dbw = np.asarray([j.dt_bwd for j in jobs], dtype=np.float64)
    # Eq. (8): single-GPU jobs (w=1) have no exchange/reduction terms.
    share = np.where(w > 1, (m / w) * (w - 1.0), 0.0)
    compute = dfw * M + dbw
    return G, share, compute


def _hetero_mins(cluster: Cluster, occupied: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Worst-member device terms per occupancy row.

    ``occupied`` is a bool mask [..., S]; returns ``(speed, bw_shared,
    bw_isolated)`` with the leading shape of ``occupied``: the slowest
    server speed floor, slowest shared uplink, and slowest isolated uplink
    over each row's occupied servers (+inf where a class is absent, so
    ``min(bw_isolated, bw_shared / f)`` and ``np.minimum`` select the real
    bottleneck).  Masked minima are pure selections, so the degenerate
    uniform cluster reproduces the scalar fields exactly."""
    speed = np.where(occupied, cluster.server_speed_floor, np.inf).min(axis=-1)
    bw_sh = np.where(occupied, cluster.uplink_shared_or_inf, np.inf).min(axis=-1)
    bw_iso = np.where(occupied, cluster.uplink_isolated_or_inf, np.inf).min(axis=-1)
    return speed, bw_sh, bw_iso


def evaluate(cluster: Cluster, jobs: list[Job], Y: np.ndarray) -> IterModel:
    """Evaluate Eqs. (6)-(8) for the active-job placement ``Y`` [J, S]."""
    J = len(jobs)
    if Y.shape != (J, cluster.num_servers):
        raise ValueError(f"Y shape {Y.shape} != ({J}, {cluster.num_servers})")
    G, share, compute = _job_terms(jobs)
    if not np.array_equal(Y.sum(axis=1), G):
        raise ValueError("placement does not cover every job's GPUs (Eq. 1)")

    p = contention_level(Y, G)
    k = np.maximum(cluster.xi1 * p, 1.0)
    multi = (Y > 0).sum(axis=1) > 1
    f = degradation(cluster.alpha, k)
    if cluster.is_heterogeneous:
        speed, bw_sh, bw_iso = _hetero_mins(cluster, Y > 0)
        bandwidth = np.where(multi, np.minimum(bw_iso, bw_sh / f),
                             cluster.b_intra)
    else:
        speed = cluster.gpu_speed
        bandwidth = np.where(multi, cluster.b_inter / f, cluster.b_intra)

    n_srv = (Y > 0).sum(axis=1).astype(np.float64)
    gamma = cluster.xi2 * n_srv

    exchange = 2.0 * share / bandwidth
    reduce_t = share / speed
    tau = exchange + reduce_t + gamma + compute
    phi = np.floor(1.0 / tau).astype(np.int64)
    EVAL_COUNTS["full"] += 1
    return IterModel(p=p, k=k, bandwidth=bandwidth, gamma=gamma,
                     exchange=exchange, reduce=reduce_t, compute=compute,
                     tau=tau, phi=phi)


def stack_model(cluster: Cluster, G: np.ndarray, share: np.ndarray,
                compute: np.ndarray, Y_stack: np.ndarray,
                active: np.ndarray | None = None) -> IterModel:
    """Eqs. (6)-(8) on a prepared [C, J, S] candidate stack.

    The vectorised core shared by :func:`evaluate_many` (which adds Job
    -list handling and Eq. (1) validation on top), the simulator's
    multi-window stepping (which pre-computes the placement-independent
    terms ``G``/``share``/``compute`` once per run and feeds window
    stacks straight in), and :func:`evaluate_stack`.  The term arrays may
    be shared across candidates ([J], broadcast over the stack) or
    per-candidate ([C, J] -- the columnar placement engine's branch
    stacks, where each candidate row set comes from a different decision
    history); both shapes follow the same elementwise expressions, so the
    shared form is the per-candidate form with repeated rows.  ``active``
    [C, J] masks rows out per candidate by zeroing them -- a zero row
    straddles nothing, so every other row's contention is exactly as if
    the row were absent.

    When the Pallas tau kernel is enabled (see :func:`tau_backend`), the
    inner straddle/per-server/max reduction and the Eq. (8) combination
    run inside one jitted kernel instead of this NumPy pipeline; the
    candidate axis is the kernel's grid dimension for both term shapes.
    """
    Y = Y_stack
    if active is not None:
        Y = np.where(active[:, :, None], Y, 0)
    G2 = np.broadcast_to(np.asarray(G), Y.shape[:2])
    share2 = np.broadcast_to(np.asarray(share), Y.shape[:2])
    compute2 = np.broadcast_to(np.asarray(compute), Y.shape[:2])
    if TAU_BACKEND != "numpy":
        from repro.kernels.tau import tau_stack
        p, n_srv_i, tau = tau_stack(cluster, G, share, compute, Y)
    else:
        straddle = (Y > 0) & (Y < G2[:, :, None])      # [C, J, S]
        per_server = straddle.sum(axis=1)              # [C, S]
        p = np.where(straddle, per_server[:, None, :], 0).max(axis=2)
        p = p.astype(np.int64)
        n_srv_i = (Y > 0).sum(axis=2)
        tau = None                       # derived from the terms below
    k = np.maximum(cluster.xi1 * p, 1.0)
    f = degradation(cluster.alpha, k)
    if cluster.is_heterogeneous:
        speed, bw_sh, bw_iso = _hetero_mins(cluster, Y > 0)
        bandwidth = np.where(n_srv_i > 1, np.minimum(bw_iso, bw_sh / f),
                             cluster.b_intra)
    else:
        speed = cluster.gpu_speed
        bandwidth = np.where(n_srv_i > 1, cluster.b_inter / f, cluster.b_intra)
    gamma = cluster.xi2 * n_srv_i.astype(np.float64)
    exchange = 2.0 * share2 / bandwidth
    reduce_t = share2 / speed
    compute_b = compute2
    if tau is None:
        tau = exchange + reduce_t + gamma + compute_b
    phi = np.floor(1.0 / tau).astype(np.int64)
    return IterModel(p=p, k=k, bandwidth=bandwidth, gamma=gamma,
                     exchange=exchange, reduce=reduce_t, compute=compute_b,
                     tau=tau, phi=phi)


def ladder_terms(cluster: Cluster, jobs: list[Job], Y_rows: np.ndarray
                 ) -> dict[str, np.ndarray]:
    """Per-job arrays :func:`tau_ladder` needs, computed once per run.

    ``Y_rows`` [J, S] holds each job's per-server GPU counts.  Everything
    here is stage-independent: the straddle vectors (Eq. 6), whether a
    job spans servers, and the share/reduce/gamma/compute terms of
    Eq. (8).  :func:`tau_ladder` gathers rows of these by job id."""
    G, share, compute = _job_terms(jobs)
    straddle = (Y_rows > 0) & (Y_rows < G[:, None])
    n_srv = (Y_rows > 0).sum(axis=1)
    if cluster.is_heterogeneous:
        speed, bw_sh, bw_iso = _hetero_mins(cluster, Y_rows > 0)
        reduce_t = share / speed
    else:
        reduce_t = share / cluster.gpu_speed
        bw_sh = np.full(len(jobs), float(cluster.b_inter))
        bw_iso = np.full(len(jobs), np.inf)
    return {
        "straddle": straddle,
        "multi": n_srv > 1,
        "share": share,
        "reduce": reduce_t,
        "bw_sh": bw_sh,
        "bw_iso": bw_iso,
        "gamma": cluster.xi2 * n_srv.astype(np.float64),
        "compute": compute,
    }


def tau_ladder(cluster: Cluster, terms: dict[str, np.ndarray],
               rows: np.ndarray, depth: int
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched Eq. (6)-(8) maintenance for a removal ladder.

    ``rows`` holds the active job ids in guessed completion order; stage
    ``s`` is the active set with the first ``s`` rows removed.  Removing
    a row only subtracts its straddle vector from the per-server Eq. (6)
    counts, so all ``depth + 1`` stages' counts come from one cumulative
    sum -- the vectorised form of :class:`IncrementalEval`'s per-row
    remove maintenance -- and one [depth+1, A, S] max produces every
    stage's p.  ``terms`` is the run-constant bundle from
    :func:`ladder_terms`.  Returns (p, tau, phi), each [depth+1, A];
    entries for already-removed rows are meaningless and must not be
    read.  Values are bit-identical to :func:`evaluate` on each stage's
    surviving subset (same integer counts, same float expression order).
    """
    straddle = terms["straddle"][rows]                 # [A, S]
    total = straddle.sum(axis=0)                       # [S]
    if depth:
        drops = np.cumsum(straddle[:depth], axis=0)    # [depth, S]
        per_server = np.concatenate([total[None], total[None] - drops])
    else:
        per_server = total[None]
    p = (straddle[None, :, :] * per_server[:, None, :]).max(axis=2)
    k = np.maximum(cluster.xi1 * p, 1.0)
    f = k + cluster.alpha * (k - 1.0)    # degradation(); k already >= 1
    # bw_sh is filled with b_inter (bw_iso with +inf) on homogeneous
    # clusters, so this is the same elementwise division as the scalar
    # form there and the isolated-uplink minimum elsewhere.
    bandwidth = np.where(terms["multi"][rows][None, :],
                         np.minimum(terms["bw_iso"][rows][None, :],
                                    terms["bw_sh"][rows][None, :] / f),
                         cluster.b_intra)
    exchange = 2.0 * terms["share"][rows][None, :] / bandwidth
    tau = exchange + terms["reduce"][rows][None, :] \
        + terms["gamma"][rows][None, :] + terms["compute"][rows][None, :]
    phi = np.floor(1.0 / tau).astype(np.int64)
    return p, tau, phi


def evaluate_many(cluster: Cluster, jobs: list[Job], Y_stack: np.ndarray,
                  active: np.ndarray | None = None) -> IterModel:
    """Score a stack of C candidate placements [C, J, S] in one pass.

    ``jobs`` is the shared row order across candidates.  ``active`` [C, J]
    (optional) marks which rows participate in each candidate; inactive
    rows are zeroed out, which leaves every other row's contention exactly
    as if the row were absent (a zero row straddles nothing), so candidates
    with different overlap subsets of the same job list can share a stack.

    Bit-identical to running :func:`evaluate` per candidate: all reductions
    run along the same axes with the same element values.  Inactive rows
    still receive (meaningless) tau entries -- callers must only read
    active rows.
    """
    Y = np.asarray(Y_stack)
    if Y.ndim != 3 or Y.shape[1:] != (len(jobs), cluster.num_servers):
        raise ValueError(
            f"Y_stack shape {Y.shape} != (C, {len(jobs)}, {cluster.num_servers})")
    G, share, compute = _job_terms(jobs)
    if active is not None:
        active = np.asarray(active, dtype=bool)
        if active.shape != Y.shape[:2]:
            raise ValueError(f"active shape {active.shape} != {Y.shape[:2]}")
        Y = np.where(active[:, :, None], Y, 0)
        expect = np.where(active, G[None, :], 0)
    else:
        expect = np.broadcast_to(G[None, :], Y.shape[:2])
    if not np.array_equal(Y.sum(axis=2), expect):
        raise ValueError("placement does not cover every job's GPUs (Eq. 1)")

    EVAL_COUNTS["batched_calls"] += 1
    EVAL_COUNTS["batched_rows"] += Y.shape[0]
    return stack_model(cluster, G, share, compute, Y)


def evaluate_stack(cluster: Cluster, G: np.ndarray, share: np.ndarray,
                   compute: np.ndarray, Y_stack: np.ndarray,
                   active: np.ndarray | None = None) -> IterModel:
    """Score a padded candidate stack whose rows differ *per candidate*.

    The columnar-stack entry point: where :func:`evaluate_many` shares one
    job list (and hence one [J] term vector) across all candidates, here
    each candidate carries its own row set -- ``G``/``share``/``compute``
    are [C, J] with candidate c's row j holding the Eq. (8) terms of
    whatever job occupies that slot of c's stack (zero-padded, inactive
    rows beyond c's depth).  This is how the columnar placement engine
    scores one probe per *branch row* in a single pass without gathering
    the branches onto a shared job order.  Shared [J] terms are accepted
    too and broadcast, making :func:`evaluate_many` the special case.

    Same Eq. (1) validation, counters, and :func:`stack_model` core as
    :func:`evaluate_many`; bit-identical to evaluating each candidate's
    active rows with :func:`evaluate`.
    """
    Y = np.asarray(Y_stack)
    if Y.ndim != 3 or Y.shape[2] != cluster.num_servers:
        raise ValueError(
            f"Y_stack shape {Y.shape} != (C, J, {cluster.num_servers})")
    G2 = np.broadcast_to(np.asarray(G), Y.shape[:2])
    if active is not None:
        active = np.asarray(active, dtype=bool)
        if active.shape != Y.shape[:2]:
            raise ValueError(f"active shape {active.shape} != {Y.shape[:2]}")
        Y = np.where(active[:, :, None], Y, 0)
        expect = np.where(active, G2, 0)
    else:
        expect = G2
    if not np.array_equal(Y.sum(axis=2), expect):
        raise ValueError("placement does not cover every job's GPUs (Eq. 1)")

    EVAL_COUNTS["batched_calls"] += 1
    EVAL_COUNTS["batched_rows"] += Y.shape[0]
    return stack_model(cluster, G, share, compute, Y)


# --------------------------------------------------------------------------
# Incremental engine
# --------------------------------------------------------------------------


class IncrementalEval:
    """Exact Eq. (6)-(8) maintenance under single-row placement changes.

    Holds the straddle matrix and the per-server straddler counts for a
    live set of rows.  :meth:`add` / :meth:`remove` update the counts for
    the one changed row and recompute p (and, where p changed, k/B/tau/phi)
    only for the rows straddling a server whose count moved -- O(S +
    |affected|) per update instead of the O(J*S) of a fresh
    :func:`evaluate`.  All terms are computed with the same expressions as
    :func:`evaluate`, so the maintained state is bit-identical.
    """

    def __init__(self, cluster: Cluster, capacity: int = 16):
        self.cluster = cluster
        self._S = cluster.num_servers
        cap = max(4, capacity)
        self._jobs: list[Job | None] = [None] * cap
        self._live = np.zeros(cap, dtype=bool)
        self._Y = np.zeros((cap, self._S), dtype=np.int64)
        self._straddle = np.zeros((cap, self._S), dtype=bool)
        self._per_server = np.zeros(self._S, dtype=np.int64)
        # Placement-independent per-row terms (cached at add).
        self._share = np.zeros(cap)
        self._reduce = np.zeros(cap)
        self._compute = np.zeros(cap)
        # Device terms over the row's occupied servers (cached at add;
        # constants gpu_speed / b_inter / +inf on homogeneous clusters).
        self._spd = np.zeros(cap)
        self._bw_sh = np.zeros(cap)
        self._bw_iso = np.zeros(cap)
        # Placement-dependent but row-local terms.
        self._gamma = np.zeros(cap)
        self._multi = np.zeros(cap, dtype=bool)
        # Contention-dependent terms, maintained incrementally.
        self._p = np.zeros(cap, dtype=np.int64)
        self._k = np.zeros(cap)
        self._bandwidth = np.zeros(cap)
        self._exchange = np.zeros(cap)
        self._tau = np.zeros(cap)
        self._phi = np.zeros(cap, dtype=np.int64)
        self._free = list(range(cap))

    def __len__(self) -> int:
        return int(self._live.sum())

    def _grow(self) -> None:
        cap = len(self._live)
        new = cap * 2
        self._jobs.extend([None] * cap)
        for name in ("_live", "_share", "_reduce", "_compute", "_spd",
                     "_bw_sh", "_bw_iso", "_gamma", "_multi", "_p", "_k",
                     "_bandwidth", "_exchange", "_tau", "_phi"):
            old = getattr(self, name)
            setattr(self, name, np.concatenate(
                [old, np.zeros(cap, dtype=old.dtype)]))
        self._Y = np.concatenate(
            [self._Y, np.zeros((cap, self._S), dtype=np.int64)])
        self._straddle = np.concatenate(
            [self._straddle, np.zeros((cap, self._S), dtype=bool)])
        self._free.extend(range(cap, new))

    def add(self, job: Job, y: np.ndarray) -> int:
        """Insert a placed job row ``y`` [S]; returns its row handle."""
        y = np.asarray(y, dtype=np.int64)
        if y.shape != (self._S,):
            raise ValueError(f"y shape {y.shape} != ({self._S},)")
        if int(y.sum()) != job.num_gpus:
            raise ValueError("placement does not cover the job's GPUs (Eq. 1)")
        if not self._free:
            self._grow()
        row = self._free.pop()
        cl = self.cluster
        self._jobs[row] = job
        self._Y[row] = y
        w = float(job.num_gpus)
        share = (job.grad_size / w) * (w - 1.0) if w > 1 else 0.0
        pos = y > 0
        if cl.is_heterogeneous:
            spd = float(cl.server_speed_floor[pos].min())
            bw_sh = float(cl.uplink_shared_or_inf[pos].min())
            bw_iso = float(cl.uplink_isolated_or_inf[pos].min())
        else:
            spd, bw_sh, bw_iso = cl.gpu_speed, cl.b_inter, np.inf
        self._spd[row] = spd
        self._bw_sh[row] = bw_sh
        self._bw_iso[row] = bw_iso
        self._share[row] = share
        self._reduce[row] = share / spd
        self._compute[row] = job.dt_fwd * float(job.batch) + job.dt_bwd
        n_srv = int(pos.sum())
        self._gamma[row] = cl.xi2 * float(n_srv)
        self._multi[row] = n_srv > 1
        row_straddle = pos & (y < job.num_gpus)
        self._straddle[row] = row_straddle
        self._live[row] = True
        self._apply_count_delta(row, row_straddle, +1)
        EVAL_COUNTS["incremental_updates"] += 1
        return row

    def remove(self, row: int) -> None:
        """Remove a previously added row; its handle becomes invalid."""
        if not self._live[row]:
            raise KeyError(f"row {row} is not live")
        row_straddle = self._straddle[row].copy()
        self._live[row] = False
        self._straddle[row] = False
        self._Y[row] = 0
        self._jobs[row] = None
        self._apply_count_delta(row, row_straddle, -1)
        self._free.append(row)
        EVAL_COUNTS["incremental_updates"] += 1
        EVAL_COUNTS["incremental_removes"] += 1

    def _refresh_terms_scalar(self, r: int) -> None:
        """Recompute k/B/exchange/tau/phi for one row from its current p.
        Plain float64 arithmetic with the same operation order as the
        vector path, so bit-identical results."""
        cl = self.cluster
        k = cl.xi1 * float(self._p[r])
        if k < 1.0:
            k = 1.0
        f = k + cl.alpha * (k - 1.0)
        if self._multi[r]:
            # _bw_sh/_bw_iso cache b_inter/+inf on homogeneous clusters,
            # so this is the original b_inter / f there.
            bandwidth = float(self._bw_sh[r]) / f
            bw_iso = float(self._bw_iso[r])
            if bw_iso < bandwidth:
                bandwidth = bw_iso
        else:
            bandwidth = cl.b_intra
        exchange = 2.0 * float(self._share[r]) / bandwidth
        tau = exchange + float(self._reduce[r]) \
            + float(self._gamma[r]) + float(self._compute[r])
        self._k[r] = k
        self._bandwidth[r] = bandwidth
        self._exchange[r] = exchange
        self._tau[r] = tau
        self._phi[r] = math.floor(1.0 / tau)

    def _refresh_terms(self, upd: np.ndarray) -> None:
        """Recompute k/B/exchange/tau/phi for the rows whose p changed."""
        if len(upd) == 1:
            self._refresh_terms_scalar(int(upd[0]))
            return
        cl = self.cluster
        k = np.maximum(cl.xi1 * self._p[upd], 1.0)
        f = degradation(cl.alpha, k)
        bandwidth = np.where(self._multi[upd],
                             np.minimum(self._bw_iso[upd],
                                        self._bw_sh[upd] / f),
                             cl.b_intra)
        exchange = 2.0 * self._share[upd] / bandwidth
        tau = exchange + self._reduce[upd] + self._gamma[upd] + self._compute[upd]
        self._k[upd] = k
        self._bandwidth[upd] = bandwidth
        self._exchange[upd] = exchange
        self._tau[upd] = tau
        self._phi[upd] = np.floor(1.0 / tau).astype(np.int64)

    def _apply_count_delta(self, row: int, row_straddle: np.ndarray,
                           delta: int) -> None:
        # Contention moves monotonically with the per-server counts, so
        # other rows never need a full O(S) p recompute on add (their p can
        # only grow, and only through a changed server: an O(|changed|) max
        # suffices), and on remove only rows whose old p sat exactly on a
        # changed server's old count can shrink.
        changed = np.flatnonzero(row_straddle)
        n_changed = len(changed)
        counts_c = None
        if n_changed:
            self._per_server[changed] += delta
            counts_c = self._per_server[changed]
            affected = self._live & self._straddle[:, changed].any(axis=1)
            affected[row] = False       # the changed row is handled below
            rows = np.flatnonzero(affected)
        else:
            rows = ()
        if len(rows):
            if n_changed == 1:
                # Every affected row straddles the single changed server.
                cand = counts_c[0]
            else:
                cand = (self._straddle[np.ix_(rows, changed)]
                        * counts_c).max(axis=1)
            if delta > 0:
                grew = cand > self._p[rows]
                upd = rows[grew]
                if len(upd):
                    self._p[upd] = cand[grew] if n_changed > 1 else cand
                    self._refresh_terms(upd)
            else:
                # Old count at a changed server = new count + 1; rows whose
                # p exceeds every changed server's old count peak elsewhere.
                maybe = rows[self._p[rows] == cand + 1]
                if len(maybe):
                    p_new = (self._straddle[maybe]
                             * self._per_server).max(axis=1)
                    shrunk = p_new != self._p[maybe]
                    upd = maybe[shrunk]
                    if len(upd):
                        self._p[upd] = p_new[shrunk]
                        self._refresh_terms(upd)
        if delta > 0:
            # The new row always needs its own full terms; its straddled
            # servers are exactly ``changed``, so its Eq. (6) level is the
            # max of their (fresh) counts.
            self._p[row] = int(counts_c.max()) if n_changed else 0
            self._refresh_terms_scalar(row)

    def tau_of(self, row: int) -> float:
        """Current Eq. (8) tau of a live row."""
        if not self._live[row]:
            raise KeyError(f"row {row} is not live")
        return float(self._tau[row])

    def probe_tau(self, job: Job, y: np.ndarray) -> float:
        """tau of ``job`` if placed as ``y`` against the current live set,
        WITHOUT mutating any state.  tau_j depends only on the job's own
        contention level p_j = max over its straddled servers of the
        straddler count including itself (Eq. 6) -- other rows' p values
        don't enter Eq. (8) for j -- so a probe is a pure O(S) read."""
        y = np.asarray(y, dtype=np.int64)
        if int(y.sum()) != job.num_gpus:
            raise ValueError("placement does not cover the job's GPUs (Eq. 1)")
        straddle_row = (y > 0) & (y < job.num_gpus)
        p = int((self._per_server[straddle_row] + 1).max()) \
            if straddle_row.any() else 0
        n_srv = int((y > 0).sum())
        EVAL_COUNTS["probes"] += 1
        cl = self.cluster
        if cl.is_heterogeneous:
            pos = y > 0
            return scalar_tau(
                cl, job, p, n_srv,
                speed=float(cl.server_speed_floor[pos].min()),
                bw_shared=float(cl.uplink_shared_or_inf[pos].min()),
                bw_isolated=float(cl.uplink_isolated_or_inf[pos].min()))
        return scalar_tau(cl, job, p, n_srv)

    def probe_tau_many(self, job: Job, Y_stack: np.ndarray) -> np.ndarray:
        """Batched :meth:`probe_tau`: tau of ``job`` for each candidate
        placement row of ``Y_stack`` [C, S], scored against the current
        live set in one vectorised pass (no per-candidate Python loop) and
        without mutating any state.  Bit-identical to C scalar probes."""
        Y = np.asarray(Y_stack, dtype=np.int64)
        if Y.ndim != 2 or Y.shape[1] != self._S:
            raise ValueError(f"Y_stack shape {Y.shape} != (C, {self._S})")
        if not np.all(Y.sum(axis=1) == job.num_gpus):
            raise ValueError("placement does not cover the job's GPUs (Eq. 1)")
        straddle = (Y > 0) & (Y < job.num_gpus)              # [C, S]
        p = np.where(straddle, (self._per_server + 1)[None, :], 0).max(axis=1)
        n_srv = (Y > 0).sum(axis=1)
        EVAL_COUNTS["probes"] += Y.shape[0]
        cl = self.cluster
        if cl.is_heterogeneous:
            speed, bw_sh, bw_iso = _hetero_mins(cl, Y > 0)
            return scalar_tau_many(cl, job, p, n_srv, speed=speed,
                                   bw_shared=bw_sh, bw_isolated=bw_iso)
        return scalar_tau_many(cl, job, p, n_srv)

    def window(self, rows) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(p, tau, phi) for live ``rows`` -- the simulator's per-window
        gather.  Fancy indexing already copies, so this is three array
        gathers instead of :meth:`model`'s nine."""
        idx = np.asarray(rows, dtype=np.int64)
        if idx.ndim != 1 or (len(idx) and not np.all(self._live[idx])):
            raise KeyError("window() requires live row handles")
        return self._p[idx], self._tau[idx], self._phi[idx]

    def model(self, rows) -> IterModel:
        """Gather the maintained terms for ``rows`` (in that order)."""
        idx = np.asarray(rows, dtype=np.int64)
        if idx.ndim != 1 or (len(idx) and not np.all(self._live[idx])):
            raise KeyError("model() requires live row handles")
        return IterModel(
            p=self._p[idx].copy(), k=self._k[idx].copy(),
            bandwidth=self._bandwidth[idx].copy(),
            gamma=self._gamma[idx].copy(),
            exchange=self._exchange[idx].copy(),
            reduce=self._reduce[idx].copy(),
            compute=self._compute[idx].copy(),
            tau=self._tau[idx].copy(), phi=self._phi[idx].copy())


# --------------------------------------------------------------------------
# Estimate helpers (shared by every rho-hat consumer)
# --------------------------------------------------------------------------


def scalar_tau(cluster: Cluster, job: Job, p: int, n_srv: int,
               speed: float | None = None, bw_shared: float | None = None,
               bw_isolated: float | None = None) -> float:
    """Eq. (8) for one job given its contention level ``p`` and server
    spread ``n_srv`` -- the scalar core shared by the incremental probes.
    Plain-float IEEE arithmetic (Python floats are IEEE float64, so the
    inlined degradation is the same computation), bit-identical to the
    vectorised engines.

    ``speed``/``bw_shared``/``bw_isolated`` carry the heterogeneous
    worst-member device terms over the candidate's occupied servers (see
    :func:`_hetero_mins`); ``None`` keeps the uniform scalars (the
    homogeneous original, expression for expression).
    """
    w = float(job.num_gpus)
    share = (job.grad_size / w) * (w - 1.0) if w > 1 else 0.0
    k = max(cluster.xi1 * p, 1.0)
    if n_srv > 1:
        sh = cluster.b_inter if bw_shared is None else bw_shared
        bandwidth = sh / (k + cluster.alpha * (k - 1.0))
        if bw_isolated is not None and bw_isolated < bandwidth:
            bandwidth = bw_isolated
    else:
        bandwidth = cluster.b_intra
    gamma = cluster.xi2 * float(n_srv)
    exchange = 2.0 * share / bandwidth
    reduce_t = share / (cluster.gpu_speed if speed is None else speed)
    compute = job.dt_fwd * float(job.batch) + job.dt_bwd
    return exchange + reduce_t + gamma + compute


def scalar_tau_many(cluster: Cluster, job: Job, p: np.ndarray,
                    n_srv: np.ndarray, speed: np.ndarray | None = None,
                    bw_shared: np.ndarray | None = None,
                    bw_isolated: np.ndarray | None = None) -> np.ndarray:
    """Batched :func:`scalar_tau`: Eq. (8) for one job at C hypothesised
    (contention level, server spread) pairs in one vectorised pass -- the
    batched probe entry point shared by :meth:`IncrementalEval.probe_tau_many`
    and the scheduler's multi-candidate rho-hat probes
    (:meth:`repro.core.api.PlacementState.refined_rho_many`).  Elementwise
    float64 with the same operation order as the scalar form, so the
    results are bit-identical per candidate.  The optional
    ``speed``/``bw_shared``/``bw_isolated`` arrays ([C], from
    :func:`_hetero_mins`) carry per-candidate heterogeneous device terms;
    ``None`` keeps the uniform scalars.

    The fused columnar score step (``score_probes`` in
    :mod:`repro.kernels.placement`) re-derives exactly this expression
    chain on device for tall probe batches -- any change to the
    operation order here must land there too, or the x64 bit-identity
    contract pinned by ``tests/test_columnar_equivalence.py`` breaks."""
    p = np.asarray(p, dtype=np.float64)
    n_srv = np.asarray(n_srv)
    w = float(job.num_gpus)
    share = (job.grad_size / w) * (w - 1.0) if w > 1 else 0.0
    k = np.maximum(cluster.xi1 * p, 1.0)
    f = degradation(cluster.alpha, k)
    sh = cluster.b_inter if bw_shared is None \
        else np.asarray(bw_shared, dtype=np.float64)
    bw_multi = sh / f
    if bw_isolated is not None:
        bw_multi = np.minimum(np.asarray(bw_isolated, dtype=np.float64),
                              bw_multi)
    bandwidth = np.where(n_srv > 1, bw_multi, cluster.b_intra)
    gamma = cluster.xi2 * n_srv.astype(np.float64)
    exchange = 2.0 * share / bandwidth
    reduce_t = share / (cluster.gpu_speed if speed is None
                        else np.asarray(speed, dtype=np.float64))
    compute = job.dt_fwd * float(job.batch) + job.dt_bwd
    return exchange + reduce_t + gamma + compute


def slots_for(iters: int, tau: float) -> float:
    """rho-hat slot count at per-iteration time ``tau``: ceil(F_j / phi)
    with phi = floor(1/tau) clamped >= 1.  The one place this floor/ceil
    pair lives -- PlacementState.refined_rho, estimate_exec_time and the
    Table-1 estimates all route through it.  (math.floor/ceil on floats
    match np.floor/ceil exactly; this is just the scalar fast path.)"""
    phi = max(1, math.floor(1.0 / tau))
    return float(math.ceil(iters / phi))


def slots_for_many(iters: int, tau: np.ndarray) -> np.ndarray:
    """Vectorised :func:`slots_for`: rho-hat slot counts for a batch of
    taus in one pass.  np.floor/np.ceil on float64 match math.floor/ceil
    exactly, phi is a small exact integer in float64, and int/int true
    division equals float64 division for exactly representable operands --
    so every element is bit-identical to the scalar form.  The columnar
    placement engine's per-step probe batches route through this."""
    phi = np.maximum(1.0, np.floor(1.0 / np.asarray(tau, dtype=np.float64)))
    return np.ceil(iters / phi)


def predict_exec_time(cluster: Cluster, job: Job, jobs_snapshot: list[Job],
                      Y_snapshot: np.ndarray, y_j: np.ndarray) -> float:
    """rho_hat(y^k): estimated execution time (slots) of ``job`` placed as
    ``y_j`` [S] while ``jobs_snapshot`` are placed as ``Y_snapshot``
    [J', S] -- the scheduler-side estimate of Fig. 3 (evaluate Eq. (8)
    against the snapshot, convert tau to slots, multiply by F_j)."""
    y_j = np.asarray(y_j)
    if len(jobs_snapshot):
        Y = np.vstack([np.asarray(Y_snapshot), y_j[None, :]])
    else:
        Y = y_j[None, :]
    model = evaluate(cluster, list(jobs_snapshot) + [job], Y)
    return slots_for(job.iters, float(model.tau[-1]))


def estimate_exec_time(cluster: Cluster, job: Job, Y_snapshot: np.ndarray,
                       jobs_snapshot: list[Job], y_j: np.ndarray) -> float:
    """Back-compat wrapper for :func:`predict_exec_time` (older argument
    order).  The true rho is later produced by the slot simulator
    (contention evolves over time)."""
    return predict_exec_time(cluster, job, jobs_snapshot, Y_snapshot, y_j)


def tau_bounds(cluster: Cluster, job: Job) -> tuple[float, float]:
    """[tau_lo, tau_hi] per §5.1: B in [b_e/f(a, max_s O_s), b_i], spread in
    [1, G_j] servers.  Used to derive the l/u estimate bracket.

    On heterogeneous clusters the bracket widens to the device extremes:
    tau_lo prices the fastest server speed floor, tau_hi the slowest floor
    and the worst effective uplink (isolated uplinks keep their full
    bandwidth; shared ones pay f(alpha, k_max))."""
    w = float(job.num_gpus)
    share = (job.grad_size / w) * (w - 1.0) if w > 1 else 0.0
    compute = job.dt_fwd * job.batch + job.dt_bwd
    k_max = max(1.0, cluster.xi1 * max(cluster.capacities))
    if cluster.is_heterogeneous:
        f_max = degradation(cluster.alpha, k_max)
        eff = np.where(cluster.uplink_isolated, cluster.uplink_bandwidth,
                       cluster.uplink_bandwidth / f_max)
        b_lo = float(eff.min())
        speed_hi = float(cluster.server_speed_floor.max())
        speed_lo = float(cluster.server_speed_floor.min())
    else:
        b_lo = cluster.b_inter / degradation(cluster.alpha, k_max)
        speed_hi = speed_lo = cluster.gpu_speed
    tau_lo = 2.0 * share / cluster.b_intra + share / speed_hi \
        + cluster.xi2 * 1.0 + compute
    tau_hi = 2.0 * share / b_lo + share / speed_lo \
        + cluster.xi2 * min(w, cluster.num_servers) + compute
    return tau_lo, tau_hi
