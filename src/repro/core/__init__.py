"""Paper core: contention-aware scheduling of ring-all-reduce DDL jobs.

Faithful implementation of Yu et al., "On Scheduling Ring-All-Reduce
Learning Jobs in Multi-Tenant GPU Clusters with Communication Contention"
(MobiHoc '22): the Eq. (6)-(9) analytical model, the slot simulator that
evaluates actual execution under time-varying contention, the SJF-BCO
approximation algorithm (Algs. 1-3) and the §7 baselines.

Public surface (new code should use the unified API):

  * :mod:`repro.core.api` -- ``ScheduleRequest`` / ``ScheduleResult``, the
    policy registry (``register_policy`` / ``get_policy`` /
    ``list_policies``) and the busy-time building blocks.
  * :mod:`repro.core.scenario` -- declarative ``Scenario`` experiments and
    ``run_scenario``.

The legacy free-function entrypoints (``sjf_bco``, ``first_fit``,
``schedule_online``, ``baselines.POLICIES``, ...) are gone after their
one-release deprecation overlap: use
``get_policy(name)(ScheduleRequest(...))``.
"""
from repro.core.api import (PlacementState, ScheduleRequest, ScheduleResult,
                            SchedulingPolicy, SharedState, get_chooser,
                            get_policy, list_choosers, list_policies,
                            nominal_rho, probe_thetas, register_chooser,
                            register_policy, rho_hat, try_place_group)
from repro.core.cluster import Cluster, philly_cluster
from repro.core.jobs import Job, philly_workload
from repro.core.contention import (IncrementalEval, IterModel,
                                   contention_level, degradation,
                                   estimate_exec_time, eval_counts, evaluate,
                                   evaluate_many, evaluation_engine,
                                   predict_exec_time, reset_eval_counts,
                                   scalar_tau_many, slots_for, stack_model,
                                   tau_backend, tau_bounds, tau_ladder)
from repro.core.preempt import evict, evictable, replace, resize
from repro.core.simulator import SimEvent, SimResult, simulate
from repro.core.sjf_bco import fa_ffp, lbsgf
from repro.core.scenario import (ArrivalSpec, ClusterSpec, ContentionStats,
                                 RunReport, Scenario, WorkloadSpec,
                                 run_scenario)
from repro.core.theory import TheoryReport, report
from repro.core.trace import load_trace, replay_trace

__all__ = [
    # unified scheduling API
    "ScheduleRequest", "ScheduleResult", "SchedulingPolicy",
    "register_policy", "get_policy", "list_policies",
    "register_chooser", "get_chooser", "list_choosers",
    "PlacementState", "SharedState", "nominal_rho", "rho_hat",
    "probe_thetas", "try_place_group",
    # scenarios
    "Scenario", "ClusterSpec", "WorkloadSpec", "ArrivalSpec",
    "RunReport", "ContentionStats", "run_scenario",
    "load_trace", "replay_trace",
    # problem model
    "Cluster", "philly_cluster", "Job", "philly_workload",
    "IterModel", "contention_level", "degradation", "evaluate",
    "evaluate_many", "IncrementalEval", "evaluation_engine",
    "eval_counts", "reset_eval_counts", "scalar_tau_many", "slots_for",
    "estimate_exec_time", "predict_exec_time", "tau_bounds",
    "stack_model", "tau_backend", "tau_ladder",
    "SimEvent", "SimResult", "simulate",
    # algorithm subroutines
    "fa_ffp", "lbsgf",
    # preemption / elasticity primitives
    "evict", "evictable", "replace", "resize",
    "TheoryReport", "report",
]
