"""Paper core: contention-aware scheduling of ring-all-reduce DDL jobs.

Faithful implementation of Yu et al., "On Scheduling Ring-All-Reduce
Learning Jobs in Multi-Tenant GPU Clusters with Communication Contention"
(MobiHoc '22): the Eq. (6)-(9) analytical model, the slot simulator that
evaluates actual execution under time-varying contention, the SJF-BCO
approximation algorithm (Algs. 1-3) and the §7 baselines.
"""
from repro.core.cluster import Cluster, philly_cluster
from repro.core.jobs import Job, philly_workload
from repro.core.contention import (IterModel, contention_level, degradation,
                                   evaluate, estimate_exec_time, tau_bounds)
from repro.core.simulator import SimResult, simulate
from repro.core.sjf_bco import Schedule, fa_ffp, lbsgf, rho_hat, sjf_bco
from repro.core import baselines
from repro.core.baselines import (first_fit, list_scheduling, random_policy,
                                  reserved_bandwidth)
from repro.core.theory import TheoryReport, report

baselines.POLICIES["sjf-bco"] = sjf_bco

__all__ = [
    "Cluster", "philly_cluster", "Job", "philly_workload",
    "IterModel", "contention_level", "degradation", "evaluate",
    "estimate_exec_time", "tau_bounds",
    "SimResult", "simulate",
    "Schedule", "fa_ffp", "lbsgf", "rho_hat", "sjf_bco",
    "first_fit", "list_scheduling", "random_policy", "reserved_bandwidth",
    "TheoryReport", "report",
]
