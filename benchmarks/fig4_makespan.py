"""Fig. 4 reproduction: makespan + avg JCT under SJF-BCO vs FF/LS/RAND.

Paper setting: 160 Philly-mix jobs, 20 servers, T=1200.
Paper claim: SJF-BCO outperforms all baselines on makespan and average JCT
(most prominent when GPUs are scarce)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POLICIES, run_policy
from repro.core import philly_cluster, philly_workload

HORIZON = 1200
SEEDS = (1, 2, 3)


def run(seeds=SEEDS, verbose: bool = True) -> list[dict]:
    rows = []
    for seed in seeds:
        cluster = philly_cluster(20, seed=seed)
        jobs = philly_workload(seed=seed)
        for name in POLICIES:
            r = run_policy(name, cluster, jobs, HORIZON)
            r["seed"] = seed
            rows.append(r)
            if verbose:
                print(f"  seed {seed} {name:8s} makespan {r['makespan']:7.0f} "
                      f"avg JCT {r['avg_jct']:7.1f} util {r['utilization']:.2f}")
    if verbose:
        for name in POLICIES:
            ms = np.mean([r["makespan"] for r in rows if r["policy"] == name])
            jct = np.mean([r["avg_jct"] for r in rows if r["policy"] == name])
            print(f"  MEAN {name:8s} makespan {ms:7.0f} avg JCT {jct:7.1f}")
    return rows


def validate(rows) -> dict:
    """Check the paper's qualitative claims on every seed."""
    ok_ms, ok_jct = True, True
    for seed in {r["seed"] for r in rows}:
        by = {r["policy"]: r for r in rows if r["seed"] == seed}
        best_base_ms = min(by[p]["makespan"] for p in ("FF", "LS", "RAND"))
        ok_ms &= by["SJF-BCO"]["makespan"] <= best_base_ms
        best_base_jct = min(by[p]["avg_jct"] for p in ("FF", "LS", "RAND"))
        ok_jct &= by["SJF-BCO"]["avg_jct"] <= best_base_jct * 1.15
    return {"sjf_best_makespan": ok_ms, "sjf_competitive_jct": ok_jct}


if __name__ == "__main__":
    rows = run()
    print("validation:", validate(rows))
