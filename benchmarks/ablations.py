"""Beyond-paper ablations.

1. Contention sweep: scale xi1 (effective-contention coefficient) and
   measure SJF-BCO's advantage over the strongest baseline (LS).  The
   paper's thesis predicts the gap widens with contention intensity.
2. SJF-BCO+ (adaptive pack-or-spread, core/extensions.py): per-job greedy
   choice between FA-FFP and LBSGF by refined completion estimate.
   Finding: it trades ~+50% makespan for ~-25% average JCT — per-job
   greedy placement optimises individual completion at the cost of the
   global objective, which is exactly why the paper's kappa-level control
   (a *population*-level knob) wins on makespan.
3. Reserved-bandwidth (GADGET-style) scheduling vs contention-aware:
   schedules built assuming reserved bandwidth, executed under contention.
"""
from __future__ import annotations

from repro.core import (ScheduleRequest, get_policy, philly_cluster,
                        philly_workload, simulate)
from repro.core.extensions import contention_sweep


def run(verbose: bool = True) -> list[str]:
    rows = []
    sweep = contention_sweep(seed=1)
    for r in sweep:
        rows.append(
            f"ablation_contention_xi1={r['xi1']},0,"
            f"sjf={r['sjf_makespan']:.0f};ls={r['ls_makespan']:.0f};"
            f"advantage={r['advantage_vs_ls']:.2f}x")
    cluster = philly_cluster(20, seed=1)
    jobs = philly_workload(seed=1)
    request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)
    plus = simulate(cluster, jobs,
                    get_policy("sjf-bco-adaptive")(request).assignment)
    base = simulate(cluster, jobs, get_policy("sjf-bco")(request).assignment)
    rows.append(f"ablation_sjfplus,0,makespan={plus.makespan:.0f}vs{base.makespan:.0f};"
                f"avg_jct={plus.avg_jct:.0f}vs{base.avg_jct:.0f}")
    res = simulate(cluster, jobs,
                   get_policy("reserved")(request).assignment)
    rows.append(f"ablation_reserved_bw,0,makespan={res.makespan:.0f}"
                f";sjf={base.makespan:.0f}")
    if verbose:
        for r in rows:
            print("  " + r)
    return rows


if __name__ == "__main__":
    run()
