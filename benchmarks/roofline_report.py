"""Roofline table from the dry-run JSON (EXPERIMENTS.md §Roofline source).

Reads results/dryrun_single.json (and _multi if present) and prints the
three terms per (arch x shape), dominant bottleneck, MODEL_FLOPS ratio and
per-device HBM fit."""
from __future__ import annotations

import json
import os

V5E_HBM = 16 * 2**30


def load(path="results/dryrun_single.json") -> list[dict]:
    if not os.path.exists(path):
        return []
    return json.load(open(path))


def run(path="results/dryrun_single.json", verbose: bool = True) -> list[dict]:
    rows = load(path)
    if verbose and rows:
        print(f"  {'arch':18s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
              f"{'t_coll':>9s} {'bound':>10s} {'useful':>7s} {'fits16G':>7s}")
        for r in sorted(rows, key=lambda r: (r['arch'], r['shape'])):
            fits = "yes" if r["hbm_peak_bytes"] <= V5E_HBM else "NO"
            print(f"  {r['arch']:18s} {r['shape']:12s} "
                  f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
                  f"{r['t_collective_s']:9.2e} {r['bottleneck']:>10s} "
                  f"{min(r['useful_ratio'],9.999):7.3f} {fits:>7s}")
    return rows


if __name__ == "__main__":
    run()
