"""Cross-policy leaderboard over the paper's experiment grids.

Every registered policy -- the paper's SJF-BCO and its §7 baselines plus
the preemptive/elastic family (``sjf-bco-dynamic``, ``gadget-elastic``,
``wang-ca``) -- runs the same :func:`repro.core.run_scenario` grids:

  * the Fig. 4 batch grid (Philly mix, 20 servers, |J| sweep),
  * a Fig. 6-style server sweep at fixed |J|,
  * a Fig. 7-style online sweep over Poisson arrival rates,

reporting makespan, average JCT, average queueing delay and the
time-weighted mean contention level per (grid point, policy) into
``BENCH_leaderboard.json``.

``--quick`` doubles as CI's correctness smoke with hard asserts:

  * ``sjf-bco-dynamic`` makespan <= ``sjf-bco`` on every Fig. 4 point
    (the batch portfolio guarantees it by construction);
  * scalar vs incremental oracle identity UNDER PREEMPTION: the dynamic
    policy's segmented schedule is bit-identical across contention
    engines, and its simulation is event-for-event identical across the
    readiness axes.

Usage:
    PYTHONPATH=src python benchmarks/bench_leaderboard.py [--quick] [--out F]
"""
from __future__ import annotations

import numpy as np

from repro.core import (ArrivalSpec, ClusterSpec, Cluster, Job, Scenario,
                        ScheduleRequest, WorkloadSpec, get_policy,
                        run_scenario, simulate)

try:
    from benchmarks._bench_util import (make_parser, same_schedule, same_sim,
                                        write_report)
except ImportError:
    from _bench_util import (make_parser, same_schedule, same_sim,
                             write_report)

POLICIES = ("sjf-bco", "sjf-bco-dynamic", "gadget-elastic", "wang-ca",
            "ff", "ls", "rand", "reserved")
HORIZON = 1200
SEED = 1


def _row(policy: str, scenario: Scenario, point: dict) -> dict:
    rep = run_scenario(scenario)
    return {"policy": policy, **point,
            "makespan": float(rep.makespan),
            "avg_jct": float(rep.avg_jct),
            "avg_queueing_delay": float(rep.avg_queueing_delay),
            "mean_contention": float(rep.contention.mean),
            "segments": len(rep.schedule.assignment),
            "preempted": rep.schedule.quotas is not None}


def fig4_grid(n_jobs_sweep) -> list[dict]:
    """Batch Philly grid: |J| sweep at 20 servers (the Fig. 4 setting)."""
    rows = []
    for n in n_jobs_sweep:
        for policy in POLICIES:
            rows.append(_row(policy, Scenario(
                cluster=ClusterSpec(num_servers=20, seed=SEED),
                workload=WorkloadSpec(seed=SEED, num_jobs=n),
                policy=policy, horizon=HORIZON),
                {"grid": "fig4", "n_jobs": n}))
            print("  fig4 |J|=%3d %-16s makespan %8.1f avg JCT %8.1f" % (
                n, rows[-1]["policy"], rows[-1]["makespan"],
                rows[-1]["avg_jct"]))
    return rows


def fig6_grid(servers_sweep, n_jobs: int) -> list[dict]:
    """Server-count sweep at fixed |J| (the Fig. 6 scarcity axis)."""
    rows = []
    for s in servers_sweep:
        for policy in POLICIES:
            rows.append(_row(policy, Scenario(
                cluster=ClusterSpec(num_servers=s, seed=SEED),
                workload=WorkloadSpec(seed=SEED, num_jobs=n_jobs),
                policy=policy, horizon=HORIZON),
                {"grid": "fig6", "servers": s, "n_jobs": n_jobs}))
    return rows


def fig7_grid(rates_sweep, n_jobs: int) -> list[dict]:
    """Online Poisson sweep (the Fig. 7 load axis): queueing delay and
    preemption live here."""
    rows = []
    for rate in rates_sweep:
        for policy in POLICIES:
            rows.append(_row(policy, Scenario(
                cluster=ClusterSpec(num_servers=8, seed=SEED),
                workload=WorkloadSpec(seed=SEED, num_jobs=n_jobs),
                arrivals=ArrivalSpec(rate=rate, seed=SEED),
                policy=policy, horizon=10**6),
                {"grid": "fig7", "rate": rate, "n_jobs": n_jobs}))
    return rows


def validate_fig4(rows: list[dict]) -> dict:
    """Hard assert: the dynamic portfolio never loses to SJF-BCO on
    makespan, at every Fig. 4 grid point."""
    points = sorted({r["n_jobs"] for r in rows if r["grid"] == "fig4"})
    for n in points:
        by = {r["policy"]: r for r in rows
              if r["grid"] == "fig4" and r["n_jobs"] == n}
        assert by["sjf-bco-dynamic"]["makespan"] <= by["sjf-bco"]["makespan"], \
            f"fig4 |J|={n}: dynamic lost to sjf-bco"
    return {"dynamic_never_worse_fig4": True, "points": points}


def preemption_oracle_smoke() -> dict:
    """Scalar vs incremental identity under preemption (hard asserts)."""
    cluster = Cluster(capacities=(4, 4))
    jobs = [Job(jid=0, num_gpus=8, iters=4000, grad_size=0.25, batch=32,
                dt_fwd=3e-4, dt_bwd=8e-3)]
    jobs += [Job(jid=i, num_gpus=2, iters=200, grad_size=0.05, batch=32,
                 dt_fwd=3e-4, dt_bwd=8e-3) for i in range(1, 4)]
    arrivals = np.array([0, 5, 6, 7], dtype=np.int64)
    scheds = {}
    for engine in ("reference", "incremental"):
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=10**6,
                                  params={"engine": engine})
        scheds[engine] = get_policy("sjf-bco-dynamic")(request)
    assert scheds["reference"].quotas is not None, \
        "oracle smoke trace no longer triggers preemption"
    assert same_schedule(scheds["reference"], scheds["incremental"]), \
        "engine divergence under preemption"
    quotas = scheds["reference"].quotas
    sims = {r: simulate(cluster, jobs, scheds["reference"].assignment,
                        arrivals=arrivals, quotas=quotas, readiness=r)
            for r in ("tracked", "rescan")}
    assert same_sim(sims["tracked"], sims["rescan"]), \
        "readiness divergence under preemption"
    return {"engines_identical": True, "readiness_identical": True,
            "segments": len(scheds["reference"].assignment)}


def main() -> None:
    args = make_parser(__doc__, "BENCH_leaderboard.json").parse_args()
    if args.quick:
        rows = (fig4_grid([16]) + fig6_grid([8], 16)
                + fig7_grid([0.5], 16))
    else:
        rows = (fig4_grid([16, 32, 64]) + fig6_grid([12, 20], 48)
                + fig7_grid([0.2, 0.5, 2.0], 32))
    report = {
        "bench": "leaderboard", "quick": bool(args.quick),
        "policies": list(POLICIES),
        "rows": rows,
        "validation": {**validate_fig4(rows),
                       "preemption_oracle": preemption_oracle_smoke()},
    }
    write_report(report, args.out)


if __name__ == "__main__":
    main()
