"""Fig. 7 reproduction: impact of lambda (LBSGF server-spread factor).

Paper setting: kappa=1 (every multi-GPU job uses LBSGF), lambda in
{1,2,4,8}.  Paper claim: makespan monotonically decreases as lambda grows
(more candidate servers => less contention + smaller overhead for the
jobs that spread)."""
from __future__ import annotations

import dataclasses


from repro.core import (ScheduleRequest, get_policy, philly_cluster,
                        philly_workload, simulate)

HORIZON = 1200
LAMBDAS = (1.0, 2.0, 4.0, 8.0)


def run(seed: int = 1, verbose: bool = True) -> list[dict]:
    cluster = philly_cluster(20, seed=seed)
    base_jobs = philly_workload(seed=seed)
    sjf = get_policy("sjf-bco")
    rows = []
    for lam in LAMBDAS:
        jobs = [dataclasses.replace(j, lam=lam) for j in base_jobs]
        sched = sjf(ScheduleRequest(cluster=cluster, jobs=jobs,
                                    horizon=HORIZON, params={"kappas": [1]}))
        sim = simulate(cluster, jobs, sched.assignment)
        rows.append({"lambda": lam, "makespan": sim.makespan,
                     "avg_jct": sim.avg_jct,
                     "peak_contention": sim.peak_contention})
        if verbose:
            print(f"  lambda {lam:4.1f}: makespan {sim.makespan:7.0f} "
                  f"avg JCT {sim.avg_jct:7.1f} "
                  f"peak p {sim.peak_contention}")
    return rows


def validate(rows) -> dict:
    ms = [r["makespan"] for r in rows]
    # monotone non-increasing up to 5% noise, strictly better at the end
    mostly_down = all(ms[i + 1] <= ms[i] * 1.05 for i in range(len(ms) - 1))
    return {"lambda_mostly_decreasing": bool(mostly_down),
            "lambda_helps": bool(ms[-1] <= ms[0])}


if __name__ == "__main__":
    rows = run()
    print("validation:", validate(rows))
