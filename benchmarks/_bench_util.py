"""Shared plumbing for the repo's microbenchmark drivers.

The three bench scripts (``bench_contention``, ``bench_simulator``,
``bench_service``) report through one schema -- a top-level dict with
``bench``/``quick`` keys plus per-section row lists -- written by
:func:`write_report`, and build their inputs from the same scaled
Philly-mix case (:func:`philly_case`).  Their ``--quick`` runs double as
CI correctness smokes: every divergence check routes through
:func:`check_identical` / :func:`check_same_sim`, which hard-assert (CI
fails on the raise) instead of recording a boolean nobody reads.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import philly_cluster, philly_workload

try:                                    # run as a module: -m benchmarks....
    from benchmarks.common import mix_for
except ImportError:                     # run as a script from benchmarks/
    from common import mix_for

__all__ = ["make_parser", "philly_case", "timed", "same_schedule",
           "check_identical", "same_sim", "check_same_sim", "write_report"]


def make_parser(doc: str, default_out: str) -> argparse.ArgumentParser:
    """The shared CLI: ``--quick`` (CI smoke) and ``--out`` (JSON path)."""
    ap = argparse.ArgumentParser(description=doc)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small sizes + hard identity asserts")
    ap.add_argument("--out", default=default_out)
    return ap


def philly_case(n_jobs: int, seed: int = 1, servers: int = 20):
    """The standard benchmark case: a ``servers``-server Philly cluster
    plus the §7 job mix scaled to ``n_jobs`` -> (cluster, jobs)."""
    cluster = philly_cluster(servers, seed=seed)
    jobs = philly_workload(seed=seed, mix=mix_for(n_jobs))
    return cluster, jobs


def timed(fn, repeats: int = 1):
    """Run ``fn`` ``repeats`` times -> (last result, best wall seconds)."""
    best, result = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return result, best


def same_schedule(a, b, check_theta: bool = False) -> bool:
    """Bit-identity of two :class:`~repro.core.api.ScheduleResult`\\ s:
    committed clocks and the assignment, GPU id for GPU id.
    ``check_theta`` adds the (theta_u, kappa) the bisection landed on."""
    if check_theta and not (a.theta == b.theta and a.kappa == b.kappa):
        return False
    return bool(np.array_equal(a.est_start, b.est_start)
                and np.array_equal(a.est_finish, b.est_finish)
                and a.est_makespan == b.est_makespan
                and len(a.assignment) == len(b.assignment)
                and all(ja == jb and np.array_equal(ga, gb)
                        for (ja, ga), (jb, gb) in zip(a.assignment,
                                                      b.assignment)))


def check_identical(a, b, label: str, check_theta: bool = False) -> bool:
    """Hard-assert schedule bit-identity (CI's ``--quick`` smoke relies
    on the raise, not a report field); returns True for report rows."""
    assert same_schedule(a, b, check_theta=check_theta), label
    return True


def same_sim(a, b) -> bool:
    """Event-for-event identity of two :class:`~repro.core.SimResult`\\ s."""
    return bool(a.events == b.events
                and np.array_equal(a.start, b.start)
                and np.array_equal(a.finish, b.finish)
                and a.avg_jct == b.avg_jct
                and a.busy_gpu_slots == b.busy_gpu_slots)


def check_same_sim(a, b, label: str) -> bool:
    """Hard-assert simulation identity; returns True for report rows."""
    assert same_sim(a, b), label
    return True


def write_report(report: dict, out: str,
                 carry: tuple[str, ...] = ("scale",)) -> None:
    """Write the section-row report JSON and confirm the path.

    Sections named in ``carry`` that the current run did not produce are
    preserved from the previous report at ``out`` (if any) instead of
    being dropped -- so BENCH_contention.json's expensive ``--scale``
    section survives a rerun without ``--scale``.
    """
    try:
        with open(out) as fh:
            prior = json.load(fh)
    except (OSError, ValueError):
        prior = {}
    for key in carry:
        if key not in report and key in prior:
            report[key] = prior[key]
            print(f"kept prior {key!r} section from {out}")
    with open(out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {out}")
