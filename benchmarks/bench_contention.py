"""Microbenchmark for the batched/incremental contention-model engines.

Four measurements per job count |J| (16 / 64 / 256 by default):

  1. *Scheduler pass*: SJF-BCO (Alg. 1, theta bisection + kappa sweep) plus
     the slot simulation, once per engine.  The "reference" engine is the
     original per-candidate ``evaluate()`` loop; "incremental" replaces
     every full [J, S] model pass with an O(S)-ish probe/row-update;
     "batched" scores multi-candidate decisions via ``evaluate_many``.
     Schedules are asserted identical across engines (they are bit-equal
     by construction; see tests/test_batched_contention.py).  Each engine
     row records the sweep/bisect modes the counters were measured under,
     so numbers stay comparable across PRs as defaults move.
  2. *Kappa sweep*: SJF-BCO end-to-end (schedule + simulate) with
     ``params={"sweep": "batched"}`` (all kappa branches of a theta forked
     off shared placed prefixes) vs ``"sequential"`` (one kappa at a time,
     the reference), both pinned to the sequential bisection so the sweep
     axis is isolated.  Schedules are asserted identical -- CI's bench
     smoke fails on divergence.  Acceptance bar: >= 2x end-to-end at
     |J| = 256.
  3. *Theta bisection*: SJF-BCO end-to-end with ``params={"bisect":
     "speculative"}`` (probe-ladder rounds scored through shared
     copy-on-write placement lineages, the default) vs ``"sequential"``
     (the one-theta-at-a-time Alg. 1 oracle).  The final (theta, kappa,
     placements) are asserted identical -- CI's bench smoke fails on
     divergence.
  4. *Columnar placement*: SJF-BCO end-to-end with
     ``params={"placement": "columnar"}`` (the whole sweep x bisect forest
     advanced as one [branches, S] array program: vectorised argmin picks,
     Eq. (16) pool checks and batched refined-rho re-checks, jit-fused
     per step under x64 -- the bench enables ``jax_enable_x64`` so the
     "auto" backend resolves to "jit") vs ``"scalar"`` (the per-branch
     ``try_place`` walk -- the oracle, and the faster CPU path at every
     measured size).  The final (theta, kappa, placements) are asserted
     identical -- CI's bench smoke fails on divergence.  Each row
     records ``scalar_s`` / ``columnar_s`` / ``winner``; the section's
     ``placement_crossover_J`` is the smallest measured |J| where
     columnar wins, or null when the scalar walk wins throughout.  The
     full run sweeps |J| = 256 / 1024 / 4096 / 16384; ``--scale`` adds
     a ``scale`` section with the |J| = 100000 schedule+simulate point
     (jit-columnar AND scalar, bit-identity asserted, simulated against
     a seeded Pareto arrival stream) which ``write_report`` preserves
     across reruns without the flag.
  5. *Kernel microbench*: ``evaluate_many`` on a [C, J, S] stack vs a
     Python loop of C ``evaluate()`` calls over the same placements.
  6. *Heterogeneity*: a cluster whose per-GPU ``gpu_speeds`` / per-server
     ``links`` arrays merely restate the homogeneous scalars is asserted
     bit-identical to the scalar cluster (schedule AND SimEvent stream --
     the degenerate-identity contract of the hetero refactor, enforced in
     CI via ``--quick``), plus one mixed-tier timing point recording what
     the generalized Eq. (8) terms cost end-to-end.

Emits ``BENCH_contention.json`` -- part of the repo's perf trajectory --
with wall-clock numbers and the model-evaluation counters (engine
acceptance bar: >= 5x fewer full-model evaluations at |J| = 256).

Usage::

    PYTHONPATH=src python benchmarks/bench_contention.py \
        [--quick] [--scale] [--out F]
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import (ScheduleRequest, eval_counts, evaluate,
                        evaluate_many, get_policy, reset_eval_counts,
                        simulate)
try:                                    # run as a module: -m benchmarks....
    from benchmarks._bench_util import (check_identical, make_parser,
                                        philly_case, timed, write_report)
except ImportError:                     # run as a script from benchmarks/
    from _bench_util import (check_identical, make_parser, philly_case,
                             timed, write_report)

ENGINES = ("reference", "incremental", "batched")


def bench_scheduler(n_jobs: int, seed: int = 1) -> dict:
    cluster, jobs = philly_case(n_jobs, seed)
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "engines": {}}
    schedules = {}
    for engine in ENGINES:
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  horizon=horizon,
                                  params={"engine": engine})
        reset_eval_counts()
        t0 = time.perf_counter()
        sched = get_policy("sjf-bco")(request)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim = simulate(cluster, jobs, sched.assignment, engine=engine)
        t_sim = time.perf_counter() - t0
        counts = eval_counts()
        schedules[engine] = sched
        row["engines"][engine] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            # The active sweep/bisect/placement/stepping modes these
            # counters were measured under (the request defaults);
            # recorded per row so numbers stay comparable across PRs as
            # defaults move.
            "sweep_mode": "batched",
            "bisect_mode": "speculative",
            "placement_mode": "scalar",
            "sim_stepping": "multi" if engine != "reference" else "single",
            "est_makespan": sched.est_makespan,
            "sim_makespan": sim.makespan,
            **counts,
        }
    ref = schedules["reference"]
    for engine in ENGINES[1:]:
        # Hard failure, not just a report field: CI's bench-smoke step
        # relies on this to catch engine divergence.
        row["engines"][engine]["schedule_identical_to_reference"] = \
            check_identical(
                ref, schedules[engine],
                f"{engine} schedule diverged from reference at J={n_jobs}")
    ref_e = row["engines"]["reference"]
    inc_e = row["engines"]["incremental"]
    # "Full-model evaluations": complete [J, S] passes.  The incremental
    # engine replaces them with O(S) probes / row updates; evaluate_many
    # calls count once each (one fused pass).
    ref_full = ref_e["full"] + ref_e["batched_calls"]
    inc_full = inc_e["full"] + inc_e["batched_calls"]
    row["full_eval_reduction"] = round(ref_full / max(1, inc_full), 1)
    row["wall_speedup"] = round(
        (ref_e["schedule_s"] + ref_e["simulate_s"])
        / max(1e-9, inc_e["schedule_s"] + inc_e["simulate_s"]), 2)
    return row


def bench_sweep(n_jobs: int, seed: int = 1) -> dict:
    """SJF-BCO end-to-end: batched (shared-prefix) vs sequential kappa
    sweep, both on the default incremental engine and both pinned to the
    sequential bisection so only the sweep axis varies.  Both run the
    default scalar placement walk (the columnar axis has its own
    section, :func:`bench_placement`)."""
    cluster, jobs = philly_case(n_jobs, seed)
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "bisect_mode": "sequential", "modes": {}}
    schedules = {}
    for sweep in ("sequential", "batched"):
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  horizon=horizon,
                                  params={"sweep": sweep,
                                          "bisect": "sequential"})
        t0 = time.perf_counter()
        sched = get_policy("sjf-bco")(request)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim = simulate(cluster, jobs, sched.assignment)
        t_sim = time.perf_counter() - t0
        schedules[sweep] = sched
        row["modes"][sweep] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            "end_to_end_s": round(t_sched + t_sim, 4),
            "placement_mode": "scalar",
            "est_makespan": sched.est_makespan,
            "sim_makespan": sim.makespan,
        }
    # Hard failure, not just a report field: CI's bench-smoke step relies
    # on this to catch batched-sweep divergence.
    row["batched_identical_to_sequential"] = check_identical(
        schedules["sequential"], schedules["batched"],
        f"batched sweep diverged from sequential at J={n_jobs}",
        check_theta=True)
    row["end_to_end_speedup"] = round(
        row["modes"]["sequential"]["end_to_end_s"]
        / max(1e-9, row["modes"]["batched"]["end_to_end_s"]), 2)
    return row


def bench_bisect(n_jobs: int, seed: int = 1) -> dict:
    """SJF-BCO end-to-end: speculative vs sequential theta bisection,
    both on the default incremental engine, batched kappa sweep and
    scalar placement."""
    cluster, jobs = philly_case(n_jobs, seed)
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "sweep_mode": "batched",
                 "placement_mode": "scalar", "modes": {}}
    schedules = {}
    for bisect_mode in ("sequential", "speculative"):
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  horizon=horizon,
                                  params={"bisect": bisect_mode})
        t0 = time.perf_counter()
        sched = get_policy("sjf-bco")(request)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim = simulate(cluster, jobs, sched.assignment)
        t_sim = time.perf_counter() - t0
        schedules[bisect_mode] = sched
        row["modes"][bisect_mode] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            "end_to_end_s": round(t_sched + t_sim, 4),
            "theta": sched.theta,
            "kappa": sched.kappa,
            "est_makespan": sched.est_makespan,
            "sim_makespan": sim.makespan,
        }
    # Hard failure, not just a report field: CI's bench-smoke step relies
    # on this to catch speculative-bisection divergence from the oracle.
    row["speculative_identical_to_sequential"] = check_identical(
        schedules["sequential"], schedules["speculative"],
        f"speculative bisection diverged from sequential at J={n_jobs}",
        check_theta=True)
    row["end_to_end_speedup"] = round(
        row["modes"]["sequential"]["end_to_end_s"]
        / max(1e-9, row["modes"]["speculative"]["end_to_end_s"]), 2)
    return row


def bench_placement(n_jobs: int, seed: int = 1,
                    backend: str = "auto") -> dict:
    """SJF-BCO end-to-end: columnar branch-vectorised placement (the
    whole sweep x bisect forest as one [branches, S] array program,
    jit-fused per step when ``backend`` resolves to "jit") vs the
    scalar per-branch walk, identical modes otherwise (incremental
    engine, batched sweep, speculative bisection; each placement runs
    its own ladder defaults -- see ``bisect_levels``).  Schedules are
    asserted bit-identical (the jitted-columnar == scalar hard assert
    of CI's ``--quick`` smoke).

    Each row records ``scalar_s`` / ``columnar_s`` / ``winner`` so the
    report states explicitly, per size, which engine the measured
    crossover favours; ``main`` folds these into the section-level
    ``crossover_J``.  On this CPU host the scalar walk's copy-on-write
    lineages win at every measured size (the columnar row is the
    number to watch across PRs -- it is the trace-scale array engine
    that accelerator work builds on); record what is measured, not
    what is hoped."""
    from repro.core.api import resolve_columnar_backend
    cluster, jobs = philly_case(n_jobs, seed)
    horizon = max(1200, 12 * n_jobs)
    backend = resolve_columnar_backend({"columnar_backend": backend})
    row: dict = {"J": n_jobs, "sweep_mode": "batched",
                 "bisect_mode": "speculative",
                 "columnar_backend": backend, "modes": {}}
    schedules = {}
    for placement in ("scalar", "columnar"):
        request = ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=horizon,
            params={"placement": placement,
                    "columnar_backend": backend})
        sched, t_sched = timed(lambda req=request:
                               get_policy("sjf-bco")(req))
        sim, t_sim = timed(lambda a=sched.assignment:
                           simulate(cluster, jobs, a))
        schedules[placement] = sched
        row["modes"][placement] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            "end_to_end_s": round(t_sched + t_sim, 4),
            "theta": sched.theta,
            "kappa": sched.kappa,
            "est_makespan": sched.est_makespan,
            "sim_makespan": sim.makespan,
        }
    # Hard failure, not just a report field: CI's bench-smoke step
    # relies on this to catch (jitted-)columnar divergence from the
    # scalar oracle.
    row["columnar_identical_to_scalar"] = check_identical(
        schedules["scalar"], schedules["columnar"],
        f"columnar placement diverged from scalar at J={n_jobs}",
        check_theta=True)
    row["scalar_s"] = row["modes"]["scalar"]["schedule_s"]
    row["columnar_s"] = row["modes"]["columnar"]["schedule_s"]
    row["winner"] = ("columnar" if row["columnar_s"] < row["scalar_s"]
                     else "scalar")
    row["schedule_speedup"] = round(
        row["scalar_s"] / max(1e-9, row["columnar_s"]), 2)
    return row


def bench_scale(n_jobs: int = 100_000, seed: int = 1) -> dict:
    """The |J| = 1e5 point: one batch SJF-BCO pass through the
    jit-fused columnar placement, then a simulation of the resulting
    schedule against a seeded heavy-tailed Pareto arrival stream
    (``ArrivalSpec(kind="pareto")`` -- many near-zero gaps punctuated
    by long lulls, mean-normalised to 0.5 jobs/slot).  Runs the scalar
    walk on the same instance too, so the scalar-vs-columnar question
    is answered by measurement at this scale rather than extrapolated
    from the placement section's smaller sizes.  Behind ``--scale``
    only (minutes of wall clock); ``write_report`` preserves the
    section across reruns without the flag."""
    from repro.core import ArrivalSpec
    cluster, jobs = philly_case(n_jobs, seed)
    jobs = [dataclasses.replace(j, jid=i)
            for i, j in enumerate(jobs[:n_jobs])]
    arrivals = ArrivalSpec(kind="pareto", rate=0.5, seed=seed,
                           shape=1.5).build(jobs)
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "sweep_mode": "batched",
                 "bisect_mode": "speculative",
                 "arrivals": {"kind": "pareto", "rate": 0.5,
                              "shape": 1.5, "seed": seed,
                              "last_arrival": int(arrivals[-1])},
                 "modes": {}}
    schedules = {}
    for placement, params in (
            ("columnar", {"placement": "columnar",
                          "columnar_backend": "jit"}),
            ("scalar", {"placement": "scalar"})):
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  horizon=horizon, params=params)
        sched, t_sched = timed(lambda req=request:
                               get_policy("sjf-bco")(req))
        sim, t_sim = timed(lambda a=sched.assignment:
                           simulate(cluster, jobs, a, arrivals=arrivals))
        schedules[placement] = sched
        row["modes"][placement] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            "theta": sched.theta,
            "kappa": sched.kappa,
            "completed": sim.completed,
            "sim_makespan": sim.makespan,
        }
        print(f"scale |J|={n_jobs}: {placement} schedule "
              f"{t_sched:.1f}s simulate {t_sim:.1f}s "
              f"completed={sim.completed}", flush=True)
    row["columnar_identical_to_scalar"] = check_identical(
        schedules["scalar"], schedules["columnar"],
        f"columnar placement diverged from scalar at J={n_jobs}",
        check_theta=True)
    row["winner"] = (
        "columnar" if row["modes"]["columnar"]["schedule_s"]
        < row["modes"]["scalar"]["schedule_s"] else "scalar")
    return row


def bench_hetero(n_jobs: int, seed: int = 1) -> dict:
    """Degenerate-hetero identity (hard assert) + one mixed-tier point.

    A cluster whose ``gpu_speeds``/``links`` restate the scalars must be
    bit-identical to the scalar cluster -- schedule and simulation both
    (CI's bench smoke runs this under ``--quick``).  The mixed-tier row
    then times SJF-BCO + simulate on a genuinely heterogeneous cluster
    (half the servers at quarter speed, half the uplinks isolated), so
    the cost of the generalized Eq. (8) terms is tracked across PRs."""
    cluster, jobs = philly_case(n_jobs, seed)
    uniform = dataclasses.replace(
        cluster,
        gpu_speeds=(cluster.gpu_speed,) * cluster.num_gpus,
        links=((cluster.b_inter, "shared"),) * cluster.num_servers)
    assert not uniform.is_heterogeneous
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "modes": {}}
    schedules, sims = {}, {}
    for name, cl in (("scalar", cluster), ("degenerate", uniform)):
        request = ScheduleRequest(cluster=cl, jobs=jobs, horizon=horizon)
        sched, t_sched = timed(lambda req=request:
                               get_policy("sjf-bco")(req))
        sim, t_sim = timed(lambda c=cl, a=sched.assignment:
                           simulate(c, jobs, a))
        schedules[name], sims[name] = sched, sim
        row["modes"][name] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            "sim_makespan": sim.makespan,
        }
    # Hard failure, not just a report field: CI's bench-smoke step relies
    # on this to catch degenerate-hetero divergence from the scalars.
    row["degenerate_identical_to_scalar"] = check_identical(
        schedules["scalar"], schedules["degenerate"],
        f"degenerate hetero cluster diverged from scalars at J={n_jobs}",
        check_theta=True)
    if sims["scalar"].events != sims["degenerate"].events:
        raise AssertionError(
            f"degenerate hetero SimEvent stream diverged at J={n_jobs}")
    # Mixed tiers: half the servers at quarter speed, half isolated.
    speeds, links = [], []
    for s, cap in enumerate(cluster.capacities):
        speeds += [cluster.gpu_speed * (0.25 if s % 2 else 1.0)] * cap
        links.append((cluster.b_inter, "isolated" if s % 2 else "shared"))
    mixed = dataclasses.replace(cluster, gpu_speeds=tuple(speeds),
                                links=tuple(links))
    request = ScheduleRequest(cluster=mixed, jobs=jobs, horizon=horizon)
    sched, t_sched = timed(lambda req=request: get_policy("sjf-bco")(req))
    sim, t_sim = timed(lambda a=sched.assignment:
                       simulate(mixed, jobs, a))
    row["modes"]["mixed"] = {
        "schedule_s": round(t_sched, 4),
        "simulate_s": round(t_sim, 4),
        "sim_makespan": sim.makespan,
    }
    row["mixed_overhead"] = round(
        row["modes"]["mixed"]["schedule_s"]
        / max(1e-9, row["modes"]["scalar"]["schedule_s"]), 2)
    return row


def bench_evaluate_many(n_jobs: int, n_cands: int = 64, seed: int = 0,
                        repeats: int = 5) -> dict:
    """evaluate_many on [C, J, S] vs a loop of C evaluate() calls."""
    rng = np.random.default_rng(seed)
    cluster, jobs = philly_case(n_jobs, seed)
    S = cluster.num_servers
    stack = np.zeros((n_cands, len(jobs), S), dtype=np.int64)
    for c in range(n_cands):
        for i, job in enumerate(jobs):
            for _ in range(job.num_gpus):
                stack[c, i, rng.integers(S)] += 1
    t_loop = t_many = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for c in range(n_cands):
            evaluate(cluster, jobs, stack[c])
        t_loop = min(t_loop, time.perf_counter() - t0)
        t0 = time.perf_counter()
        many = evaluate_many(cluster, jobs, stack)
        t_many = min(t_many, time.perf_counter() - t0)
    # sanity: the batch result matches the loop on the last candidate
    assert np.array_equal(many.tau[-1],
                          evaluate(cluster, jobs, stack[-1]).tau)
    return {"J": n_jobs, "C": n_cands,
            "loop_s": round(t_loop, 4), "batched_s": round(t_many, 4),
            "speedup": round(t_loop / max(1e-9, t_many), 2)}


def main() -> None:
    ap = make_parser(__doc__, "BENCH_contention.json")
    ap.add_argument("--scale", action="store_true",
                    help="add the |J|=100000 schedule+simulate point "
                         "(minutes; excluded from --quick)")
    args = ap.parse_args()
    # The jit-fused columnar backend is gated on float64 (the
    # bit-identity precondition); enable it up front so "auto"
    # resolves to "jit" and the placement rows measure the fast path.
    import jax
    jax.config.update("jax_enable_x64", True)

    sizes = [16, 64] if args.quick else [16, 64, 256]
    report = {"bench": "contention-engine",
              "quick": args.quick,
              "scheduler": [], "sweep": [], "bisect": [],
              "placement": [], "evaluate_many": [], "hetero": []}
    for n in sizes:
        row = bench_scheduler(n)
        report["scheduler"].append(row)
        inc = row["engines"]["incremental"]
        print(f"|J|={n:4d}  ref {row['engines']['reference']['schedule_s']:.2f}s"
              f"  inc {inc['schedule_s']:.2f}s"
              f"  wall x{row['wall_speedup']:.2f}"
              f"  full-evals x{row['full_eval_reduction']:.0f} fewer"
              f"  identical={inc['schedule_identical_to_reference']}")
    for n in sizes:
        row = bench_sweep(n)
        report["sweep"].append(row)
        print(f"sweep |J|={n:4d}: sequential "
              f"{row['modes']['sequential']['end_to_end_s']:.2f}s"
              f"  batched {row['modes']['batched']['end_to_end_s']:.2f}s"
              f"  x{row['end_to_end_speedup']:.2f}"
              f"  identical={row['batched_identical_to_sequential']}")
    for n in sizes:
        row = bench_bisect(n)
        report["bisect"].append(row)
        print(f"bisect |J|={n:4d}: sequential "
              f"{row['modes']['sequential']['end_to_end_s']:.2f}s"
              f"  speculative {row['modes']['speculative']['end_to_end_s']:.2f}s"
              f"  x{row['end_to_end_speedup']:.2f}"
              f"  identical={row['speculative_identical_to_sequential']}")
    # Jitted-columnar-vs-scalar identity is part of the --quick CI
    # smoke too (hard assert inside bench_placement; x64 is on, so
    # "auto" resolves to the jit backend).
    for n in (sizes if args.quick else [256, 1024, 4096, 16384]):
        row = bench_placement(n)
        report["placement"].append(row)
        print(f"placement |J|={n:5d}: scalar {row['scalar_s']:.2f}s"
              f"  columnar[{row['columnar_backend']}] "
              f"{row['columnar_s']:.2f}s"
              f"  winner={row['winner']}"
              f"  identical={row['columnar_identical_to_scalar']}")
    # The explicit crossover: smallest measured |J| where the columnar
    # engine beats the scalar walk, or null when the scalar walk wins
    # at every measured size (the honest answer on this CPU host).
    won = [r["J"] for r in report["placement"] if r["winner"] == "columnar"]
    report["placement_crossover_J"] = min(won) if won else None
    print(f"placement crossover |J| = {report['placement_crossover_J']}")
    if args.scale and not args.quick:
        report["scale"] = [bench_scale(100_000)]
    for n in sizes:
        row = bench_evaluate_many(n, n_cands=16 if args.quick else 64)
        report["evaluate_many"].append(row)
        print(f"evaluate_many |J|={n:4d} C={row['C']}: loop {row['loop_s']}s"
              f" batched {row['batched_s']}s  x{row['speedup']:.1f}")
    # Degenerate-hetero identity is part of the --quick CI smoke too
    # (hard asserts inside bench_hetero).
    for n in sizes:
        row = bench_hetero(n)
        report["hetero"].append(row)
        print(f"hetero |J|={n:4d}: scalar "
              f"{row['modes']['scalar']['schedule_s']:.2f}s"
              f"  mixed {row['modes']['mixed']['schedule_s']:.2f}s"
              f"  x{row['mixed_overhead']:.2f}"
              f"  identical={row['degenerate_identical_to_scalar']}")

    write_report(report, args.out)


if __name__ == "__main__":
    main()
