"""Microbenchmark for the batched/incremental contention-model engines.

Four measurements per job count |J| (16 / 64 / 256 by default):

  1. *Scheduler pass*: SJF-BCO (Alg. 1, theta bisection + kappa sweep) plus
     the slot simulation, once per engine.  The "reference" engine is the
     original per-candidate ``evaluate()`` loop; "incremental" replaces
     every full [J, S] model pass with an O(S)-ish probe/row-update;
     "batched" scores multi-candidate decisions via ``evaluate_many``.
     Schedules are asserted identical across engines (they are bit-equal
     by construction; see tests/test_batched_contention.py).  Each engine
     row records the sweep/bisect modes the counters were measured under,
     so numbers stay comparable across PRs as defaults move.
  2. *Kappa sweep*: SJF-BCO end-to-end (schedule + simulate) with
     ``params={"sweep": "batched"}`` (all kappa branches of a theta forked
     off shared placed prefixes) vs ``"sequential"`` (one kappa at a time,
     the reference), both pinned to the sequential bisection so the sweep
     axis is isolated.  Schedules are asserted identical -- CI's bench
     smoke fails on divergence.  Acceptance bar: >= 2x end-to-end at
     |J| = 256.
  3. *Theta bisection*: SJF-BCO end-to-end with ``params={"bisect":
     "speculative"}`` (probe-ladder rounds scored through shared
     copy-on-write placement lineages, the default) vs ``"sequential"``
     (the one-theta-at-a-time Alg. 1 oracle).  The final (theta, kappa,
     placements) are asserted identical -- CI's bench smoke fails on
     divergence.
  4. *Kernel microbench*: ``evaluate_many`` on a [C, J, S] stack vs a
     Python loop of C ``evaluate()`` calls over the same placements.

Emits ``BENCH_contention.json`` -- part of the repo's perf trajectory --
with wall-clock numbers and the model-evaluation counters (engine
acceptance bar: >= 5x fewer full-model evaluations at |J| = 256).

Usage::

    PYTHONPATH=src python benchmarks/bench_contention.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (ScheduleRequest, eval_counts, evaluate,
                        evaluate_many, get_policy, philly_cluster,
                        philly_workload, reset_eval_counts, simulate)
try:                                    # run as a module: -m benchmarks....
    from benchmarks.common import mix_for
except ImportError:                     # run as a script from benchmarks/
    from common import mix_for

ENGINES = ("reference", "incremental", "batched")


def bench_scheduler(n_jobs: int, seed: int = 1) -> dict:
    cluster = philly_cluster(20, seed=seed)
    jobs = philly_workload(seed=seed, mix=mix_for(n_jobs))
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "engines": {}}
    schedules = {}
    for engine in ENGINES:
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  horizon=horizon,
                                  params={"engine": engine})
        reset_eval_counts()
        t0 = time.perf_counter()
        sched = get_policy("sjf-bco")(request)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim = simulate(cluster, jobs, sched.assignment, engine=engine)
        t_sim = time.perf_counter() - t0
        counts = eval_counts()
        schedules[engine] = sched
        row["engines"][engine] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            # The active sweep/bisect/stepping modes these counters were
            # measured under (the request defaults); recorded per row so
            # numbers stay comparable across PRs as defaults move.
            "sweep_mode": "batched",
            "bisect_mode": "speculative",
            "sim_stepping": "multi" if engine != "reference" else "single",
            "est_makespan": sched.est_makespan,
            "sim_makespan": sim.makespan,
            **counts,
        }
    ref = schedules["reference"]
    for engine in ENGINES[1:]:
        other = schedules[engine]
        same = (other.est_makespan == ref.est_makespan
                and len(other.assignment) == len(ref.assignment)
                and all(j1 == j2 and np.array_equal(g1, g2)
                        for (j1, g1), (j2, g2)
                        in zip(ref.assignment, other.assignment)))
        # Hard failure, not just a report field: CI's bench-smoke step
        # relies on this to catch engine divergence.
        assert same, f"{engine} schedule diverged from reference at J={n_jobs}"
        row["engines"][engine]["schedule_identical_to_reference"] = same
    ref_e = row["engines"]["reference"]
    inc_e = row["engines"]["incremental"]
    # "Full-model evaluations": complete [J, S] passes.  The incremental
    # engine replaces them with O(S) probes / row updates; evaluate_many
    # calls count once each (one fused pass).
    ref_full = ref_e["full"] + ref_e["batched_calls"]
    inc_full = inc_e["full"] + inc_e["batched_calls"]
    row["full_eval_reduction"] = round(ref_full / max(1, inc_full), 1)
    row["wall_speedup"] = round(
        (ref_e["schedule_s"] + ref_e["simulate_s"])
        / max(1e-9, inc_e["schedule_s"] + inc_e["simulate_s"]), 2)
    return row


def bench_sweep(n_jobs: int, seed: int = 1) -> dict:
    """SJF-BCO end-to-end: batched (shared-prefix) vs sequential kappa
    sweep, both on the default incremental engine and both pinned to the
    sequential bisection so only the sweep axis varies."""
    cluster = philly_cluster(20, seed=seed)
    jobs = philly_workload(seed=seed, mix=mix_for(n_jobs))
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "bisect_mode": "sequential", "modes": {}}
    schedules = {}
    for sweep in ("sequential", "batched"):
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  horizon=horizon,
                                  params={"sweep": sweep,
                                          "bisect": "sequential"})
        t0 = time.perf_counter()
        sched = get_policy("sjf-bco")(request)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim = simulate(cluster, jobs, sched.assignment)
        t_sim = time.perf_counter() - t0
        schedules[sweep] = sched
        row["modes"][sweep] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            "end_to_end_s": round(t_sched + t_sim, 4),
            "est_makespan": sched.est_makespan,
            "sim_makespan": sim.makespan,
        }
    ref, bat = schedules["sequential"], schedules["batched"]
    same = (bat.est_makespan == ref.est_makespan
            and bat.kappa == ref.kappa
            and len(bat.assignment) == len(ref.assignment)
            and all(j1 == j2 and np.array_equal(g1, g2)
                    for (j1, g1), (j2, g2)
                    in zip(ref.assignment, bat.assignment)))
    # Hard failure, not just a report field: CI's bench-smoke step relies
    # on this to catch batched-sweep divergence.
    assert same, f"batched sweep diverged from sequential at J={n_jobs}"
    row["batched_identical_to_sequential"] = same
    row["end_to_end_speedup"] = round(
        row["modes"]["sequential"]["end_to_end_s"]
        / max(1e-9, row["modes"]["batched"]["end_to_end_s"]), 2)
    return row


def bench_bisect(n_jobs: int, seed: int = 1) -> dict:
    """SJF-BCO end-to-end: speculative vs sequential theta bisection,
    both on the default incremental engine and batched kappa sweep."""
    cluster = philly_cluster(20, seed=seed)
    jobs = philly_workload(seed=seed, mix=mix_for(n_jobs))
    horizon = max(1200, 12 * n_jobs)
    row: dict = {"J": n_jobs, "sweep_mode": "batched", "modes": {}}
    schedules = {}
    for bisect_mode in ("sequential", "speculative"):
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  horizon=horizon,
                                  params={"bisect": bisect_mode})
        t0 = time.perf_counter()
        sched = get_policy("sjf-bco")(request)
        t_sched = time.perf_counter() - t0
        t0 = time.perf_counter()
        sim = simulate(cluster, jobs, sched.assignment)
        t_sim = time.perf_counter() - t0
        schedules[bisect_mode] = sched
        row["modes"][bisect_mode] = {
            "schedule_s": round(t_sched, 4),
            "simulate_s": round(t_sim, 4),
            "end_to_end_s": round(t_sched + t_sim, 4),
            "theta": sched.theta,
            "kappa": sched.kappa,
            "est_makespan": sched.est_makespan,
            "sim_makespan": sim.makespan,
        }
    ref, spec = schedules["sequential"], schedules["speculative"]
    same = (spec.theta == ref.theta
            and spec.kappa == ref.kappa
            and spec.est_makespan == ref.est_makespan
            and len(spec.assignment) == len(ref.assignment)
            and all(j1 == j2 and np.array_equal(g1, g2)
                    for (j1, g1), (j2, g2)
                    in zip(ref.assignment, spec.assignment)))
    # Hard failure, not just a report field: CI's bench-smoke step relies
    # on this to catch speculative-bisection divergence from the oracle.
    assert same, \
        f"speculative bisection diverged from sequential at J={n_jobs}"
    row["speculative_identical_to_sequential"] = same
    row["end_to_end_speedup"] = round(
        row["modes"]["sequential"]["end_to_end_s"]
        / max(1e-9, row["modes"]["speculative"]["end_to_end_s"]), 2)
    return row


def bench_evaluate_many(n_jobs: int, n_cands: int = 64, seed: int = 0,
                        repeats: int = 5) -> dict:
    """evaluate_many on [C, J, S] vs a loop of C evaluate() calls."""
    rng = np.random.default_rng(seed)
    cluster = philly_cluster(20, seed=seed)
    jobs = philly_workload(seed=seed, mix=mix_for(n_jobs))
    S = cluster.num_servers
    stack = np.zeros((n_cands, len(jobs), S), dtype=np.int64)
    for c in range(n_cands):
        for i, job in enumerate(jobs):
            for _ in range(job.num_gpus):
                stack[c, i, rng.integers(S)] += 1
    t_loop = t_many = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for c in range(n_cands):
            evaluate(cluster, jobs, stack[c])
        t_loop = min(t_loop, time.perf_counter() - t0)
        t0 = time.perf_counter()
        many = evaluate_many(cluster, jobs, stack)
        t_many = min(t_many, time.perf_counter() - t0)
    # sanity: the batch result matches the loop on the last candidate
    assert np.array_equal(many.tau[-1],
                          evaluate(cluster, jobs, stack[-1]).tau)
    return {"J": n_jobs, "C": n_cands,
            "loop_s": round(t_loop, 4), "batched_s": round(t_many, 4),
            "speedup": round(t_loop / max(1e-9, t_many), 2)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small sizes only")
    ap.add_argument("--out", default="BENCH_contention.json")
    args = ap.parse_args()

    sizes = [16, 64] if args.quick else [16, 64, 256]
    report = {"bench": "contention-engine",
              "quick": args.quick,
              "scheduler": [], "sweep": [], "bisect": [],
              "evaluate_many": []}
    for n in sizes:
        row = bench_scheduler(n)
        report["scheduler"].append(row)
        inc = row["engines"]["incremental"]
        print(f"|J|={n:4d}  ref {row['engines']['reference']['schedule_s']:.2f}s"
              f"  inc {inc['schedule_s']:.2f}s"
              f"  wall x{row['wall_speedup']:.2f}"
              f"  full-evals x{row['full_eval_reduction']:.0f} fewer"
              f"  identical={inc['schedule_identical_to_reference']}")
    for n in sizes:
        row = bench_sweep(n)
        report["sweep"].append(row)
        print(f"sweep |J|={n:4d}: sequential "
              f"{row['modes']['sequential']['end_to_end_s']:.2f}s"
              f"  batched {row['modes']['batched']['end_to_end_s']:.2f}s"
              f"  x{row['end_to_end_speedup']:.2f}"
              f"  identical={row['batched_identical_to_sequential']}")
    for n in sizes:
        row = bench_bisect(n)
        report["bisect"].append(row)
        print(f"bisect |J|={n:4d}: sequential "
              f"{row['modes']['sequential']['end_to_end_s']:.2f}s"
              f"  speculative {row['modes']['speculative']['end_to_end_s']:.2f}s"
              f"  x{row['end_to_end_speedup']:.2f}"
              f"  identical={row['speculative_identical_to_sequential']}")
    for n in sizes:
        row = bench_evaluate_many(n, n_cands=16 if args.quick else 64)
        report["evaluate_many"].append(row)
        print(f"evaluate_many |J|={n:4d} C={row['C']}: loop {row['loop_s']}s"
              f" batched {row['batched_s']}s  x{row['speedup']:.1f}")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
