"""Fig. 5 reproduction: impact of the small/large threshold kappa.

Paper claim: as kappa grows the makespan first DROPS (small jobs pack into
shared servers, less fragmentation), then RISES (big jobs packed into
shared servers worsen contention), then DROPS slightly again (everything
shared shrinks ring spans).  We sweep kappa with the theta bisection fixed
to SJF-BCO's own schedule at each kappa."""
from __future__ import annotations

import numpy as np

from repro.core import (ScheduleRequest, get_policy, philly_cluster,
                        philly_workload, simulate)

HORIZON = 1200
KAPPAS = (1, 2, 4, 8, 16, 32)


def run(seed: int = 1, verbose: bool = True) -> list[dict]:
    cluster = philly_cluster(20, seed=seed)
    jobs = philly_workload(seed=seed)
    sjf = get_policy("sjf-bco")
    rows = []
    for kappa in KAPPAS:
        sched = sjf(ScheduleRequest(cluster=cluster, jobs=jobs,
                                    horizon=HORIZON,
                                    params={"kappas": [kappa]}))
        sim = simulate(cluster, jobs, sched.assignment)
        rows.append({"kappa": kappa, "makespan": sim.makespan,
                     "avg_jct": sim.avg_jct,
                     "peak_contention": sim.peak_contention})
        if verbose:
            print(f"  kappa {kappa:3d}: makespan {sim.makespan:7.0f} "
                  f"avg JCT {sim.avg_jct:7.1f} "
                  f"peak contention {sim.peak_contention}")
    return rows


def validate(rows) -> dict:
    """Non-monotone with an interior change of direction (the paper's
    drop-rise(-drop) shape), and kappa matters (spread > 5%)."""
    ms = [r["makespan"] for r in rows]
    diffs = np.sign(np.diff(ms))
    non_monotone = len({d for d in diffs if d != 0}) > 1
    spread = (max(ms) - min(ms)) / max(ms)
    return {"kappa_non_monotone": bool(non_monotone),
            "kappa_matters": bool(spread > 0.05),
            "spread": round(float(spread), 3)}


if __name__ == "__main__":
    rows = run()
    print("validation:", validate(rows))
