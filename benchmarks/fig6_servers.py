"""Fig. 6 reproduction: makespan as the number of servers grows (10 -> 20).

Paper claim (T=1500): more servers => less contention => smaller makespan
for FF, LS and SJF-BCO; FF benefits the most."""
from __future__ import annotations

from benchmarks.common import run_policy
from repro.core import philly_cluster, philly_workload

HORIZON = 1500
SERVER_COUNTS = (10, 14, 20)
POLICY_NAMES = ("SJF-BCO", "FF", "LS")


def run(seed: int = 1, verbose: bool = True) -> list[dict]:
    jobs = philly_workload(seed=seed)
    rows = []
    for n in SERVER_COUNTS:
        cluster = philly_cluster(n, seed=seed)
        for name in POLICY_NAMES:
            r = run_policy(name, cluster, jobs, HORIZON)
            r["servers"] = n
            rows.append(r)
            if verbose:
                print(f"  {n:2d} servers {name:8s} makespan "
                      f"{r['makespan']:7.0f} peak p {r['peak_contention']}")
    return rows


def validate(rows) -> dict:
    out = {}
    for name in POLICY_NAMES:
        ms = [r["makespan"] for r in rows if r["policy"] == name]
        out[f"{name}_decreases"] = bool(ms[-1] < ms[0])
    return out


if __name__ == "__main__":
    rows = run()
    print("validation:", validate(rows))
