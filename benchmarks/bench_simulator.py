"""Microbenchmark for the simulator's incremental scaling axes.

Two sections, at |J| in {256, 1024} (``--quick``: {64, 256}), each over a
*batch* case (every job available at t=0, seeded random G_j-GPU placements
-- heavy straddling and deep FIFO queues, the simulator-bound regime the
Fig. 3 loop hits at scale; scheduling cost is excluded by construction) and
an *online* case (the same placements behind a staggered Poisson-gap
arrival stream: idle windows + arrival-constrained starts):

  1. *Readiness* (``simulate`` section): ``readiness="tracked"`` (per-GPU
     queue-head pointers + per-job GPUs-at-head counters, the default --
     which now also means multi-window stepping) vs ``readiness="rescan"``
     (the original per-event O(J * G) scan, the semantics oracle).
  2. *Stepping* (``stepping`` section): tracked readiness with
     ``stepping="multi"`` (speculative multi-window ladders: the Eq.
     (6)-(8) terms of many completion stages per vectorised batch) vs
     ``stepping="single"`` (one IncrementalEval window at a time).

All combinations must agree event-for-event (asserted here -- CI's bench
smoke runs ``--quick`` and fails on divergence).  Emits
``BENCH_simulator.json`` with the wall-clock numbers; acceptance bars:
>= 5x tracked-vs-rescan and >= 2x vs the PR 4 tracked numbers with
multi-window stepping on, both at |J| = 1024.

Usage::

    PYTHONPATH=src python benchmarks/bench_simulator.py [--quick] [--out F]
"""
from __future__ import annotations

import numpy as np

from repro.core import simulate

try:                                    # run as a module: -m benchmarks....
    from benchmarks._bench_util import (check_same_sim, make_parser,
                                        philly_case, timed, write_report)
except ImportError:                     # run as a script from benchmarks/
    from _bench_util import (check_same_sim, make_parser, philly_case,
                             timed, write_report)


def _case_inputs(n_jobs: int, seed: int):
    cluster, jobs = philly_case(n_jobs, seed)
    rng = np.random.default_rng(seed)
    assignment = [(j.jid, np.sort(rng.choice(cluster.num_gpus,
                                             size=j.num_gpus, replace=False)))
                  for j in jobs]
    arrivals = np.floor(np.cumsum(
        rng.exponential(2.0, size=len(jobs)))).astype(np.int64)
    return cluster, jobs, assignment, arrivals


def bench_simulate(n_jobs: int, seed: int = 1, repeats: int = 5) -> dict:
    cluster, jobs, assignment, arrivals = _case_inputs(n_jobs, seed)
    row: dict = {"J": n_jobs, "cases": {}}
    for case, arr in (("batch", None), ("online", arrivals)):
        sims, times = {}, {}
        for readiness in ("tracked", "rescan"):
            sims[readiness], times[readiness] = timed(
                lambda r=readiness: simulate(cluster, jobs, assignment,
                                             arrivals=arr, readiness=r),
                repeats=repeats)
        a = sims["tracked"]
        # Hard failure, not just a report field: CI's bench-smoke step
        # relies on this to catch readiness-tracking divergence.
        same = check_same_sim(
            a, sims["rescan"],
            f"tracked readiness diverged from rescan at J={n_jobs}")
        row["cases"][case] = {
            "tracked_s": round(times["tracked"], 4),
            "rescan_s": round(times["rescan"], 4),
            # the modes the tracked row ran under (request defaults)
            "tracked_stepping": "multi",
            "speedup": round(times["rescan"] / max(1e-9, times["tracked"]), 2),
            "events": len(a.events),
            "makespan": float(a.makespan),
            "identical_to_rescan": same,
        }
    return row


def bench_stepping(n_jobs: int, seed: int = 1, repeats: int = 5) -> dict:
    """Multi-window ladders vs single-window stepping, both tracked."""
    cluster, jobs, assignment, arrivals = _case_inputs(n_jobs, seed)
    row: dict = {"J": n_jobs, "cases": {}}
    for case, arr in (("batch", None), ("online", arrivals)):
        sims, times = {}, {}
        for stepping in ("multi", "single"):
            sims[stepping], times[stepping] = timed(
                lambda s=stepping: simulate(cluster, jobs, assignment,
                                            arrivals=arr, stepping=s),
                repeats=repeats)
        a = sims["multi"]
        # Hard failure, not just a report field: CI's bench-smoke step
        # relies on this to catch multi-window stepping divergence.
        same = check_same_sim(
            a, sims["single"],
            f"multi-window stepping diverged from single at J={n_jobs}")
        row["cases"][case] = {
            "multi_s": round(times["multi"], 4),
            "single_s": round(times["single"], 4),
            "speedup": round(times["single"] / max(1e-9, times["multi"]), 2),
            "events": len(a.events),
            "makespan": float(a.makespan),
            "identical_to_single": same,
        }
    return row


def main() -> None:
    args = make_parser(__doc__, "BENCH_simulator.json").parse_args()

    sizes = [64, 256] if args.quick else [256, 1024]
    report = {"bench": "simulator-readiness", "quick": args.quick,
              "simulate": [], "stepping": []}
    for n in sizes:
        row = bench_simulate(n)
        report["simulate"].append(row)
        for case, r in row["cases"].items():
            print(f"|J|={n:5d} {case:6s}  rescan {r['rescan_s']:.3f}s"
                  f"  tracked {r['tracked_s']:.3f}s  x{r['speedup']:.2f}"
                  f"  events={r['events']}")
    for n in sizes:
        row = bench_stepping(n)
        report["stepping"].append(row)
        for case, r in row["cases"].items():
            print(f"stepping |J|={n:5d} {case:6s}  single {r['single_s']:.3f}s"
                  f"  multi {r['multi_s']:.3f}s  x{r['speedup']:.2f}"
                  f"  identical={r['identical_to_single']}")

    write_report(report, args.out)


if __name__ == "__main__":
    main()
