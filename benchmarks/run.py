"""Benchmark harness: one function per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = scheduler wall
time where applicable) plus the validation verdicts against the paper's
qualitative claims.  The roofline table (dry-run derived) is appended when
results/dryrun_single.json exists.
"""
from __future__ import annotations

import sys
import time

import numpy as np


def _fig4() -> list[str]:
    from benchmarks import fig4_makespan as f4
    rows = f4.run(verbose=False)
    out = []
    for name in ("SJF-BCO", "FF", "LS", "RAND"):
        sel = [r for r in rows if r["policy"] == name]
        us = np.mean([r["sched_time_s"] for r in sel]) * 1e6
        ms = np.mean([r["makespan"] for r in sel])
        jct = np.mean([r["avg_jct"] for r in sel])
        out.append(f"fig4_{name},{us:.0f},makespan={ms:.0f};avg_jct={jct:.0f}")
    v = f4.validate(rows)
    out.append(f"fig4_validation,0,{';'.join(f'{k}={v[k]}' for k in v)}")
    return out


def _fig5() -> list[str]:
    from benchmarks import fig5_kappa as f5
    t0 = time.time()
    rows = f5.run(verbose=False)
    us = (time.time() - t0) / len(rows) * 1e6
    v = f5.validate(rows)
    curve = ";".join(f"k{r['kappa']}={r['makespan']:.0f}" for r in rows)
    return [f"fig5_kappa_sweep,{us:.0f},{curve}",
            f"fig5_validation,0,{';'.join(f'{k}={v[k]}' for k in v)}"]


def _fig6() -> list[str]:
    from benchmarks import fig6_servers as f6
    t0 = time.time()
    rows = f6.run(verbose=False)
    us = (time.time() - t0) / len(rows) * 1e6
    v = f6.validate(rows)
    out = []
    for name in ("SJF-BCO", "FF", "LS"):
        curve = ";".join(f"s{r['servers']}={r['makespan']:.0f}"
                         for r in rows if r["policy"] == name)
        out.append(f"fig6_{name},{us:.0f},{curve}")
    out.append(f"fig6_validation,0,{';'.join(f'{k}={v[k]}' for k in v)}")
    return out


def _fig7() -> list[str]:
    from benchmarks import fig7_lambda as f7
    t0 = time.time()
    rows = f7.run(verbose=False)
    us = (time.time() - t0) / len(rows) * 1e6
    v = f7.validate(rows)
    curve = ";".join(f"l{r['lambda']:.0f}={r['makespan']:.0f}" for r in rows)
    return [f"fig7_lambda_sweep,{us:.0f},{curve}",
            f"fig7_validation,0,{';'.join(f'{k}={v[k]}' for k in v)}"]


def _rar() -> list[str]:
    from benchmarks import rar_microbench
    try:
        return [f"rar_{l}" for l in rar_microbench.run(verbose=False)]
    except Exception as e:                                  # noqa: BLE001
        return [f"rar_microbench,0,SKIPPED({type(e).__name__})"]


def _ablations() -> list[str]:
    from benchmarks import ablations
    return ablations.run(verbose=False)


def _roofline() -> list[str]:
    from benchmarks import roofline_report
    rows = roofline_report.run(verbose=False)
    out = []
    for r in rows:
        out.append(
            f"roofline_{r['arch']}_{r['shape']},0,"
            f"t_comp={r['t_compute_s']:.2e};t_mem={r['t_memory_s']:.2e};"
            f"t_coll={r['t_collective_s']:.2e};bound={r['bottleneck']};"
            f"mem_gib={r['hbm_peak_bytes']/2**30:.1f}")
    if not out:
        out = ["roofline,0,NO_DRYRUN_JSON(run repro.launch.dryrun first)"]
    return out


def main() -> None:
    sections = [("fig4 makespan-vs-policy", _fig4),
                ("fig5 kappa sweep", _fig5),
                ("fig6 servers sweep", _fig6),
                ("fig7 lambda sweep", _fig7),
                ("rar microbench", _rar),
                ("ablations (beyond-paper)", _ablations),
                ("roofline (dry-run derived)", _roofline)]
    failures = 0
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# {title}", file=sys.stderr)
        try:
            for row in fn():
                print(row)
        except Exception as e:                              # noqa: BLE001
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"{title.replace(' ', '_')},0,FAILED({type(e).__name__})")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
