import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: profile one (arch x shape) pair, optionally with
config overrides, and print the three roofline terms + the top collective /
HBM-traffic contributors (hypothesis -> change -> re-lower -> measure).

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch llama3-405b --shape train_4k --set q_chunk=1024
"""
import argparse
import dataclasses
import json

import jax

from repro.configs import ARCHS, get_config
from repro.launch import roofline
from repro.launch.dryrun import run_pair


def profile(arch: str, shape: str, overrides: dict | None = None,
            verbose: bool = True, multi_pod: bool = False,
            opt_overrides: dict | None = None) -> dict:
    cfg0 = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg0, **overrides)
        ARCHS[arch] = cfg           # run_pair resolves via the registry
    try:
        row = run_pair(arch, shape, multi_pod=multi_pod, verbose=False,
                       opt_overrides=opt_overrides)
    finally:
        ARCHS[arch] = cfg0
    if verbose:
        print(f"== {arch} x {shape} overrides={overrides or {}}")
        print(f"   t_compute {row['t_compute_s']:.3e}s  "
              f"t_memory {row['t_memory_s']:.3e}s  "
              f"t_collective {row['t_collective_s']:.3e}s  "
              f"-> {row['bottleneck']}  "
              f"mem {row['hbm_peak_bytes']/2**30:.1f} GiB  "
              f"useful {row['useful_ratio']:.3f}")
    return row


def profile_deep(arch: str, shape: str, overrides: dict | None = None,
                 multi_pod: bool = False) -> None:
    """Full breakdown: requires re-lowering to get the HLO text."""
    import time
    from repro.launch.dryrun import build_jitted
    from repro.launch.mesh import make_production_mesh
    cfg0 = get_config(arch)
    if overrides:
        ARCHS[arch] = dataclasses.replace(cfg0, **overrides)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with jax.set_mesh(mesh):
            jitted, args = build_jitted(arch, shape, mesh)
            compiled = jitted.lower(*args).compile()
    finally:
        ARCHS[arch] = cfg0
    txt = compiled.as_text()
    print("--- top collectives (loop-expanded) ---")
    for r in roofline.collective_breakdown(txt):
        print(f"  {r['bytes']:12.3e} B  x{r['mult']:<4d} {r['kind']:<19s} "
              f"{r['shape']:<28s} in {r['comp'][:44]}")
    print("--- top HBM traffic in loops ---")
    for r in roofline.bytes_breakdown(txt):
        print(f"  {r['bytes']:12.3e} B  x{r['mult']:<4d} {r['line'][:95]}")


def _parse_overrides(pairs: list[str]) -> dict:
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                if v in ("True", "False"):
                    v = v == "True"
        out[k] = v
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--opt", nargs="*", default=[],
                    help="AdamWConfig overrides, e.g. grad_accum_steps=8")
    ap.add_argument("--deep", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    ov = _parse_overrides(a.set)
    oov = _parse_overrides(a.opt) or None
    profile(a.arch, a.shape, ov, multi_pod=a.multi_pod, opt_overrides=oov)
    if a.deep:
        profile_deep(a.arch, a.shape, ov, multi_pod=a.multi_pod)
