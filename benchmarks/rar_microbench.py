"""RAR vs fused all-reduce micro-benchmark (§3 / §Perf ablation).

Runs in a subprocess with 8 forced host devices so the parent process
keeps its single-device view.  Reports wall time per gradient exchange and
the HLO collective schedule of each variant (2(w-1) collective-permutes vs
one fused all-reduce) — the structural comparison that carries to TPU."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

_CODE = """
import time, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist.rar import ring_all_reduce

mesh = jax.make_mesh((8,), ("data",))
x = jnp.ones((8, 1 << 20), jnp.float32)          # 4 MiB per shard

def bench(fn, tag):
    jitted = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("data"),
                                   out_specs=P("data")))
    compiled = jitted.lower(x).compile()
    txt = compiled.as_text()
    permutes = txt.count("collective-permute(")
    allreduces = txt.count("all-reduce(")
    jitted(x).block_until_ready()
    t0 = time.time()
    for _ in range(20):
        out = jitted(x)
    out.block_until_ready()
    us = (time.time() - t0) / 20 * 1e6
    print(f"{tag},{us:.1f},permutes={permutes};allreduces={allreduces}")

bench(lambda x: ring_all_reduce(x, "data"), "rar_ring_2w-1_steps")
bench(lambda x: jax.lax.psum(x, "data"), "xla_fused_allreduce")
"""


def run(verbose: bool = True) -> list[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CODE)],
                         capture_output=True, text=True, env=env)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    lines = [l for l in out.stdout.splitlines() if "," in l]
    if verbose:
        for l in lines:
            print("  " + l)
    return lines


if __name__ == "__main__":
    run()
