"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

from repro.core import (philly_cluster, philly_workload, simulate, sjf_bco,
                        first_fit, list_scheduling, random_policy)

POLICIES = {
    "SJF-BCO": sjf_bco,
    "FF": first_fit,
    "LS": list_scheduling,
    "RAND": random_policy,
}


def run_policy(name: str, cluster, jobs, horizon: int):
    t0 = time.time()
    sched = POLICIES[name](cluster, jobs, horizon)
    sim = simulate(cluster, jobs, sched.assignment)
    return {
        "policy": name,
        "makespan": sim.makespan,
        "avg_jct": sim.avg_jct,
        "peak_contention": sim.peak_contention,
        "utilization": sim.utilization,
        "sched_time_s": time.time() - t0,
        "schedule": sched,
        "sim": sim,
    }


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
