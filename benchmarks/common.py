"""Shared helpers for the paper-figure benchmarks.

All figure drivers go through the policy registry: ``run_policy`` builds a
:class:`~repro.core.api.ScheduleRequest`, resolves the policy by registry
name and simulates the result.
"""
from __future__ import annotations

import time

from repro.core import ScheduleRequest, get_policy, simulate
from repro.core.jobs import PHILLY_MIX

# Display name -> registry name for the §7 figures.
POLICIES = {
    "SJF-BCO": "sjf-bco",
    "FF": "ff",
    "LS": "ls",
    "RAND": "rand",
}


def mix_for(total: int) -> tuple[tuple[int, int], ...]:
    """Scale the §7 Philly mix (160 jobs) to ``total`` jobs, preserving the
    job-size shares; the remainder lands on the largest fractional parts."""
    base = sum(c for _, c in PHILLY_MIX)
    exact = [(g, total * c / base) for g, c in PHILLY_MIX]
    counts = [int(x) for _, x in exact]
    order = sorted(range(len(exact)),
                   key=lambda i: exact[i][1] - counts[i], reverse=True)
    for i in order[: total - sum(counts)]:
        counts[i] += 1
    return tuple((g, c) for (g, _), c in zip(exact, counts) if c > 0)


def run_policy(name: str, cluster, jobs, horizon: int,
               params: dict | None = None, engine: str | None = None):
    """``engine`` picks the contention-model engine for the policy and the
    simulation (None = the repo default, "incremental"; all engines give
    identical results, only speed differs)."""
    policy = get_policy(POLICIES.get(name, name))
    params = dict(params or {})
    if engine is not None:
        params["engine"] = engine
    request = ScheduleRequest(cluster=cluster, jobs=list(jobs),
                              horizon=horizon, params=params)
    t0 = time.time()
    sched = policy(request)
    sim = simulate(cluster, jobs, sched.assignment, engine=engine)
    return {
        "policy": name,
        "makespan": sim.makespan,
        "avg_jct": sim.avg_jct,
        "peak_contention": sim.peak_contention,
        "utilization": sim.utilization,
        "sched_time_s": time.time() - t0,
        "schedule": sched,
        "sim": sim,
    }


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
