"""Sustained-arrival throughput benchmark for the scheduler service.

Drives :class:`repro.service.SchedulerService` with Poisson and burst
submission traffic at |J| in {256, 1024, 4096} (``--quick``: {64, 256})
and reports scheduling throughput (decisions/sec over the chooser calls)
plus p50/p99 per-decision latency, the numbers an operator would watch on
a live daemon.  A second section prices journal durability: the same
trace against the in-memory store vs the stdlib-sqlite write-ahead store
(appends/sec and the end-to-end slowdown).

``--quick`` doubles as CI's correctness smoke with hard asserts, not
report fields:

  * the daemon's drained schedule is bit-identical (assignment, est
    starts/finishes) to a direct ``schedule_arrivals`` run -- i.e. the
    one-shot policy call -- on the same trace, and
  * it stays bit-identical after a simulated crash (journal truncated
    mid-stream, daemon recovered by replay, remaining jobs resubmitted).

Usage::

    PYTHONPATH=src python benchmarks/bench_service.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import ScheduleRequest, get_policy, philly_cluster, \
    philly_workload
from repro.service import (Daemon, QueueManager, SchedulerService,
                           SubmitRequest, TenantConfig)

try:                                    # run as a module: -m benchmarks....
    from benchmarks.common import mix_for
except ImportError:                     # run as a script from benchmarks/
    from common import mix_for

HORIZON = 10**6                         # open-ended stream: budget = horizon


def _trace(n_jobs: int, traffic: str, seed: int):
    """A |J|-job Philly-mix submission trace under the given traffic."""
    cluster = philly_cluster(max(20, n_jobs // 16), seed=seed)
    jobs = philly_workload(seed=seed, mix=mix_for(n_jobs))
    rng = np.random.default_rng(seed)
    if traffic == "poisson":
        arrivals = np.floor(np.cumsum(
            rng.exponential(2.0, size=len(jobs)))).astype(np.int64)
    elif traffic == "burst":
        # waves of 32 simultaneous submissions, long idle gaps between
        wave = np.repeat(np.arange((len(jobs) + 31) // 32), 32)[:len(jobs)]
        arrivals = (wave * 64).astype(np.int64)
    else:
        raise ValueError(traffic)
    return cluster, jobs, arrivals


def _same_schedule(a, b) -> bool:
    return bool(np.array_equal(a.est_start, b.est_start)
                and np.array_equal(a.est_finish, b.est_finish)
                and len(a.assignment) == len(b.assignment)
                and all(ja == jb and np.array_equal(ga, gb)
                        for (ja, ga), (jb, gb) in zip(a.assignment,
                                                      b.assignment)))


def _drive(cluster, jobs, arrivals, **svc_kwargs):
    """Submit the whole trace, drain, return (service, schedule, wall)."""
    svc = SchedulerService(cluster, policy="sjf-bco", horizon=HORIZON,
                           **svc_kwargs)
    t0 = time.perf_counter()
    for job, arrival in zip(jobs, arrivals):
        svc.submit(SubmitRequest(job, int(arrival)))
    schedule, _ = svc.drain()
    wall = time.perf_counter() - t0
    return svc, schedule, wall


def bench_traffic(n_jobs: int, traffic: str, seed: int = 1) -> dict:
    """Throughput + decision-latency percentiles for one traffic shape."""
    cluster, jobs, arrivals = _trace(n_jobs, traffic, seed)
    svc, schedule, wall = _drive(cluster, jobs, arrivals)
    lat = np.asarray(svc.daemon.decision_latencies)
    placed = len(schedule.assignment)
    return {
        "J": n_jobs,
        "traffic": traffic,
        "placed": placed,
        "rounds": svc.daemon.rounds,
        "wall_s": round(wall, 4),
        "decisions_per_sec": round(placed / max(1e-9, lat.sum()), 1),
        "p50_decision_ms": round(float(np.percentile(lat, 50)) * 1e3, 4),
        "p99_decision_ms": round(float(np.percentile(lat, 99)) * 1e3, 4),
        "max_decision_ms": round(float(lat.max()) * 1e3, 4),
    }


def bench_stores(n_jobs: int, seed: int = 1) -> dict:
    """Journal-durability cost: in-memory vs sqlite write-ahead store."""
    cluster, jobs, arrivals = _trace(n_jobs, "poisson", seed)
    _, mem_sched, mem_wall = _drive(cluster, jobs, arrivals)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "journal.db")
        svc, sq_sched, sq_wall = _drive(cluster, jobs, arrivals,
                                        store_path=path)
        entries = len(svc.daemon.store)
        svc.close()
    assert _same_schedule(mem_sched, sq_sched), \
        "sqlite-backed daemon diverged from the in-memory one"
    return {
        "J": n_jobs,
        "journal_entries": entries,
        "memory_wall_s": round(mem_wall, 4),
        "sqlite_wall_s": round(sq_wall, 4),
        "sqlite_appends_per_sec": round(entries / max(1e-9, sq_wall), 1),
        "durability_overhead": round(sq_wall / max(1e-9, mem_wall), 2),
    }


def smoke_identity(n_jobs: int, seed: int = 1) -> dict:
    """--quick hard asserts: daemon == schedule_arrivals, also across a
    simulated crash/recovery."""
    cluster, jobs, arrivals = _trace(n_jobs, "poisson", seed)
    ref = get_policy("sjf-bco")(ScheduleRequest(
        cluster, list(jobs), arrivals=arrivals, horizon=HORIZON))
    svc, schedule, _ = _drive(cluster, jobs, arrivals)
    assert _same_schedule(ref, schedule), \
        "daemon path diverged from schedule_arrivals"

    # crash: truncate the journal to ~60% and recover by replay
    store = svc.daemon.store
    snap = store.prefix(int(len(store) * 0.6))
    replayed = len(snap)
    daemon = Daemon.recover(cluster, snap,
                            QueueManager(TenantConfig("sjf-bco")),
                            horizon=HORIZON)
    for job, arrival in list(zip(jobs, arrivals))[len(daemon.jobs):]:
        daemon.admit(job, int(arrival))
    recovered, _ = daemon.drain()
    assert _same_schedule(ref, recovered), \
        "recovered daemon diverged from schedule_arrivals"
    return {"J": n_jobs, "journal_entries": len(store),
            "replayed_entries": replayed,
            "identical_to_schedule_arrivals": True,
            "identical_after_recovery": True}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small sizes + identity asserts")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args()

    sizes = [64, 256] if args.quick else [256, 1024, 4096]
    report = {"bench": "service-throughput", "quick": args.quick,
              "traffic": [], "stores": [], "identity": []}
    for n in sizes:
        for traffic in ("poisson", "burst"):
            row = bench_traffic(n, traffic)
            report["traffic"].append(row)
            print(f"|J|={n:5d} {traffic:8s}  {row['decisions_per_sec']:9.1f}"
                  f" dec/s  p50 {row['p50_decision_ms']:.3f}ms"
                  f"  p99 {row['p99_decision_ms']:.3f}ms"
                  f"  rounds={row['rounds']}")
    store_sizes = sizes[:1] if args.quick else sizes[:2]
    for n in store_sizes:
        row = bench_stores(n)
        report["stores"].append(row)
        print(f"stores |J|={n:5d}  memory {row['memory_wall_s']:.3f}s"
              f"  sqlite {row['sqlite_wall_s']:.3f}s"
              f"  ({row['sqlite_appends_per_sec']:.0f} appends/s,"
              f" x{row['durability_overhead']:.2f})")
    row = smoke_identity(sizes[0])
    report["identity"].append(row)
    print(f"identity |J|={row['J']}  one-shot: ok   after recovery of"
          f" {row['replayed_entries']}/{row['journal_entries']}"
          f" journal entries: ok")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
