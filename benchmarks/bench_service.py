"""Sustained-arrival throughput benchmark for the scheduler service.

Drives :class:`repro.service.SchedulerService` with Poisson and burst
submission traffic at |J| in {256, 1024, 4096} (``--quick``: {64, 256})
and reports scheduling throughput (decisions/sec over the chooser calls)
plus p50/p99 per-decision latency, the numbers an operator would watch on
a live daemon.  A second section prices journal durability: the same
trace against the in-memory store vs the stdlib-sqlite write-ahead store
(appends/sec and the end-to-end slowdown).  A third section prices the
``feedback="actual"`` repricing loop (completions pulled back into the
placement clocks via ``observe_finish``) against the default
``"estimate"`` mode on the same trace.

``--quick`` doubles as CI's correctness smoke with hard asserts, not
report fields:

  * the daemon's drained schedule is bit-identical (assignment, est
    starts/finishes) to a direct ``schedule_arrivals`` run -- i.e. the
    one-shot policy call -- on the same trace, and
  * it stays bit-identical after a simulated crash (journal truncated
    mid-stream, daemon recovered by replay, remaining jobs resubmitted).

Usage::

    PYTHONPATH=src python benchmarks/bench_service.py [--quick] [--out F]
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import ScheduleRequest, get_policy
from repro.service import (Daemon, QueueManager, SchedulerService,
                           SubmitRequest, TenantConfig)

try:                                    # run as a module: -m benchmarks....
    from benchmarks._bench_util import (make_parser, philly_case,
                                        same_schedule, write_report)
except ImportError:                     # run as a script from benchmarks/
    from _bench_util import (make_parser, philly_case, same_schedule,
                             write_report)

HORIZON = 10**6                         # open-ended stream: budget = horizon


def _trace(n_jobs: int, traffic: str, seed: int):
    """A |J|-job Philly-mix submission trace under the given traffic."""
    cluster, jobs = philly_case(n_jobs, seed=seed,
                                servers=max(20, n_jobs // 16))
    rng = np.random.default_rng(seed)
    if traffic == "poisson":
        arrivals = np.floor(np.cumsum(
            rng.exponential(2.0, size=len(jobs)))).astype(np.int64)
    elif traffic == "burst":
        # waves of 32 simultaneous submissions, long idle gaps between
        wave = np.repeat(np.arange((len(jobs) + 31) // 32), 32)[:len(jobs)]
        arrivals = (wave * 64).astype(np.int64)
    else:
        raise ValueError(traffic)
    return cluster, jobs, arrivals


def _drive(cluster, jobs, arrivals, **svc_kwargs):
    """Submit the whole trace, drain; returns (service, schedule, sim,
    wall seconds)."""
    svc = SchedulerService(cluster, policy="sjf-bco", horizon=HORIZON,
                           **svc_kwargs)
    t0 = time.perf_counter()
    for job, arrival in zip(jobs, arrivals):
        svc.submit(SubmitRequest(job, int(arrival)))
    schedule, sim = svc.drain()
    wall = time.perf_counter() - t0
    return svc, schedule, sim, wall


def bench_traffic(n_jobs: int, traffic: str, seed: int = 1) -> dict:
    """Throughput + decision-latency percentiles for one traffic shape."""
    cluster, jobs, arrivals = _trace(n_jobs, traffic, seed)
    svc, schedule, _, wall = _drive(cluster, jobs, arrivals)
    lat = np.asarray(svc.daemon.decision_latencies)
    placed = len(schedule.assignment)
    return {
        "J": n_jobs,
        "traffic": traffic,
        "placed": placed,
        "rounds": svc.daemon.rounds,
        "wall_s": round(wall, 4),
        "decisions_per_sec": round(placed / max(1e-9, lat.sum()), 1),
        "p50_decision_ms": round(float(np.percentile(lat, 50)) * 1e3, 4),
        "p99_decision_ms": round(float(np.percentile(lat, 99)) * 1e3, 4),
        "max_decision_ms": round(float(lat.max()) * 1e3, 4),
    }


def bench_stores(n_jobs: int, seed: int = 1) -> dict:
    """Journal-durability cost: in-memory vs sqlite write-ahead store."""
    cluster, jobs, arrivals = _trace(n_jobs, "poisson", seed)
    _, mem_sched, _, mem_wall = _drive(cluster, jobs, arrivals)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "journal.db")
        svc, sq_sched, _, sq_wall = _drive(cluster, jobs, arrivals,
                                           store_path=path)
        entries = len(svc.daemon.store)
        svc.close()
    assert same_schedule(mem_sched, sq_sched), \
        "sqlite-backed daemon diverged from the in-memory one"
    return {
        "J": n_jobs,
        "journal_entries": entries,
        "memory_wall_s": round(mem_wall, 4),
        "sqlite_wall_s": round(sq_wall, 4),
        "sqlite_appends_per_sec": round(entries / max(1e-9, sq_wall), 1),
        "durability_overhead": round(sq_wall / max(1e-9, mem_wall), 2),
    }


def bench_feedback(n_jobs: int, seed: int = 1) -> dict:
    """Price the ``feedback="actual"`` repricing loop vs ``"estimate"``.

    Both modes drain the same Poisson trace.  ``"actual"`` runs the
    monitor every round and pulls each observed completion back into the
    placement clocks (:meth:`PlacementState.observe_finish`), so later
    decisions see real finishes instead of pessimistic estimates -- the
    row records what that buys (placements moved, estimate error) and
    what it costs (wall slowdown)."""
    cluster, jobs, arrivals = _trace(n_jobs, "poisson", seed)
    out = {}
    for mode in ("estimate", "actual"):
        svc, schedule, sim, wall = _drive(cluster, jobs, arrivals,
                                          feedback=mode)
        placed = len(schedule.assignment)
        # Drained runs must place and complete every submitted job.
        assert placed == len(jobs), (mode, placed, len(jobs))
        assert int((sim.finish >= 0).sum()) == len(jobs), \
            f"{mode}: not all jobs completed in simulation"
        out[mode] = {"schedule": schedule, "sim": sim, "wall": wall,
                     "rounds": svc.daemon.rounds}
    est, act = out["estimate"], out["actual"]
    gpus = {mode: dict(out[mode]["schedule"].assignment)
            for mode in ("estimate", "actual")}
    moved = sum(1 for jid in gpus["estimate"]
                if not np.array_equal(gpus["estimate"][jid],
                                      gpus["actual"][jid]))
    row = {"J": n_jobs}
    for mode in ("estimate", "actual"):
        sim = out[mode]["sim"]
        row[mode] = {
            "wall_s": round(out[mode]["wall"], 4),
            "rounds": out[mode]["rounds"],
            "est_makespan": out[mode]["schedule"].est_makespan,
            "sim_makespan": float(sim.finish.max()),
            "avg_jct": sim.avg_jct,
        }
    row["placements_moved_by_feedback"] = moved
    row["feedback_overhead"] = round(
        act["wall"] / max(1e-9, est["wall"]), 2)
    return row


def smoke_identity(n_jobs: int, seed: int = 1) -> dict:
    """--quick hard asserts: daemon == schedule_arrivals, also across a
    simulated crash/recovery."""
    cluster, jobs, arrivals = _trace(n_jobs, "poisson", seed)
    ref = get_policy("sjf-bco")(ScheduleRequest(
        cluster, list(jobs), arrivals=arrivals, horizon=HORIZON))
    svc, schedule, _, _ = _drive(cluster, jobs, arrivals)
    assert same_schedule(ref, schedule), \
        "daemon path diverged from schedule_arrivals"

    # crash: truncate the journal to ~60% and recover by replay
    store = svc.daemon.store
    snap = store.prefix(int(len(store) * 0.6))
    replayed = len(snap)
    daemon = Daemon.recover(cluster, snap,
                            QueueManager(TenantConfig("sjf-bco")),
                            horizon=HORIZON)
    for job, arrival in list(zip(jobs, arrivals))[len(daemon.jobs):]:
        daemon.admit(job, int(arrival))
    recovered, _ = daemon.drain()
    assert same_schedule(ref, recovered), \
        "recovered daemon diverged from schedule_arrivals"
    return {"J": n_jobs, "journal_entries": len(store),
            "replayed_entries": replayed,
            "identical_to_schedule_arrivals": True,
            "identical_after_recovery": True}


def main() -> None:
    args = make_parser(__doc__, "BENCH_service.json").parse_args()

    sizes = [64, 256] if args.quick else [256, 1024, 4096]
    report = {"bench": "service-throughput", "quick": args.quick,
              "traffic": [], "stores": [], "feedback": [], "identity": []}
    for n in sizes:
        for traffic in ("poisson", "burst"):
            row = bench_traffic(n, traffic)
            report["traffic"].append(row)
            print(f"|J|={n:5d} {traffic:8s}  {row['decisions_per_sec']:9.1f}"
                  f" dec/s  p50 {row['p50_decision_ms']:.3f}ms"
                  f"  p99 {row['p99_decision_ms']:.3f}ms"
                  f"  rounds={row['rounds']}")
    store_sizes = sizes[:1] if args.quick else sizes[:2]
    for n in store_sizes:
        row = bench_stores(n)
        report["stores"].append(row)
        print(f"stores |J|={n:5d}  memory {row['memory_wall_s']:.3f}s"
              f"  sqlite {row['sqlite_wall_s']:.3f}s"
              f"  ({row['sqlite_appends_per_sec']:.0f} appends/s,"
              f" x{row['durability_overhead']:.2f})")
    for n in store_sizes:
        row = bench_feedback(n)
        report["feedback"].append(row)
        print(f"feedback |J|={n:5d}  estimate {row['estimate']['wall_s']:.3f}s"
              f"  actual {row['actual']['wall_s']:.3f}s"
              f"  (x{row['feedback_overhead']:.2f},"
              f" {row['placements_moved_by_feedback']} placements moved)")
    row = smoke_identity(sizes[0])
    report["identity"].append(row)
    print(f"identity |J|={row['J']}  one-shot: ok   after recovery of"
          f" {row['replayed_entries']}/{row['journal_entries']}"
          f" journal entries: ok")

    write_report(report, args.out)


if __name__ == "__main__":
    main()
