"""Markdown link checker for the docs gate (CI `docs` job).

Scans the given markdown files (default: README.md + docs/*.md) for
relative links/images and fails when a target file does not exist in the
repo.  External (http/https/mailto) links and pure #anchors are skipped —
the gate is about the repo not drifting, not about the internet.

Usage:  python docs/check_links.py [file.md ...]
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check(md: pathlib.Path) -> list[str]:
    """Broken-link messages for one markdown file (empty = clean)."""
    errors = []
    for n, line in enumerate(md.read_text().splitlines(), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}:{n}: broken link "
                              f"-> {target}")
    return errors


def main(argv: list[str]) -> int:
    """Check argv paths (or the default doc set); exit code: 0 when clean,
    1 when any link is broken."""
    files = ([pathlib.Path(a) for a in argv] if argv else
             [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))])
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"missing file: {md}")
            continue
        errors.extend(check(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"[check_links] {len(files)} files, {len(errors)} broken links")
    return min(len(errors), 1)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
