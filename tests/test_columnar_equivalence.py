"""Columnar branch-vectorised placement vs the scalar oracle: bit-identity.

Mirrors ``tests/test_bisect_equivalence.py`` for the ``placement`` axis:
the :class:`~repro.core.columnar.ColumnarPlacement` engine must reproduce
the per-branch scalar walk decision-for-decision --

  * at the engine level: random clusters / jobs / theta ladders, every
    branch's survival, busy-time clocks, assignment and committed floats
    against an independent per-branch :func:`try_place` walk;
  * at the policy level: ``placement="columnar"`` vs ``"scalar"`` ends on
    the same (theta, kappa) and bit-equal schedules across policies,
    engines and bisect modes;
  * trivially for the policies with no columnar path (adaptive / rand /
    reserved): the param validates and both values coincide.

A hypothesis property sweep runs when hypothesis is installed (the CI
image may not ship it; the seeded numpy sweep below covers the same
space deterministically either way).
"""
import numpy as np
import pytest

from repro.core import (Cluster, Job, ScheduleRequest, get_policy,
                        philly_cluster, philly_workload)
from repro.core.api import (ColumnarPlacement, PlacementState, finalize,
                            nominal_rho, try_place)
from repro.core.sjf_bco import fa_ffp, lbsgf

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False


def _philly_case(seed, n_jobs=42, n_servers=8):
    cluster = philly_cluster(n_servers, seed=seed)
    mix = ((1, n_jobs // 3), (2, n_jobs // 6), (4, n_jobs // 4),
           (8, n_jobs // 6), (16, n_jobs // 12))
    jobs = philly_workload(seed=seed, mix=mix)
    return cluster, jobs


def _random_case(rng, max_servers=6):
    """A small random cluster + workload + theta ladder + kappa split."""
    caps = rng.choice([4, 8, 16], size=rng.integers(2, max_servers + 1))
    cluster = Cluster(tuple(int(c) for c in caps))
    n = int(rng.integers(4, 14))
    jobs = [Job(jid=j,
                num_gpus=int(rng.integers(1, min(cluster.num_gpus, 16) + 1)),
                iters=int(rng.integers(200, 4000)),
                grad_size=float(rng.uniform(0.5e-3, 2.0e-3)),
                batch=int(rng.integers(16, 64)),
                dt_fwd=float(rng.uniform(2.0e-4, 5.0e-4)),
                dt_bwd=float(rng.uniform(4.0e-3, 1.2e-2)))
            for j in range(n)]
    u = float(rng.uniform(1.0, 4.0))
    rho_noms = {j.jid: nominal_rho(cluster, j) for j in jobs}
    floor = max(rho_noms.values()) / u
    # An ascending ladder straddling the feasibility boundary: some
    # branches should die, some survive.
    thetas = sorted(float(floor * f)
                    for f in rng.uniform(0.3, 40.0, size=rng.integers(3, 9)))
    kappas = sorted({int(k) for k in
                     rng.choice([1, 2, 4, 8, 16], size=rng.integers(1, 4))})
    return cluster, jobs, u, rho_noms, thetas, kappas


def _assert_schedules_equal(a, b):
    assert a.theta == b.theta
    assert a.kappa == b.kappa
    assert a.est_makespan == b.est_makespan
    assert a.max_busy_time == b.max_busy_time
    assert len(a.assignment) == len(b.assignment)
    for (j1, g1), (j2, g2) in zip(a.assignment, b.assignment):
        assert j1 == j2
        assert np.array_equal(g1, g2)
    assert np.array_equal(a.est_start, b.est_start)
    assert np.array_equal(a.est_finish, b.est_finish)


def _check_columnar_vs_scalar_walk(cluster, jobs, u, rho_noms, thetas,
                                   kappas, engine):
    """Drive one ColumnarPlacement over the (theta, kappa) grid and an
    independent scalar try_place walk per branch; compare everything."""
    order = sorted(jobs, key=lambda j: (rho_noms[j.jid], j.jid))
    pairs = [(float(th), k) for th in thetas for k in kappas]
    col = ColumnarPlacement(cluster, [th for th, _ in pairs], jobs, u,
                            engine=engine)
    kappa_arr = np.asarray([k for _, k in pairs], dtype=np.int64)
    for job in order:
        picker_of = (job.num_gpus > kappa_arr).astype(np.int64)
        col.place(job, rho_noms[job.jid], (fa_ffp, lbsgf), picker_of)
        if not col.alive.any():
            break
    for b, (theta, kappa) in enumerate(pairs):
        state = PlacementState(cluster, engine=engine)
        ok = True
        for job in order:
            picker = fa_ffp if job.num_gpus <= kappa else lbsgf
            if not try_place(state, job, picker, rho_noms[job.jid], u,
                             theta):
                ok = False
                break
        assert bool(col.alive[b]) == ok, (b, theta, kappa)
        if not ok:
            assert col.result(b, theta, kappa, "x") is None
            continue
        row = int(col.row_of[b])
        assert np.array_equal(col.U[row], state.U), (b, theta, kappa)
        assert np.array_equal(col.R[row], state.R), (b, theta, kappa)
        _assert_schedules_equal(col.result(b, theta, kappa, "x"),
                                finalize(state, len(jobs), theta, kappa,
                                         "x"))


class TestColumnarEngineRandomSweep:
    """Random clusters / jobs / ladders, engine-level decision identity."""

    @pytest.mark.parametrize("seed", range(12))
    def test_random_case_matches_scalar_walk(self, seed):
        rng = np.random.default_rng(seed)
        cluster, jobs, u, rho_noms, thetas, kappas = _random_case(rng)
        engine = ("incremental", "batched", "reference")[seed % 3]
        _check_columnar_vs_scalar_walk(cluster, jobs, u, rho_noms, thetas,
                                       kappas, engine)


class TestColumnarPolicyEquivalence:
    @pytest.mark.parametrize("seed", range(2))
    @pytest.mark.parametrize("engine", ["incremental", "batched",
                                        "reference"])
    @pytest.mark.parametrize("bisect", ["speculative", "sequential"])
    def test_sjf_bco(self, seed, engine, bisect):
        cluster, jobs = _philly_case(seed)
        results = {}
        for placement in ("scalar", "columnar"):
            request = ScheduleRequest(
                cluster=cluster, jobs=jobs, horizon=2400,
                params={"engine": engine, "bisect": bisect,
                        "placement": placement})
            results[placement] = get_policy("sjf-bco")(request)
        _assert_schedules_equal(results["scalar"], results["columnar"])

    @pytest.mark.parametrize("seed", range(2))
    @pytest.mark.parametrize("policy", ["ff", "ls"])
    @pytest.mark.parametrize("bisect", ["speculative", "sequential"])
    def test_single_picker_policies(self, seed, policy, bisect):
        cluster, jobs = _philly_case(seed)
        results = {}
        for placement in ("scalar", "columnar"):
            request = ScheduleRequest(
                cluster=cluster, jobs=jobs, horizon=2400,
                params={"bisect": bisect, "placement": placement})
            results[placement] = get_policy(policy)(request)
        _assert_schedules_equal(results["scalar"], results["columnar"])

    @pytest.mark.parametrize("policy,params", [
        ("sjf-bco-adaptive", {}),
        ("rand", {"seed": 3}),
        ("reserved", {"reserved_fraction": 0.25}),
    ])
    def test_scalar_only_policies_accept_the_param(self, policy, params):
        """Policies with no columnar path still validate ``placement``
        and coincide trivially for both values."""
        cluster, jobs = _philly_case(1, n_jobs=24, n_servers=6)
        results = {}
        for placement in ("scalar", "columnar"):
            request = ScheduleRequest(
                cluster=cluster, jobs=jobs, horizon=2400,
                params={**params, "placement": placement})
            results[placement] = get_policy(policy)(request)
        _assert_schedules_equal(results["scalar"], results["columnar"])
        with pytest.raises(ValueError, match="placement"):
            get_policy(policy)(ScheduleRequest(
                cluster=cluster, jobs=jobs, horizon=2400,
                params={**params, "placement": "bogus"}))

    def test_warm_start_falls_back_to_scalar(self):
        """warm_start changes the search trajectory, so columnar must
        quietly fall back -- both placements give the warm result."""
        cluster, jobs = _philly_case(0, n_jobs=24, n_servers=6)
        results = {}
        for placement in ("scalar", "columnar"):
            request = ScheduleRequest(
                cluster=cluster, jobs=jobs, horizon=2400,
                params={"warm_start": True, "placement": placement})
            results[placement] = get_policy("sjf-bco")(request)
        _assert_schedules_equal(results["scalar"], results["columnar"])


class TestColumnarJitBackends:
    """The fused jit/Pallas backends vs the numpy walk: bit-identity
    under x64 across seeds x policies x hetero clusters, plus the
    no-retrace guard (the padded array program must not recompile as
    jobs stream through)."""

    @staticmethod
    def _force_device(monkeypatch):
        """Force every batch through the device program: without this the
        DISPATCH_MIN_ROWS gate routes short batches to the numpy pickers
        and the device path would go untested at test sizes."""
        import repro.kernels.placement as kp
        monkeypatch.setattr(kp, "DISPATCH_MIN_ROWS", 0)

    @staticmethod
    def _x64():
        jax = pytest.importorskip("jax")
        x64_was = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        return jax, x64_was

    def _hetero_case(self, seed, n_jobs=24, n_servers=6):
        import dataclasses
        base = philly_cluster(n_servers, seed=seed)
        rng = np.random.default_rng(1000 + seed)
        speeds = []
        for cap in base.capacities:
            tier = float(rng.choice([base.gpu_speed, base.gpu_speed / 4]))
            speeds += [tier] * cap
        links = tuple(
            (float(rng.choice([base.b_inter, base.b_inter * 0.5])),
             str(rng.choice(["shared", "isolated"])))
            for _ in range(base.num_servers))
        cluster = dataclasses.replace(base, gpu_speeds=tuple(speeds),
                                      links=links)
        assert cluster.is_heterogeneous
        mix = ((1, n_jobs // 3), (2, n_jobs // 6), (4, n_jobs // 4),
               (8, n_jobs // 6), (16, n_jobs // 12))
        return cluster, philly_workload(seed=seed, mix=mix)

    @pytest.mark.parametrize("seed", range(2))
    @pytest.mark.parametrize("policy", ["sjf-bco", "ff", "ls"])
    @pytest.mark.parametrize("hetero", [False, True])
    def test_jit_vs_eager_bit_identity(self, seed, policy, hetero,
                                       monkeypatch):
        """backend="jit" (fused XLA program + host rankings) equals the
        eager numpy walk AND the scalar oracle bit-for-bit."""
        jax, x64_was = self._x64()
        self._force_device(monkeypatch)
        try:
            if hetero:
                cluster, jobs = self._hetero_case(seed)
            else:
                cluster, jobs = _philly_case(seed, n_jobs=30, n_servers=6)
            results = {}
            for backend, placement in (("numpy", "columnar"),
                                       ("jit", "columnar"),
                                       ("numpy", "scalar")):
                request = ScheduleRequest(
                    cluster=cluster, jobs=jobs, horizon=2400,
                    params={"placement": placement,
                            "columnar_backend": backend})
                results[(backend, placement)] = get_policy(policy)(request)
            _assert_schedules_equal(results[("numpy", "columnar")],
                                    results[("jit", "columnar")])
            _assert_schedules_equal(results[("numpy", "scalar")],
                                    results[("jit", "columnar")])
        finally:
            jax.config.update("jax_enable_x64", x64_was)

    @pytest.mark.parametrize("seed,hetero", [(0, False), (1, True)])
    def test_kernel_vs_numpy_bit_identity(self, seed, hetero, monkeypatch):
        """backend="kernel" (Pallas pick/check/score, interpret mode on
        CPU) is bit-identical to the numpy walk under x64."""
        jax, x64_was = self._x64()
        self._force_device(monkeypatch)
        try:
            if hetero:
                cluster, jobs = self._hetero_case(seed, n_jobs=18)
            else:
                cluster, jobs = _philly_case(seed, n_jobs=18, n_servers=4)
            results = {}
            for backend in ("numpy", "kernel"):
                request = ScheduleRequest(
                    cluster=cluster, jobs=jobs, horizon=2400,
                    params={"placement": "columnar",
                            "columnar_backend": backend})
                results[backend] = get_policy("sjf-bco")(request)
            _assert_schedules_equal(results["numpy"], results["kernel"])
        finally:
            jax.config.update("jax_enable_x64", x64_was)

    def test_pick_orders_device_matches_numpy(self, monkeypatch):
        """Function-level fuzz: the fused pick/check program and the
        numpy fallback agree bitwise on every output (pools, counts,
        rankings, feasibility) across random clock states."""
        jax, x64_was = self._x64()
        import repro.kernels.placement as kp
        try:
            cluster, jobs = _philly_case(5, n_jobs=12, n_servers=6)
            N = cluster.num_gpus
            rng = np.random.default_rng(11)
            for trial in range(40):
                job = jobs[int(rng.integers(len(jobs)))]
                nw = int(rng.integers(1, 40))
                U = np.round(rng.uniform(0, 30, size=(nw, N)), 3)
                th_lo = np.sort(rng.uniform(5, 40, size=nw))
                th_hi = th_lo + rng.uniform(0, 10, size=nw)
                rho_u = rng.uniform(0.5, 20, size=nw)
                pid = rng.integers(0, 2, size=nw)
                outs = {}
                for rows, label in ((10**9, "numpy"), (0, "device")):
                    monkeypatch.setattr(kp, "DISPATCH_MIN_ROWS", rows)
                    outs[label] = kp.pick_orders(
                        cluster, U.copy(), th_lo, th_hi, rho_u, pid, job)
                for a, b in zip(outs["numpy"], outs["device"]):
                    assert np.array_equal(np.asarray(a), np.asarray(b)), \
                        f"trial {trial}"
        finally:
            jax.config.update("jax_enable_x64", x64_was)

    def test_no_retrace_across_jobs(self, monkeypatch):
        """Compile-count guard: the padded fixed-shape layout must hit
        the jit cache across jobs -- a fresh workload on the same
        cluster adds ZERO new compilations."""
        jax, x64_was = self._x64()
        self._force_device(monkeypatch)
        import repro.kernels.placement as kp
        try:
            cold = dict(kp.compile_counts())    # cumulative across session
            cluster, jobs = _philly_case(7, n_jobs=36, n_servers=6)
            request = ScheduleRequest(
                cluster=cluster, jobs=jobs, horizon=2400,
                params={"placement": "columnar", "columnar_backend": "jit"})
            get_policy("sjf-bco")(request)
            warm = dict(kp.compile_counts())
            # A padded program per power-of-two row bucket and static-arg
            # combination -- not per job, not per branch count.  Counts
            # are session-cumulative, so bound the delta from this run
            # (earlier warm cache entries make it smaller, never larger).
            assert warm["pick_orders"] - cold["pick_orders"] <= 16
            assert warm["score_probes"] - cold["score_probes"] <= 16
            assert warm["pick_orders"] > 0 and warm["score_probes"] > 0
            _, jobs2 = _philly_case(8, n_jobs=36, n_servers=6)
            request2 = ScheduleRequest(
                cluster=cluster, jobs=jobs2, horizon=2400,
                params={"placement": "columnar", "columnar_backend": "jit"})
            get_policy("sjf-bco")(request2)
            assert kp.compile_counts() == warm      # no retraces
        finally:
            jax.config.update("jax_enable_x64", x64_was)


if HAVE_HYPOTHESIS:                                 # pragma: no branch
    class TestColumnarHypothesis:
        @settings(max_examples=25, deadline=None)
        @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
        def test_property_random_sweep(self, seed):
            rng = np.random.default_rng(seed)
            cluster, jobs, u, rho_noms, thetas, kappas = _random_case(rng)
            engine = ("incremental", "batched", "reference")[seed % 3]
            _check_columnar_vs_scalar_walk(cluster, jobs, u, rho_noms,
                                           thetas, kappas, engine)
