"""Unit tests for the Eq. (6)-(8) analytical model (paper §4.1)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Cluster, Job, contention_level, degradation, evaluate,
                        tau_bounds)

CL = Cluster(capacities=(4, 4, 4))


def _job(jid, gpus, iters=1000, m=1e-3, M=32, dfw=3e-4, dbw=8e-3):
    return Job(jid=jid, num_gpus=gpus, iters=iters, grad_size=m, batch=M,
               dt_fwd=dfw, dt_bwd=dbw)


class TestContentionLevel:
    def test_fig2a_colocated_jobs_no_contention(self):
        # Fig. 2(a): each job fully inside one server -> nobody straddles.
        Y = np.array([[4, 0, 0], [0, 4, 0]])
        p = contention_level(Y, np.array([4, 4]))
        assert p.tolist() == [0, 0]

    def test_fig2b_straddling_jobs_contend(self):
        # Fig. 2(b): both jobs split across servers 0 and 1 -> p = 2 each.
        Y = np.array([[2, 2, 0], [2, 2, 0]])
        p = contention_level(Y, np.array([4, 4]))
        assert p.tolist() == [2, 2]

    def test_single_straddler_contends_with_itself_only(self):
        Y = np.array([[2, 2, 0], [0, 0, 4]])
        p = contention_level(Y, np.array([4, 4]))
        assert p.tolist() == [1, 0]

    def test_max_over_servers(self):
        # Job 0 straddles all three servers; server 1 also hosts straddling
        # job 1 and server 2 hosts straddling jobs 1.. -> p0 is the max count.
        Y = np.array([[1, 1, 1], [0, 2, 1], [0, 1, 2]])
        G = np.array([3, 3, 3])
        p = contention_level(Y, G)
        assert p[0] == 3  # servers 1/2 each host 3 straddlers
        assert p[1] == 3 and p[2] == 3


class TestDegradation:
    def test_no_contention_is_identity(self):
        assert degradation(0.5, np.array([1.0])) == pytest.approx(1.0)

    @given(st.floats(0.0, 1.0), st.floats(1.0, 64.0), st.floats(0.0, 10.0))
    def test_monotone_increasing(self, alpha, k, dk):
        f1 = degradation(alpha, np.array([k]))
        f2 = degradation(alpha, np.array([k + dk]))
        assert f2 >= f1

    def test_clamped_below_one_contender(self):
        # k = xi1 * p may fall below 1 for p = 1; f must not "boost" bandwidth.
        assert degradation(0.3, np.array([0.5])) == pytest.approx(1.0)


class TestIterModel:
    def test_colocated_uses_intra_bandwidth(self):
        jobs = [_job(0, 4), _job(1, 4)]
        Y = np.array([[4, 0, 0], [0, 4, 0]])
        m = evaluate(CL, jobs, Y)
        assert np.allclose(m.bandwidth, CL.b_intra)

    def test_straddling_uses_degraded_inter_bandwidth(self):
        jobs = [_job(0, 4), _job(1, 4)]
        Y = np.array([[2, 2, 0], [2, 2, 0]])
        m = evaluate(CL, jobs, Y)
        k = max(1.0, CL.xi1 * 2)
        expected = CL.b_inter / (k + CL.alpha * (k - 1))
        assert np.allclose(m.bandwidth, expected)

    def test_single_gpu_job_has_no_exchange(self):
        jobs = [_job(0, 1)]
        Y = np.array([[1, 0, 0]])
        m = evaluate(CL, jobs, Y)
        assert m.exchange[0] == 0.0 and m.reduce[0] == 0.0
        assert m.tau[0] == pytest.approx(CL.xi2 + 3e-4 * 32 + 8e-3)

    def test_overhead_linear_in_servers(self):
        jobs = [_job(0, 3)]
        for n_srv, Y in [(1, [[3, 0, 0]]), (2, [[2, 1, 0]]), (3, [[1, 1, 1]])]:
            m = evaluate(CL, jobs, np.array(Y))
            assert m.gamma[0] == pytest.approx(CL.xi2 * n_srv)

    def test_eq8_composition(self):
        jobs = [_job(0, 4, m=2e-3)]
        Y = np.array([[2, 2, 0]])
        m = evaluate(CL, jobs, Y)
        share = (2e-3 / 4) * 3
        assert m.exchange[0] == pytest.approx(2 * share / m.bandwidth[0])
        assert m.reduce[0] == pytest.approx(share / CL.gpu_speed)
        assert m.tau[0] == pytest.approx(
            m.exchange[0] + m.reduce[0] + m.gamma[0] + m.compute[0])

    def test_placement_must_cover_job(self):
        with pytest.raises(ValueError):
            evaluate(CL, [_job(0, 4)], np.array([[2, 0, 0]]))

    def test_rar_bandwidth_optimality(self):
        """§3: per-worker exchanged volume 2m(w-1)/w is bounded by 2m and
        asymptotically independent of w (monotone, converging)."""
        m = 1.0
        vols = [2 * m * (w - 1) / w for w in range(2, 129)]
        assert all(v < 2 * m for v in vols)
        assert np.all(np.diff(vols) > 0)
        assert vols[-1] - vols[-2] < 1e-3

    @given(st.integers(1, 12), st.integers(0, 2), st.data())
    @settings(max_examples=50, deadline=None)
    def test_tau_within_bounds(self, gpus, extra_jobs, data):
        """Property: any placement's tau lies within the §5-1 bracket."""
        job = _job(0, gpus)
        jobs = [job]
        placements = [_random_placement(data, gpus)]
        for e in range(extra_jobs):
            g = data.draw(st.integers(1, 6))
            jobs.append(_job(e + 1, g))
            placements.append(_random_placement(data, g))
        Y = np.array(placements)
        m = evaluate(CL, jobs, Y)
        lo, hi = tau_bounds(CL, job)
        assert lo - 1e-9 <= m.tau[0] <= hi + 1e-9


def _random_placement(data, gpus):
    """Random split of `gpus` across the 3 servers (capacity ignored: the
    analytical model itself doesn't enforce Eq. (2); schedulers do)."""
    row = [0, 0, 0]
    for _ in range(gpus):
        row[data.draw(st.integers(0, 2))] += 1
    return row
