"""The speculative fast paths vs their sequential oracles: bit-identity.

Mirrors ``tests/test_simulator_equivalence.py`` for the two speculative
axes this repo added on top of the engine/readiness ones:

  * **Theta bisection** (``params={"bisect": "speculative"}``, the
    default): probe-ladder rounds scored through shared copy-on-write
    placement lineages must end on exactly the sequential Alg. 1
    bisection's final (theta, kappa) and placements -- across seeds,
    contention engines and policies (SJF-BCO's kappa sweep, FF/LS's
    single-picker attempts).
  * **Multi-window stepping** (``simulate(..., stepping="multi")``, the
    default under tracked readiness): the vectorised completion-stage
    ladders must reproduce the single-window oracle's SimEvent stream
    event-for-event, across seeds, engines, arrival patterns and horizon
    cutoffs.
"""
import numpy as np
import pytest

from repro.core import (ScheduleRequest, get_policy, philly_cluster,
                        philly_workload, simulate)
from repro.core.api import (PlacementState, SharedState, probe_thetas,
                            try_place_group)
from repro.core.sjf_bco import fa_ffp


def _philly_case(seed, n_jobs=48, n_servers=10):
    cluster = philly_cluster(n_servers, seed=seed)
    mix = ((1, n_jobs // 3), (2, n_jobs // 6), (4, n_jobs // 4),
           (8, n_jobs // 6), (16, n_jobs // 12))
    jobs = philly_workload(seed=seed, mix=mix)
    return cluster, jobs


def _assert_schedules_equal(a, b):
    assert a.theta == b.theta
    assert a.kappa == b.kappa
    assert a.est_makespan == b.est_makespan
    assert a.max_busy_time == b.max_busy_time
    assert len(a.assignment) == len(b.assignment)
    for (j1, g1), (j2, g2) in zip(a.assignment, b.assignment):
        assert j1 == j2
        assert np.array_equal(g1, g2)
    assert np.array_equal(a.est_start, b.est_start)
    assert np.array_equal(a.est_finish, b.est_finish)


class TestSpeculativeBisection:
    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("engine", ["incremental", "batched",
                                        "reference"])
    def test_sjf_bco_matches_sequential(self, seed, engine):
        cluster, jobs = _philly_case(seed)
        results = {}
        for mode in ("sequential", "speculative"):
            request = ScheduleRequest(
                cluster=cluster, jobs=jobs, horizon=2400,
                params={"engine": engine, "bisect": mode})
            results[mode] = get_policy("sjf-bco")(request)
        _assert_schedules_equal(results["sequential"],
                                results["speculative"])

    @pytest.mark.parametrize("seed", range(3))
    @pytest.mark.parametrize("policy", ["ff", "ls"])
    def test_baselines_match_sequential(self, seed, policy):
        cluster, jobs = _philly_case(seed, n_jobs=36)
        results = {}
        for mode in ("sequential", "speculative"):
            request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                      horizon=2400,
                                      params={"bisect": mode})
            results[mode] = get_policy(policy)(request)
        _assert_schedules_equal(results["sequential"],
                                results["speculative"])

    @pytest.mark.parametrize("levels", [2, 3, 4, 6, 8])
    def test_levels_do_not_change_result(self, levels):
        cluster, jobs = _philly_case(1)
        base = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400,
            params={"bisect": "sequential"}))
        spec = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400,
            params={"bisect": "speculative", "bisect_levels": levels}))
        _assert_schedules_equal(base, spec)

    def test_sequential_sweep_falls_back_to_sequential_bisect(self):
        """The speculative sweep needs the batched-sweep structure; with
        sweep="sequential" the bisection silently runs sequentially and
        the result still matches."""
        cluster, jobs = _philly_case(2)
        a = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400,
            params={"sweep": "sequential"}))
        b = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400,
            params={"sweep": "sequential", "bisect": "sequential"}))
        _assert_schedules_equal(a, b)

    def test_unknown_bisect_mode_rejected(self):
        cluster, jobs = _philly_case(0, n_jobs=12, n_servers=4)
        for policy in ("sjf-bco", "ff"):
            with pytest.raises(ValueError, match="bisect"):
                get_policy(policy)(ScheduleRequest(
                    cluster=cluster, jobs=jobs,
                    params={"bisect": "magic"}))

    def test_probe_thetas_is_the_feasible_descent(self):
        """The ladder is exactly the theta sequence of consecutive
        feasible-tightening bisection steps."""
        left, right = 1.0, 1200.0
        ladder = probe_thetas(left, right, 4)
        lo, hi = left, right
        for theta in ladder:
            assert theta == 0.5 * (lo + hi)
            hi = theta - 1.0          # the "feasible" update
        assert ladder == sorted(ladder, reverse=True)
        # the cutoff prunes the tail but never the bracket midpoint
        cut = probe_thetas(left, right, 4, cutoff=right)
        assert cut == [0.5 * (left + right)]

    def test_try_place_group_requires_theta_pool_picker(self):
        cluster, jobs = _philly_case(0, n_jobs=12, n_servers=4)

        def rogue_picker(state, job, rho_nom, u, theta):
            return np.arange(job.num_gpus)

        shared = SharedState(PlacementState(cluster))
        with pytest.raises(ValueError, match="theta_pool"):
            try_place_group(np.asarray([10.0, 20.0]), shared, jobs[0],
                            rogue_picker, 1.0, 1.5)

    def test_try_place_group_covers_and_matches_try_place(self):
        """Group placement of one job over a theta range returns a
        partition of the thetas, each subgroup deciding exactly like the
        scalar try_place at that theta."""
        from repro.core.api import nominal_rho, try_place
        cluster, jobs = _philly_case(3, n_jobs=24, n_servers=4)
        jobs_sorted = sorted(jobs, key=lambda j: (j.num_gpus, j.jid))
        u = 1.5
        # a state with some load so feasibility actually varies with theta
        base = PlacementState(cluster)
        for job in jobs_sorted[:10]:
            try_place(base, job, fa_ffp, nominal_rho(cluster, job), u, 500.0)
        job = jobs_sorted[10]
        rho_nom = nominal_rho(cluster, job)
        thetas = np.linspace(5.0, 400.0, 23)
        out = try_place_group(thetas, SharedState(base.clone()), job,
                              fa_ffp, rho_nom, u)
        covered = np.concatenate([sub for sub, _, _ in out])
        assert sorted(covered.tolist()) == sorted(thetas.tolist())
        for sub, holder, ok in out:
            for th in sub:
                solo = base.clone()
                assert try_place(solo, job, fa_ffp, rho_nom, u,
                                 float(th)) == ok
                if ok:
                    jid, gpus = holder.state.assignment[-1]
                    assert jid == job.jid
                    assert np.array_equal(gpus, solo.assignment[-1][1])

    def test_cow_clone_isolates_branches(self):
        """Committing to a clone must not leak into the original's
        straddle-finish structures (copy-on-write correctness)."""
        from repro.core.api import nominal_rho, try_place
        cluster, jobs = _philly_case(4, n_jobs=24, n_servers=4)
        jobs_sorted = sorted(jobs, key=lambda j: (j.num_gpus, j.jid))
        u = 1.5
        state = PlacementState(cluster)
        for job in jobs_sorted[:8]:
            assert try_place(state, job, fa_ffp,
                             nominal_rho(cluster, job), u, 800.0)
        frozen = [list(f) for f in state._straddle_fin]
        clone = state.clone()
        for job in jobs_sorted[8:16]:
            try_place(clone, job, fa_ffp, nominal_rho(cluster, job), u, 800.0)
        assert [list(f) for f in state._straddle_fin] == frozen
        # and the original can still commit independently afterwards
        job = jobs_sorted[16]
        assert try_place(state, job, fa_ffp, nominal_rho(cluster, job),
                         u, 800.0)
        assert [list(f) for f in clone._straddle_fin] != \
            [list(f) for f in state._straddle_fin] or \
            clone.assignment != state.assignment


def _assert_sims_equal(a, b):
    assert a.events == b.events
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.finish, b.finish)
    assert a.makespan == b.makespan
    assert a.avg_jct == b.avg_jct
    assert a.completed == b.completed
    assert a.horizon_hit == b.horizon_hit
    assert a.peak_contention == b.peak_contention
    assert a.busy_gpu_slots == b.busy_gpu_slots
    assert a.total_gpu_slots == b.total_gpu_slots


class TestMultiWindowStepping:
    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("engine", ["incremental", "batched"])
    def test_batch_schedules_match_event_for_event(self, seed, engine):
        cluster, jobs = _philly_case(seed)
        sched = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400))
        multi = simulate(cluster, jobs, sched.assignment, engine=engine,
                         stepping="multi")
        single = simulate(cluster, jobs, sched.assignment, engine=engine,
                          stepping="single")
        _assert_sims_equal(multi, single)
        assert multi.completed == len(jobs)

    @pytest.mark.parametrize("seed", range(4))
    def test_arrival_schedules_match_event_for_event(self, seed):
        cluster, jobs = _philly_case(seed)
        rng = np.random.default_rng(300 + seed)
        arrivals = rng.integers(0, 400, size=len(jobs)).astype(np.int64)
        sched = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, arrivals=arrivals, horizon=10**6))
        multi = simulate(cluster, jobs, sched.assignment,
                         arrivals=arrivals, stepping="multi")
        single = simulate(cluster, jobs, sched.assignment,
                          arrivals=arrivals, stepping="single")
        _assert_sims_equal(multi, single)
        assert np.all(multi.start >= arrivals)

    @pytest.mark.parametrize("seed", range(4))
    def test_random_contended_placements_match(self, seed):
        """Seeded random GPU sets: heavy straddling, deep FIFO queues,
        frequent ladder invalidations and mispredictions."""
        cluster, jobs = _philly_case(seed, n_jobs=60, n_servers=6)
        rng = np.random.default_rng(400 + seed)
        asg = [(j.jid, rng.choice(cluster.num_gpus, size=j.num_gpus,
                                  replace=False)) for j in jobs]
        multi = simulate(cluster, jobs, asg, stepping="multi")
        single = simulate(cluster, jobs, asg, stepping="single")
        rescan = simulate(cluster, jobs, asg, readiness="rescan")
        _assert_sims_equal(multi, single)
        _assert_sims_equal(multi, rescan)

    @pytest.mark.parametrize("horizon", [1, 37, 250, 800])
    def test_horizon_hits_match(self, horizon):
        cluster, jobs = _philly_case(1, n_jobs=36, n_servers=6)
        rng = np.random.default_rng(7)
        arrivals = rng.integers(0, 600, size=len(jobs)).astype(np.int64)
        asg = [(j.jid, rng.choice(cluster.num_gpus, size=j.num_gpus,
                                  replace=False)) for j in jobs]
        multi = simulate(cluster, jobs, asg, arrivals=arrivals,
                         horizon=horizon, stepping="multi")
        single = simulate(cluster, jobs, asg, arrivals=arrivals,
                          horizon=horizon, stepping="single")
        _assert_sims_equal(multi, single)

    def test_default_stepping_is_multi_only_off_oracle_axes(self):
        """stepping=None resolves to multi under (tracked, non-reference)
        and to single otherwise -- results identical either way."""
        cluster, jobs = _philly_case(2, n_jobs=24, n_servers=6)
        rng = np.random.default_rng(9)
        asg = [(j.jid, rng.choice(cluster.num_gpus, size=j.num_gpus,
                                  replace=False)) for j in jobs]
        default = simulate(cluster, jobs, asg)
        for kwargs in ({"engine": "reference"}, {"readiness": "rescan"}):
            _assert_sims_equal(default, simulate(cluster, jobs, asg,
                                                 **kwargs))

    def test_multi_stepping_rejected_on_oracle_axes(self):
        cluster, jobs = _philly_case(0, n_jobs=12, n_servers=4)
        asg = [(j.jid, np.arange(j.num_gpus)) for j in jobs[:1]]
        with pytest.raises(ValueError, match="stepping"):
            simulate(cluster, jobs, asg, stepping="warp")
        with pytest.raises(ValueError, match="multi"):
            simulate(cluster, jobs, asg, stepping="multi",
                     readiness="rescan")
        with pytest.raises(ValueError, match="multi"):
            simulate(cluster, jobs, asg, stepping="multi",
                     engine="reference")
