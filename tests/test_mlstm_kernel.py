"""mLSTM Pallas kernel vs the pure-jnp oracle and the model's own math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Runs in Pallas interpret mode on CPU (mlstm_parallel defaults to
# interpret=True off-accelerator), so no `gpu` marker: CI runs it.

from repro.kernels import ref
from repro.kernels.mlstm import mlstm_parallel


def _inputs(BH, S, hd, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (BH, S, hd), dtype)
    k = jax.random.normal(ks[1], (BH, S, hd), dtype) / jnp.sqrt(hd)
    v = jax.random.normal(ks[2], (BH, S, hd), dtype)
    # realistic gates: forget ~ sigmoid(3) (slow decay), input pre-act ~ N(0,1)
    logf = jax.nn.log_sigmoid(3.0 + jax.random.normal(ks[3], (BH, S)))
    F = jnp.cumsum(logf, axis=1)
    i_pre = jax.random.normal(ks[4], (BH, S))
    return q, k, v, F, i_pre


class TestMLSTMKernel:
    @pytest.mark.parametrize("BH,S,hd", [(2, 128, 64), (4, 256, 64),
                                         (1, 512, 128), (2, 128, 256)])
    def test_matches_ref(self, BH, S, hd):
        q, k, v, F, i_pre = _inputs(BH, S, hd)
        out = mlstm_parallel(q, k, v, F, i_pre, block_q=128, block_k=128)
        exp = ref.mlstm_parallel(q, k, v, F, i_pre)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
    def test_block_shape_sweep(self, bq, bk):
        q, k, v, F, i_pre = _inputs(2, 256, 64, seed=1)
        out = mlstm_parallel(q, k, v, F, i_pre, block_q=bq, block_k=bk)
        exp = ref.mlstm_parallel(q, k, v, F, i_pre)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16_inputs(self):
        q, k, v, F, i_pre = _inputs(2, 128, 64, seed=2, dtype=jnp.bfloat16)
        out = mlstm_parallel(q, k, v, F.astype(jnp.float32),
                             i_pre.astype(jnp.float32),
                             block_q=64, block_k=64)
        exp = ref.mlstm_parallel(q, k, v, F.astype(jnp.float32),
                                 i_pre.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_matches_model_mlstm_block(self):
        """The kernel must agree with the model's chunked jnp path
        (ssm._mlstm_parallel_block) — same math, different engine."""
        from repro.models.ssm import _mlstm_parallel_block
        BH, S, hd = 2, 256, 64
        q, k, v, F, i_pre = _inputs(BH, S, hd, seed=3)
        # model layout: [B, S, H, hd] with H folded differently; use B=BH,H=1
        qm = q[:, :, None, :]
        km = k[:, :, None, :]
        vm = v[:, :, None, :]
        Fm = F[:, :, None]
        im = i_pre[:, :, None]
        exp = _mlstm_parallel_block(qm.astype(jnp.float32), Fm,
                                    km.astype(jnp.float32),
                                    vm.astype(jnp.float32), Fm, im, 0, S)
        out = mlstm_parallel(q, k, v, F, i_pre, block_q=128, block_k=128)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(exp[:, :, 0, :]),
                                   rtol=2e-4, atol=2e-4)

    def test_decay_actually_decays(self):
        """Sanity: with strong forget gates, distant tokens contribute less:
        zeroing v beyond a horizon changes y_t only slightly."""
        BH, S, hd = 1, 256, 64
        q, k, v, F, i_pre = _inputs(BH, S, hd, seed=4)
        logf = jnp.full((BH, S), jnp.log(0.5))          # aggressive decay
        F = jnp.cumsum(logf, axis=1)
        full = mlstm_parallel(q, k, v, F, i_pre, block_q=64, block_k=64)
        v_trunc = v.at[:, :128].set(0.0)
        trunc = mlstm_parallel(q, k, v_trunc, F, i_pre, block_q=64, block_k=64)
        # last rows see ~zero contribution from the zeroed distant half
        np.testing.assert_allclose(np.asarray(full[:, -16:]),
                                   np.asarray(trunc[:, -16:]),
                                   rtol=1e-3, atol=1e-3)
