"""The preemptive & elastic policy family on the selectable-oracle axes.

Bit-identity requirements, mirroring the non-preemptive suites:

  * each preemptive policy emits the same segmented schedule under every
    contention engine;
  * a preempted (multi-segment, quota-carrying) schedule simulates
    event-for-event identically across the simulator's engine x
    readiness x stepping axes;
  * the service daemon drains the preemptive choosers decision-for-
    decision identically to :func:`repro.core.api.schedule_arrivals`,
    journaling EVICT / RESIZE records inside the decision bracket;
  * killing the daemon after EVERY journal prefix -- including prefixes
    that cut inside an EVICT bracket -- and recovering reproduces the
    uninterrupted schedule exactly (the ``test_service`` fault-injection
    pattern, extended through preemption).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (Cluster, Job, ScheduleRequest, get_policy, simulate)
from repro.core.api import schedule_arrivals
from repro.service.daemon import Daemon
from repro.service.queue import QueueManager, TenantConfig
from repro.service.store import MemoryStore

ENGINES = ("reference", "batched", "incremental")
PREEMPTIVE = ("sjf-bco-dynamic", "gadget-elastic", "wang-ca")


def _evict_trace():
    """One long 8-GPU job at t=0, then a burst of shorts: the dynamic
    chooser preempts the long job for each short (verified below)."""
    cluster = Cluster(capacities=(4, 4))
    jobs = [Job(jid=0, num_gpus=8, iters=4000, grad_size=0.25, batch=32,
                dt_fwd=3e-4, dt_bwd=8e-3)]
    jobs += [Job(jid=i, num_gpus=2, iters=200, grad_size=0.05, batch=32,
                 dt_fwd=3e-4, dt_bwd=8e-3) for i in range(1, 4)]
    arrivals = np.array([0, 5, 6, 7], dtype=np.int64)
    return cluster, jobs, arrivals, 10**6


def _resize_trace():
    """A tight theta: the arrival cannot queue behind the wide job within
    the Eq. (16) budget, so gadget-elastic shrinks it (RESIZE record)."""
    cluster = Cluster(capacities=(4,))
    jobs = [Job(jid=0, num_gpus=4, iters=2000, grad_size=0.25, batch=32,
                dt_fwd=3e-4, dt_bwd=8e-3),
            Job(jid=1, num_gpus=2, iters=100, grad_size=0.05, batch=32,
                dt_fwd=3e-4, dt_bwd=8e-3)]
    arrivals = np.array([0, 5], dtype=np.int64)
    # rho(job 0) ~ 50 slots -> U charge ~ 33.4; theta = 35 admits it but
    # not an arrival queued behind it (33.4 + ~1.7 > 35), while the
    # post-shrink replacements fit (~5 and ~33.3).
    return cluster, jobs, arrivals, 35


def _same_schedule(a, b):
    if len(a.assignment) != len(b.assignment):
        return False
    for (j1, g1), (j2, g2) in zip(a.assignment, b.assignment):
        if j1 != j2 or not np.array_equal(g1, g2):
            return False
    if (a.quotas is None) != (b.quotas is None):
        return False
    if a.quotas is not None and not np.array_equal(a.quotas, b.quotas):
        return False
    return True


def _assert_sims_equal(a, b):
    assert a.events == b.events
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.finish, b.finish)
    assert a.makespan == b.makespan
    assert a.avg_jct == b.avg_jct
    assert a.completed == b.completed
    assert a.peak_contention == b.peak_contention
    assert a.busy_gpu_slots == b.busy_gpu_slots


class TestEngineEquivalence:
    @pytest.mark.parametrize("policy,trace", [
        ("sjf-bco-dynamic", _evict_trace),
        ("gadget-elastic", _evict_trace),
        ("wang-ca", _evict_trace),
        ("gadget-elastic", _resize_trace)])   # theta=35 is gadget-only
    def test_online_schedules_identical_across_engines(self, policy, trace):
        cluster, jobs, arrivals, horizon = trace()
        scheds = []
        for engine in ENGINES:
            request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                      arrivals=arrivals, horizon=horizon,
                                      params={"engine": engine})
            scheds.append(get_policy(policy)(request))
        for other in scheds[1:]:
            assert _same_schedule(scheds[0], other)

    @pytest.mark.parametrize("policy", ["sjf-bco-dynamic", "wang-ca"])
    def test_batch_schedules_identical_across_engines(self, policy):
        from repro.core import philly_cluster, philly_workload
        cluster = philly_cluster(6, seed=3)
        jobs = [dataclasses.replace(j, jid=i) for i, j in
                enumerate(philly_workload(seed=3)[:24])]
        scheds = []
        for engine in ENGINES:
            request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                      horizon=1200,
                                      params={"engine": engine})
            scheds.append(get_policy(policy)(request))
        for other in scheds[1:]:
            assert _same_schedule(scheds[0], other)

    def test_dynamic_trace_actually_preempts(self):
        cluster, jobs, arrivals, horizon = _evict_trace()
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=horizon)
        sched = get_policy("sjf-bco-dynamic")(request)
        assert sched.quotas is not None        # the schedule is segmented
        jids = [j for j, _ in sched.assignment]
        assert len(jids) > len(jobs)           # at least one split
        sim = simulate(cluster, jobs, sched.assignment, arrivals=arrivals,
                       quotas=sched.quotas)
        assert sim.completed == len(jobs)
        # the preemption must actually pay off for the shorts
        base = get_policy("sjf-bco")(dataclasses.replace(request))
        sim_base = simulate(cluster, jobs, base.assignment, arrivals=arrivals)
        assert sim.avg_jct < sim_base.avg_jct

    def test_elastic_trace_actually_resizes(self):
        cluster, jobs, arrivals, horizon = _resize_trace()
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=horizon)
        sched = get_policy("gadget-elastic")(request)
        assert sched.quotas is not None
        widths = {j: len(g) for j, g in sched.assignment}   # last seg wins
        assert widths[0] < jobs[0].num_gpus    # the wide job shrank
        sim = simulate(cluster, jobs, sched.assignment, arrivals=arrivals,
                       quotas=sched.quotas)
        assert sim.completed == len(jobs)


class TestSimulatorAxesOnSegments:
    def _segmented(self):
        cluster, jobs, arrivals, horizon = _evict_trace()
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=horizon)
        sched = get_policy("sjf-bco-dynamic")(request)
        assert sched.quotas is not None
        return cluster, jobs, arrivals, sched

    @pytest.mark.parametrize("engine", ["reference", "incremental"])
    @pytest.mark.parametrize("readiness", ["tracked", "rescan"])
    def test_segmented_schedule_identical_across_axes(self, engine,
                                                      readiness):
        cluster, jobs, arrivals, sched = self._segmented()
        oracle = simulate(cluster, jobs, sched.assignment, arrivals=arrivals,
                          quotas=sched.quotas, engine="reference",
                          readiness="rescan")
        sim = simulate(cluster, jobs, sched.assignment, arrivals=arrivals,
                       quotas=sched.quotas, engine=engine,
                       readiness=readiness)
        _assert_sims_equal(oracle, sim)

    def test_multi_stepping_matches_single(self):
        cluster, jobs, arrivals, sched = self._segmented()
        single = simulate(cluster, jobs, sched.assignment, arrivals=arrivals,
                          quotas=sched.quotas, stepping="single")
        multi = simulate(cluster, jobs, sched.assignment, arrivals=arrivals,
                         quotas=sched.quotas, stepping="multi")
        _assert_sims_equal(single, multi)

    def test_quota_guard_rejects_unlabelled_segments(self):
        cluster, jobs, arrivals, sched = self._segmented()
        with pytest.raises(ValueError, match="must pass quotas"):
            simulate(cluster, jobs, sched.assignment, arrivals=arrivals)


class TestDaemonEquivalence:
    def _drain(self, policy, trace):
        cluster, jobs, arrivals, horizon = trace()
        store = MemoryStore()
        daemon = Daemon(cluster, store,
                        QueueManager(default=TenantConfig(policy=policy)),
                        horizon=horizon)
        for job, a in zip(jobs, arrivals):
            daemon.admit(job, arrival=int(a))
        sched, sim = daemon.drain()
        return cluster, jobs, arrivals, horizon, store, sched, sim

    @pytest.mark.parametrize("policy,trace", [
        ("sjf-bco-dynamic", _evict_trace),
        ("gadget-elastic", _evict_trace),
        ("wang-ca", _evict_trace),
        ("gadget-elastic", _resize_trace)])
    def test_daemon_matches_schedule_arrivals(self, policy, trace):
        (cluster, jobs, arrivals, horizon,
         store, sched, _) = self._drain(policy, trace)
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=horizon)
        oneshot = get_policy(policy)(request)
        assert _same_schedule(sched, oneshot)

    def test_dynamic_daemon_journals_evict(self):
        *_, store, _, _ = self._drain("sjf-bco-dynamic", _evict_trace)
        kinds = [e.kind for e in store.entries()]
        assert "evict" in kinds
        # the evict record sits strictly inside a PLACING..decided bracket
        i = kinds.index("evict")
        assert "decided" in kinds[i:]

    def test_elastic_daemon_journals_resize(self):
        *_, store, _, _ = self._drain("gadget-elastic", _resize_trace)
        kinds = [e.kind for e in store.entries()]
        assert "resize" in kinds

    @pytest.mark.parametrize("policy,trace", [
        ("sjf-bco-dynamic", _evict_trace),
        ("gadget-elastic", _resize_trace)])
    def test_recovery_identical_at_every_prefix(self, policy, trace):
        """Crash after EVERY journaled event; prefixes cutting inside an
        EVICT/RESIZE bracket must recover to the pre-decision state and
        re-derive the identical preemption."""
        (cluster, jobs, arrivals, horizon,
         store, full, _) = self._drain(policy, trace)
        entries = store.entries()
        in_bracket_cuts = 0
        open_jid = None
        for k in range(len(entries) + 1):
            if k and entries[k - 1].kind == "transition" and \
                    entries[k - 1].payload["to"] == "PLACING":
                open_jid = entries[k - 1].jid
            if k and entries[k - 1].kind == "decided":
                open_jid = None
            if open_jid is not None and any(
                    e.kind in ("evict", "resize") for e in entries[:k]
                    if e.seq > 0) and entries[k - 1].kind in (
                        "evict", "resize"):
                in_bracket_cuts += 1
            daemon = Daemon.recover(
                cluster, store.prefix(k),
                QueueManager(default=TenantConfig(policy=policy)),
                horizon=horizon)
            for job, a in list(zip(jobs, arrivals))[len(daemon.jobs):]:
                daemon.admit(job, arrival=int(a))
            sched, _ = daemon.drain()
            assert _same_schedule(full, sched), f"prefix {k}"
        assert in_bracket_cuts > 0    # the interesting window was hit

    def test_recover_then_crash_then_recover(self):
        """A journal that already contains an abandoned (dangling)
        bracket -- crash, recover, write on, crash again -- still
        recovers: the abandoned bracket is skipped, not half-applied."""
        (cluster, jobs, arrivals, horizon,
         store, full, _) = self._drain("sjf-bco-dynamic", _evict_trace)
        entries = store.entries()
        cuts = [k for k in range(1, len(entries))
                if entries[k - 1].kind in ("evict", "resize")]
        assert cuts
        k = cuts[0]                        # cut right after an evict record
        snap = store.prefix(k)
        daemon = Daemon.recover(
            cluster, snap,
            QueueManager(default=TenantConfig(policy="sjf-bco-dynamic")),
            horizon=horizon)
        for job, a in list(zip(jobs, arrivals))[len(daemon.jobs):]:
            daemon.admit(job, arrival=int(a))
        daemon.drain()                     # journal now has dangling + new
        again = Daemon.recover(
            cluster, daemon.store,
            QueueManager(default=TenantConfig(policy="sjf-bco-dynamic")),
            horizon=horizon)
        sched, _ = again.drain()
        assert _same_schedule(full, sched)

    @pytest.mark.parametrize("policy,trace", [
        ("sjf-bco-dynamic", _evict_trace),
        ("gadget-elastic", _resize_trace)])
    def test_snapshot_folds_preemption_brackets(self, policy, trace):
        """Journal compaction folds EVICT/RESIZE brackets into snapshot
        ops; recovery from every compacted prefix still reproduces the
        preemptive schedule exactly (residuals re-derived bit-for-bit)."""
        (cluster, jobs, arrivals, horizon,
         store, full, _) = self._drain(policy, trace)
        folded_preemptions = 0
        for k in range(len(store) + 1):
            snap = store.prefix(k)
            snap.snapshot()
            entries = snap.entries()
            if len(entries) > 1 and entries[1].kind == "snapshot":
                folded_preemptions += sum(
                    op["op"] in ("evict", "resize")
                    for op in entries[1].payload["ops"])
            daemon = Daemon.recover(
                cluster, snap,
                QueueManager(default=TenantConfig(policy=policy)),
                horizon=horizon)
            for job, a in list(zip(jobs, arrivals))[len(daemon.jobs):]:
                daemon.admit(job, arrival=int(a))
            sched, _ = daemon.drain()
            assert _same_schedule(full, sched), f"prefix {k}"
        assert folded_preemptions > 0     # snapshots really carried them

    def test_schedule_arrivals_chooser_matches_policy(self):
        """The registry chooser is literally the policy's online path."""
        from repro.core.api import get_chooser
        cluster, jobs, arrivals, horizon = _evict_trace()
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=horizon)
        via_policy = get_policy("sjf-bco-dynamic")(request)
        chooser = get_chooser("sjf-bco-dynamic")(cluster, 1.5, {})
        via_loop = schedule_arrivals(request, chooser, "SJF-BCO-DYN")
        assert _same_schedule(via_policy, via_loop)
