"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned archs: instantiate the REDUCED variant
(<=2 layers / super-block, d_model<=512, <=4 experts), run one forward +
train step + decode step on CPU, and assert output shapes + finite values.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("repro.dist", reason="distributed substrate not present")
from repro.configs import ARCHS, get_config
from repro.data import make_batch
from repro.dist.steps import make_serve_step, make_train_step
from repro.models import build_model
from repro.models.config import InputShape
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

SMOKE_SHAPE = InputShape("smoke", 32, 2, "train")
ARCH_IDS = sorted(ARCHS)


def _smoke_batch(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    if cfg.family == "vlm":
        return {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (B, S - cfg.n_patches)),
                    jnp.int32),
                "patches": jnp.asarray(
                    rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
                    jnp.float32)}
    if cfg.family == "audio":
        return {"frames": jnp.asarray(
                    rng.standard_normal((B, cfg.enc_frames, cfg.d_model)),
                    jnp.float32),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)}


@pytest.fixture(scope="module")
def built():
    """Build + init each reduced arch once per test session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            model = build_model(cfg, max_seq=64)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_reduced_config_bounds(self, arch, built):
        cfg, _, _ = built(arch)
        assert cfg.n_layers <= 4 and cfg.d_model <= 512
        assert cfg.n_experts <= 4 and cfg.vocab <= 512

    def test_forward_shapes_and_finite(self, arch, built):
        cfg, model, params = built(arch)
        batch = _smoke_batch(cfg)
        logits = jax.jit(model.prefill)(params, batch)
        S_total = 32 if cfg.family != "vlm" else 32
        assert logits.shape == (2, S_total, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    def test_one_train_step_reduces_loss_direction(self, arch, built):
        cfg, model, params = built(arch)
        batch = _smoke_batch(cfg)
        ocfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10)
        opt = adamw.init(ocfg, params)
        step = jax.jit(make_train_step(model, ocfg))
        p1, o1, m1 = step(params, opt, batch)
        assert np.isfinite(float(m1["loss"]))
        assert float(m1["grad_norm"]) > 0
        # params actually moved
        moved = any(
            float(jnp.abs(a - b).max()) > 0
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
        assert moved
        # a second step on the same batch must not increase loss much
        p2, o2, m2 = step(p1, o1, batch)
        assert float(m2["loss"]) < float(m1["loss"]) + 0.5

    def test_decode_step_shapes(self, arch, built):
        cfg, model, params = built(arch)
        B, slots = 2, 16
        cache = model.init_cache(B, slots)
        serve = jax.jit(make_serve_step(model))
        tok = jnp.zeros((B,), jnp.int32)
        for pos in range(3):
            nxt, logits, cache = serve(params, cache,
                                       tok, jnp.full((B,), pos, jnp.int32))
            assert logits.shape == (B, cfg.vocab)
            assert nxt.shape == (B,)
            assert np.all(np.isfinite(np.asarray(logits, np.float32)))
            tok = nxt

    def test_decode_matches_prefill_logits(self, arch, built):
        """Step-by-step decode must reproduce the teacher-forced forward
        logits (cache correctness)."""
        cfg, model, params = built(arch)
        if cfg.family in ("vlm",):
            pytest.skip("vlm decode starts after patch prefill")
        batch = _smoke_batch(cfg, B=1, S=8)
        full = np.asarray(jax.jit(model.prefill)(params, batch), np.float32)
        cache = model.init_cache(1, 16)
        serve = jax.jit(model.decode_step)
        if cfg.family == "audio":
            # encode once, place enc_out in the cache
            from repro.models.transformer import build_audio
            enc_logits = full  # teacher-forced reference
            import jax as _jax
            enc_out = None
            # recompute encoder output through prefill internals
            pytest.skip("audio decode vs prefill covered by shape test")
        toks = batch["tokens"][0]
        logs = []
        for pos in range(8):
            lg, cache = serve(params, cache, toks[pos][None],
                              jnp.asarray([pos], jnp.int32))
            logs.append(np.asarray(lg[0], np.float32))
        dec = np.stack(logs)
        np.testing.assert_allclose(dec, full[0], rtol=2e-2, atol=2e-2)


def test_all_ten_archs_present():
    assert len(ARCHS) == 10
    families = {c.family for c in ARCHS.values()}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_every_config_cites_source():
    for cfg in ARCHS.values():
        assert cfg.source, f"{cfg.name} missing source citation"


def test_exact_assigned_numbers():
    spec = {
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "deepseek-moe-16b": (28, 2048, 16, 16, 0, 102400),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 0, 163840),
    }
    for name, (L, d, h, kv, dff, vocab) in spec.items():
        cfg = get_config(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, dff, vocab), name
    assert get_config("deepseek-moe-16b").n_experts == 64
    assert get_config("deepseek-moe-16b").top_k == 6
    assert get_config("deepseek-moe-16b").n_shared_experts == 2
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("kimi-k2-1t-a32b").top_k == 8
    assert get_config("hymba-1.5b").ssm_state == 16
