"""Equivalence tests for the batched / incremental contention engines.

The acceptance bar is *bit-identity*: ``evaluate_many`` and
``IncrementalEval`` must reproduce :func:`repro.core.contention.evaluate`
exactly (same floats, same ints) on randomized placements, and every
scheduling policy must emit the identical schedule (assignments and
est_makespan) under all three engines.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (Cluster, IncrementalEval, Job, ScheduleRequest,
                        contention_level, degradation, estimate_exec_time,
                        eval_counts, evaluate, evaluate_many,
                        evaluation_engine, get_policy, philly_cluster,
                        philly_workload, predict_exec_time,
                        reset_eval_counts, simulate, slots_for, tau_bounds)
from repro.core.api import PlacementState
from repro.core.contention import scalar_tau

CL = Cluster(capacities=(4, 8, 4))


def _job(jid, gpus, iters=1000, m=1.3e-3, M=32, dfw=3e-4, dbw=8e-3):
    return Job(jid=jid, num_gpus=gpus, iters=iters, grad_size=m, batch=M,
               dt_fwd=dfw, dt_bwd=dbw)


def _random_jobs(rng, n):
    return [_job(i, int(rng.choice([1, 2, 3, 4, 6, 8])),
                 iters=int(rng.integers(500, 3000)),
                 m=float(rng.uniform(0.5e-3, 2e-3)),
                 M=int(rng.integers(16, 64)),
                 dfw=float(rng.uniform(2e-4, 5e-4)),
                 dbw=float(rng.uniform(4e-3, 1.2e-2))) for i in range(n)]


def _random_placement(rng, job, n_servers):
    """Random split of G_j across servers (capacity ignored, as in the
    analytical-model tests: Eq. (2) is the schedulers' job)."""
    y = np.zeros(n_servers, dtype=np.int64)
    for _ in range(job.num_gpus):
        y[rng.integers(n_servers)] += 1
    return y


def _assert_models_equal(a, b, idx=None):
    """Exact (bitwise) equality of two IterModel slices."""
    for field in ("p", "k", "bandwidth", "gamma", "exchange", "reduce",
                  "compute", "tau", "phi"):
        av, bv = getattr(a, field), getattr(b, field)
        if idx is not None:
            bv = bv[idx]
        assert np.array_equal(av, bv), f"{field} differs"


class TestEvaluateMany:
    @pytest.mark.parametrize("seed", range(5))
    def test_bit_identical_to_per_candidate_evaluate(self, seed):
        rng = np.random.default_rng(seed)
        J, C = int(rng.integers(1, 7)), int(rng.integers(1, 6))
        jobs = _random_jobs(rng, J)
        stack = np.stack([
            np.stack([_random_placement(rng, j, CL.num_servers) for j in jobs])
            for _ in range(C)])
        many = evaluate_many(CL, jobs, stack)
        assert many.tau.shape == (C, J)
        for c in range(C):
            _assert_models_equal(evaluate(CL, jobs, stack[c]), many, idx=c)

    def test_active_mask_equals_row_omission(self):
        rng = np.random.default_rng(7)
        jobs = _random_jobs(rng, 5)
        Y = np.stack([_random_placement(rng, j, CL.num_servers) for j in jobs])
        active = np.array([[True, False, True, True, False]])
        masked = evaluate_many(CL, jobs, Y[None, :, :], active=active)
        sub = [jobs[i] for i in (0, 2, 3)]
        direct = evaluate(CL, sub, Y[[0, 2, 3]])
        # Active rows must match the model with the inactive rows omitted.
        assert np.array_equal(masked.tau[0, [0, 2, 3]], direct.tau)
        assert np.array_equal(masked.p[0, [0, 2, 3]], direct.p)

    def test_rejects_bad_shapes_and_uncovered_placements(self):
        jobs = [_job(0, 4)]
        with pytest.raises(ValueError):
            evaluate_many(CL, jobs, np.zeros((2, 1, CL.num_servers + 1),
                                             dtype=np.int64))
        with pytest.raises(ValueError):
            evaluate_many(CL, jobs, np.zeros((1, 1, CL.num_servers),
                                             dtype=np.int64))


class TestIncrementalEval:
    @pytest.mark.parametrize("seed", range(5))
    def test_add_remove_sequence_matches_evaluate(self, seed):
        rng = np.random.default_rng(100 + seed)
        jobs = _random_jobs(rng, 12)
        inc = IncrementalEval(CL, capacity=4)   # force growth too
        live: list[tuple[int, Job, np.ndarray]] = []
        for step in range(40):
            if live and rng.random() < 0.4:
                row, _, _ = live.pop(int(rng.integers(len(live))))
                inc.remove(row)
            else:
                job = jobs[int(rng.integers(len(jobs)))]
                y = _random_placement(rng, job, CL.num_servers)
                live.append((inc.add(job, y), job, y))
            if not live:
                continue
            rows = [r for r, _, _ in live]
            sub_jobs = [dataclasses.replace(j, jid=i)
                        for i, (_, j, _) in enumerate(live)]
            Y = np.stack([y for _, _, y in live])
            _assert_models_equal(inc.model(rows), evaluate(CL, sub_jobs, Y))

    def test_probe_is_read_only_and_exact(self):
        rng = np.random.default_rng(3)
        jobs = _random_jobs(rng, 6)
        inc = IncrementalEval(CL)
        rows, ys = [], []
        for job in jobs[:-1]:
            y = _random_placement(rng, job, CL.num_servers)
            rows.append(inc.add(job, y))
            ys.append(y)
        probe = jobs[-1]
        y_p = _random_placement(rng, probe, CL.num_servers)
        before = inc.model(rows)
        tau = inc.probe_tau(probe, y_p)
        _assert_models_equal(before, inc.model(rows))   # no mutation
        full = evaluate(CL, jobs[:-1] + [probe], np.stack(ys + [y_p]))
        assert tau == full.tau[-1]

    def test_scalar_tau_matches_evaluate(self):
        job = _job(0, 4)
        for y in ([4, 0, 0], [2, 2, 0], [1, 1, 2]):
            y = np.asarray(y)
            model = evaluate(CL, [job], y[None, :])
            p = int(contention_level(y[None, :],
                                     np.array([job.num_gpus]))[0])
            assert scalar_tau(CL, job, p, int((y > 0).sum())) == model.tau[0]


def _philly_request(n_servers=12, seed=3, engine=None, **params):
    cluster = philly_cluster(n_servers, seed=seed)
    mix = ((1, 12), (2, 4), (4, 6), (8, 4), (16, 2))
    jobs = philly_workload(seed=seed, mix=mix)
    if engine is not None:
        params["engine"] = engine
    return cluster, jobs, ScheduleRequest(cluster=cluster, jobs=jobs,
                                          horizon=1200, params=params)


class TestScheduleEquivalence:
    @pytest.mark.parametrize("policy", ["sjf-bco", "sjf-bco-adaptive",
                                        "ff", "ls", "rand"])
    def test_schedules_identical_across_engines(self, policy):
        results = {}
        for engine in ("reference", "incremental", "batched"):
            _, _, request = _philly_request(engine=engine)
            results[engine] = get_policy(policy)(request)
        ref = results["reference"]
        for engine in ("incremental", "batched"):
            other = results[engine]
            assert other.est_makespan == ref.est_makespan
            assert other.max_busy_time == ref.max_busy_time
            assert len(other.assignment) == len(ref.assignment)
            for (j1, g1), (j2, g2) in zip(ref.assignment, other.assignment):
                assert j1 == j2 and np.array_equal(g1, g2), \
                    f"{policy}/{engine}: job {j1} placement differs"

    def test_default_engine_context(self):
        # evaluation_engine() switches the module default used when no
        # explicit engine param is given.
        _, _, request = _philly_request()
        with evaluation_engine("reference"):
            reset_eval_counts()
            get_policy("ff")(request)
            assert eval_counts()["full"] > 0
            assert eval_counts()["probes"] == 0
        with evaluation_engine("incremental"):
            reset_eval_counts()
            get_policy("ff")(request)
            assert eval_counts()["full"] == 0
            assert eval_counts()["probes"] > 0

    def test_online_arrivals_identical_across_engines(self):
        cluster = philly_cluster(10, seed=5)
        jobs = philly_workload(seed=5, mix=((1, 8), (2, 4), (4, 4)))
        arrivals = np.random.default_rng(5).integers(0, 60, size=len(jobs))
        results = {}
        for engine in ("reference", "incremental", "batched"):
            request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                      arrivals=arrivals, horizon=2400,
                                      params={"engine": engine})
            results[engine] = get_policy("sjf-bco")(request)
        ref = results["reference"]
        for engine in ("incremental", "batched"):
            assert results[engine].est_makespan == ref.est_makespan
            for (j1, g1), (j2, g2) in zip(ref.assignment,
                                          results[engine].assignment):
                assert j1 == j2 and np.array_equal(g1, g2)


class TestSimulatorEquivalence:
    def test_simulation_identical_across_engines(self):
        cluster, jobs, request = _philly_request(engine="incremental")
        sched = get_policy("sjf-bco")(request)
        ref = simulate(cluster, jobs, sched.assignment, engine="reference")
        inc = simulate(cluster, jobs, sched.assignment, engine="incremental")
        assert ref.makespan == inc.makespan
        assert np.array_equal(ref.start, inc.start)
        assert np.array_equal(ref.finish, inc.finish)
        assert ref.peak_contention == inc.peak_contention
        assert ref.busy_gpu_slots == inc.busy_gpu_slots
        assert ref.events == inc.events

    def test_incremental_simulation_runs_no_full_evals(self):
        cluster, jobs, request = _philly_request(engine="incremental")
        sched = get_policy("sjf-bco")(request)
        # Default stepping under the incremental engine is "multi": the
        # windows come from tau_ladder batches, not full [J, S] passes.
        reset_eval_counts()
        simulate(cluster, jobs, sched.assignment, engine="incremental")
        counts = eval_counts()
        assert counts["full"] == 0
        assert counts["ladder_calls"] > 0
        assert counts["incremental_updates"] == 0
        # Single-window stepping keeps the IncrementalEval row updates.
        reset_eval_counts()
        simulate(cluster, jobs, sched.assignment, engine="incremental",
                 stepping="single")
        counts = eval_counts()
        assert counts["full"] == 0
        assert counts["incremental_updates"] > 0


class TestBatchedSweep:
    """The batched (theta, kappa) sweep must be bit-identical to the
    sequential reference: shared placed prefixes + forked suffixes change
    the work, never the schedule."""

    @pytest.mark.parametrize("seed", [3, 5, 9])
    def test_batched_sweep_identical_to_sequential(self, seed):
        cluster = philly_cluster(12, seed=seed)
        mix = ((1, 12), (2, 4), (4, 6), (8, 4), (16, 2))
        jobs = philly_workload(seed=seed, mix=mix)
        results = {}
        for sweep in ("sequential", "batched"):
            request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                      horizon=1200,
                                      params={"sweep": sweep})
            results[sweep] = get_policy("sjf-bco")(request)
        ref, bat = results["sequential"], results["batched"]
        assert bat.est_makespan == ref.est_makespan
        assert bat.max_busy_time == ref.max_busy_time
        assert bat.kappa == ref.kappa
        assert len(bat.assignment) == len(ref.assignment)
        for (j1, g1), (j2, g2) in zip(ref.assignment, bat.assignment):
            assert j1 == j2 and np.array_equal(g1, g2)

    @pytest.mark.parametrize("kappas", [[1], [4, 1, 16], [3, 5], [8, 8, 2]])
    def test_explicit_kappas_preserve_tie_breaks(self, kappas):
        # Unsorted/duplicate kappa lists: the batched sweep still picks
        # the same winner (first-best in the user's order) as the
        # sequential loop.
        cluster = philly_cluster(10, seed=4)
        jobs = philly_workload(seed=4, mix=((1, 8), (2, 4), (4, 6), (8, 2)))
        results = {}
        for sweep in ("sequential", "batched"):
            request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                      horizon=1200,
                                      params={"sweep": sweep,
                                              "kappas": list(kappas)})
            results[sweep] = get_policy("sjf-bco")(request)
        ref, bat = results["sequential"], results["batched"]
        assert bat.kappa == ref.kappa
        assert bat.est_makespan == ref.est_makespan
        for (j1, g1), (j2, g2) in zip(ref.assignment, bat.assignment):
            assert j1 == j2 and np.array_equal(g1, g2)

    def test_sweep_composes_with_engines_and_warm_start(self):
        cluster = philly_cluster(12, seed=3)
        jobs = philly_workload(seed=3, mix=((1, 12), (2, 4), (4, 6), (8, 4)))
        ref = None
        for engine in ("reference", "incremental", "batched"):
            for sweep in ("sequential", "batched"):
                for warm in (False, True):
                    request = ScheduleRequest(
                        cluster=cluster, jobs=jobs, horizon=1200,
                        params={"engine": engine, "sweep": sweep,
                                "warm_start": warm})
                    sched = get_policy("sjf-bco")(request)
                    if not warm:
                        # warm_start legitimately changes the search
                        # trajectory; cold runs must all coincide.
                        if ref is None:
                            ref = sched
                        assert sched.est_makespan == ref.est_makespan
                        for (j1, g1), (j2, g2) in zip(ref.assignment,
                                                      sched.assignment):
                            assert j1 == j2 and np.array_equal(g1, g2)
                    assert {j for j, _ in sched.assignment} \
                        == set(range(len(jobs)))

    def test_unknown_sweep_mode_rejected(self):
        cluster = philly_cluster(6, seed=1)
        jobs = philly_workload(seed=1, mix=((1, 4), (2, 2)))
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200,
                                  params={"sweep": "bogus"})
        with pytest.raises(ValueError, match="sweep"):
            get_policy("sjf-bco")(request)

    def test_placement_state_clone_is_independent(self):
        from repro.core import nominal_rho
        from repro.core.api import try_place
        from repro.core.sjf_bco import fa_ffp
        cluster = philly_cluster(6, seed=2)
        jobs = philly_workload(seed=2, mix=((2, 4), (4, 2)))
        state = PlacementState(cluster)
        for job in jobs[:3]:
            assert try_place(state, job, fa_ffp,
                             nominal_rho(cluster, job), 1.5, 1e6)
        fork = state.clone()
        snapshot = (state.U.copy(), state.R.copy(), len(state.assignment),
                    dict(state.est_finish),
                    [list(f) for f in state._straddle_fin])
        for job in jobs[3:]:
            assert try_place(fork, job, fa_ffp,
                             nominal_rho(cluster, job), 1.5, 1e6)
        # Committing into the fork left the original untouched.
        assert np.array_equal(state.U, snapshot[0])
        assert np.array_equal(state.R, snapshot[1])
        assert len(state.assignment) == snapshot[2]
        assert state.est_finish == snapshot[3]
        assert [list(f) for f in state._straddle_fin] == snapshot[4]
        assert len(fork.assignment) == len(jobs)


class TestBatchedProbes:
    """scalar_tau_many / probe_tau_many: the vectorised probe entry points
    must be bit-identical to their scalar forms."""

    @pytest.mark.parametrize("seed", range(3))
    def test_probe_tau_many_matches_scalar_probes(self, seed):
        rng = np.random.default_rng(300 + seed)
        jobs = _random_jobs(rng, 8)
        inc = IncrementalEval(CL)
        for job in jobs[:-1]:
            inc.add(job, _random_placement(rng, job, CL.num_servers))
        probe = jobs[-1]
        cands = np.stack([_random_placement(rng, probe, CL.num_servers)
                          for _ in range(6)])
        many = inc.probe_tau_many(probe, cands)
        assert many.shape == (6,)
        for c in range(6):
            assert many[c] == inc.probe_tau(probe, cands[c])

    def test_scalar_tau_many_matches_scalar_tau(self):
        from repro.core import scalar_tau_many
        job = _job(0, 4)
        p = np.array([0, 1, 2, 5, 9])
        n_srv = np.array([1, 2, 1, 3, 4])
        many = scalar_tau_many(CL, job, p, n_srv)
        for i in range(len(p)):
            assert many[i] == scalar_tau(CL, job, int(p[i]), int(n_srv[i]))

    def test_probe_tau_many_rejects_bad_stacks(self):
        job = _job(0, 4)
        inc = IncrementalEval(CL)
        with pytest.raises(ValueError):
            inc.probe_tau_many(job, np.zeros((2, CL.num_servers + 1),
                                             dtype=np.int64))
        with pytest.raises(ValueError):
            inc.probe_tau_many(job, np.zeros((2, CL.num_servers),
                                             dtype=np.int64))

    @pytest.mark.parametrize("engine", ["reference", "incremental", "batched"])
    def test_refined_rho_many_identical_across_engines(self, engine):
        rng = np.random.default_rng(11)
        cluster = philly_cluster(6, seed=11)
        jobs = philly_workload(seed=11, mix=((2, 6), (4, 3)))
        from repro.core import nominal_rho
        from repro.core.api import try_place
        from repro.core.sjf_bco import fa_ffp
        state = PlacementState(cluster, engine=engine)
        for job in jobs[:-1]:
            assert try_place(state, job, fa_ffp,
                             nominal_rho(cluster, job), 1.5, 1e6)
        probe = jobs[-1]
        cands = [np.sort(rng.choice(cluster.num_gpus, size=probe.num_gpus,
                                    replace=False)) for _ in range(5)]
        got = state.refined_rho_many(probe, cands)
        ref_state = PlacementState(cluster, engine="reference")
        for job in jobs[:-1]:
            assert try_place(ref_state, job, fa_ffp,
                             nominal_rho(cluster, job), 1.5, 1e6)
        expected = [ref_state.refined_rho(probe, g) for g in cands]
        assert got == expected


class TestWarmStart:
    def test_warm_start_schedule_is_valid(self):
        cluster, jobs, request = _philly_request(warm_start=True)
        sched = get_policy("sjf-bco")(request)
        seen = set()
        for j, gpus in sched.assignment:
            assert len(gpus) == jobs[j].num_gpus
            assert len(np.unique(gpus)) == len(gpus)
            seen.add(j)
        assert seen == set(range(len(jobs)))
        sim = simulate(cluster, jobs, sched.assignment)
        assert sim.completed == len(jobs)

    def test_warm_start_baselines_valid(self):
        cluster, jobs, request = _philly_request(warm_start=True)
        for policy in ("ff", "ls"):
            sched = get_policy(policy)(request)
            assert {j for j, _ in sched.assignment} == set(range(len(jobs)))


class TestEstimateHelpers:
    """The satellite bugfixes: dedupe + tau_bounds scalar handling."""

    def test_refined_rho_routes_through_predict_exec_time(self):
        # With no placed jobs and an empty-cluster snapshot, refined_rho,
        # estimate_exec_time and predict_exec_time are the same number for
        # every engine.
        job = _job(0, 4)
        y = np.array([2, 2, 0])
        empty_Y = np.zeros((0, CL.num_servers), dtype=np.int64)
        expected = predict_exec_time(CL, job, [], empty_Y, y)
        assert estimate_exec_time(CL, job, empty_Y, [], y) == expected
        for engine in ("reference", "incremental", "batched"):
            state = PlacementState(CL, engine=engine)
            gpus = np.array([0, 1, 4, 5])   # 2 GPUs on server 0, 2 on 1
            rho, start = state.refined_rho(job, gpus)
            assert (rho, start) == (expected, 0.0)

    def test_slots_for_clamps_phi(self):
        assert slots_for(1000, 0.01) == 10.0     # phi = 100
        assert slots_for(1000, 2.0) == 1000.0    # tau > 1 slot: phi clamps to 1
        assert slots_for(1, 0.3) == 1.0

    def test_degradation_accepts_scalars(self):
        out = degradation(0.3, 2.0)
        assert isinstance(out, float)
        assert out == pytest.approx(2.0 + 0.3 * 1.0)
        # 0-d arrays also come back as plain floats now.
        assert isinstance(degradation(0.3, np.float64(2.0)), float)
        # array inputs still return arrays
        arr = degradation(0.3, np.array([1.0, 2.0]))
        assert isinstance(arr, np.ndarray)
        # clamp below one contender
        assert degradation(0.3, 0.5) == pytest.approx(1.0)

    def test_tau_bounds_pinned_hand_computed(self):
        cluster = Cluster(capacities=(4, 4), b_intra=300.0, b_inter=1.25,
                          gpu_speed=50.0, xi1=0.7, xi2=0.002, alpha=0.3)
        job = Job(jid=0, num_gpus=4, iters=1000, grad_size=2e-3, batch=32,
                  dt_fwd=3e-4, dt_bwd=8e-3)
        share = (2e-3 / 4) * 3                      # m(w-1)/w = 1.5e-3
        compute = 3e-4 * 32 + 8e-3                  # 0.0176
        lo, hi = tau_bounds(cluster, job)
        # lower: intra bandwidth, one server
        expect_lo = 2 * share / 300.0 + share / 50.0 + 0.002 + compute
        assert lo == pytest.approx(expect_lo)
        assert lo == pytest.approx(0.019640, abs=1e-6)
        # upper: inter bandwidth degraded at k_max = xi1 * max O_s = 2.8,
        # f = k + alpha (k - 1) = 2.8 + 0.3 * 1.8 = 3.34, spread over
        # min(w, S) = 2 servers.
        k_max = 0.7 * 4
        f = k_max + 0.3 * (k_max - 1.0)
        expect_hi = 2 * share / (1.25 / f) + share / 50.0 + 0.002 * 2 + compute
        assert hi == pytest.approx(expect_hi)
        assert hi == pytest.approx(0.029646, abs=1e-6)
        assert lo < hi

    def test_tau_bounds_single_gpu_job(self):
        job = Job(jid=0, num_gpus=1, iters=100, grad_size=1e-3, batch=16,
                  dt_fwd=3e-4, dt_bwd=8e-3)
        lo, hi = tau_bounds(CL, job)
        compute = 3e-4 * 16 + 8e-3
        assert lo == pytest.approx(CL.xi2 + compute)   # no exchange terms
        assert hi == pytest.approx(CL.xi2 * 1.0 + compute)


class TestCounters:
    def test_counters_track_engines(self):
        rng = np.random.default_rng(0)
        jobs = _random_jobs(rng, 3)
        Y = np.stack([_random_placement(rng, j, CL.num_servers)
                      for j in jobs])
        reset_eval_counts()
        evaluate(CL, jobs, Y)
        assert eval_counts()["full"] == 1
        evaluate_many(CL, jobs, np.stack([Y, Y]))
        counts = eval_counts()
        assert counts["batched_calls"] == 1 and counts["batched_rows"] == 2
        inc = IncrementalEval(CL)
        row = inc.add(jobs[0], Y[0])
        inc.remove(row)
        assert eval_counts()["incremental_updates"] == 2

    def test_preemption_counters_remove_readd_probe(self):
        """The eviction-era counters: ``remove`` bumps the dedicated
        ``incremental_removes`` counter on top of ``incremental_updates``,
        a remove -> re-add round trip restores tau bit-for-bit, probes
        after it are priced like fresh ones, and ``evictions`` counts
        PlacementState surgeries (not engine updates)."""
        rng = np.random.default_rng(7)
        jobs = _random_jobs(rng, 2)
        Y = np.stack([_random_placement(rng, j, CL.num_servers)
                      for j in jobs])
        reset_eval_counts()
        inc = IncrementalEval(CL)
        r0 = inc.add(jobs[0], Y[0])
        r1 = inc.add(jobs[1], Y[1])
        tau_before = inc.tau_of(r1)
        tau0 = inc.tau_of(r0)
        counts = eval_counts()
        assert counts["incremental_removes"] == 0
        assert counts["evictions"] == 0
        inc.remove(r0)                          # remove ...
        counts = eval_counts()
        assert counts["incremental_removes"] == 1
        assert counts["incremental_updates"] == 3    # removes count as both
        r0b = inc.add(jobs[0], Y[0])            # ... re-add ...
        assert inc.tau_of(r1) == tau_before     # round trip is exact
        assert inc.tau_of(r0b) == tau0
        probes_before = eval_counts()["probes"]
        from repro.core import PlacementState
        state = PlacementState(CL, engine="incremental")
        job = jobs[0]
        gpus = np.arange(job.num_gpus)
        rho, start = state.refined_rho(job, gpus)   # ... probe
        assert eval_counts()["probes"] == probes_before + 1
        state.commit(job, gpus, rho, start, 1.5)
        from repro.core.preempt import evict
        assert evict(state, job.jid, rho / 2, 1.5) is not None
        counts = eval_counts()
        assert counts["evictions"] == 1
        # surgery is pure clock/quota arithmetic: no engine update, no
        # extra model evaluation is charged for an eviction
        assert counts["incremental_removes"] == 1
        assert counts["full"] == 0
