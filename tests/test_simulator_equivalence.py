"""The readiness-tracking simulator vs the rescan reference: event-for-event
bit-identity across seeded Philly scenarios, engines, arrival patterns,
pathological queue interleavings and horizon cutoffs.

The acceptance bar mirrors the contention-engine one: ``readiness="tracked"``
(incremental queue-head counters, the default) must reproduce
``readiness="rescan"`` (the original per-event O(J * G) scan) exactly --
same SimEvent list, same start/finish arrays, same derived metrics.
"""
import numpy as np
import pytest

from repro.core import (ScheduleRequest, get_policy, philly_cluster,
                        philly_workload, simulate)


def _assert_sims_equal(a, b):
    assert a.events == b.events
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.finish, b.finish)
    assert a.makespan == b.makespan
    assert a.avg_jct == b.avg_jct
    assert a.completed == b.completed
    assert a.horizon_hit == b.horizon_hit
    assert a.peak_contention == b.peak_contention
    assert a.busy_gpu_slots == b.busy_gpu_slots
    assert a.total_gpu_slots == b.total_gpu_slots


def _philly_case(seed, n_jobs=48, n_servers=10):
    cluster = philly_cluster(n_servers, seed=seed)
    mix = ((1, n_jobs // 3), (2, n_jobs // 6), (4, n_jobs // 4),
           (8, n_jobs // 6), (16, n_jobs // 12))
    jobs = philly_workload(seed=seed, mix=mix)
    return cluster, jobs


class TestReadinessEquivalence:
    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("engine", ["incremental", "reference"])
    def test_batch_schedules_match_event_for_event(self, seed, engine):
        cluster, jobs = _philly_case(seed)
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=2400)
        sched = get_policy("sjf-bco")(request)
        tracked = simulate(cluster, jobs, sched.assignment, engine=engine,
                           readiness="tracked")
        rescan = simulate(cluster, jobs, sched.assignment, engine=engine,
                          readiness="rescan")
        _assert_sims_equal(tracked, rescan)
        assert tracked.completed == len(jobs)

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("engine", ["incremental", "reference"])
    def test_arrival_schedules_match_event_for_event(self, seed, engine):
        cluster, jobs = _philly_case(seed)
        rng = np.random.default_rng(100 + seed)
        arrivals = rng.integers(0, 400, size=len(jobs)).astype(np.int64)
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=10**6)
        sched = get_policy("sjf-bco")(request)
        tracked = simulate(cluster, jobs, sched.assignment, engine=engine,
                           arrivals=arrivals, readiness="tracked")
        rescan = simulate(cluster, jobs, sched.assignment, engine=engine,
                          arrivals=arrivals, readiness="rescan")
        _assert_sims_equal(tracked, rescan)
        assert np.all(tracked.start >= arrivals)

    @pytest.mark.parametrize("seed", range(4))
    def test_random_contended_placements_match(self, seed):
        """Seeded random GPU sets: heavy straddling and deep FIFO queues
        exercise queue-head promotion orders the scheduler never emits."""
        cluster, jobs = _philly_case(seed, n_jobs=60, n_servers=6)
        rng = np.random.default_rng(200 + seed)
        asg = [(j.jid, rng.choice(cluster.num_gpus, size=j.num_gpus,
                                  replace=False)) for j in jobs]
        tracked = simulate(cluster, jobs, asg, readiness="tracked")
        rescan = simulate(cluster, jobs, asg, readiness="rescan")
        _assert_sims_equal(tracked, rescan)

    @pytest.mark.parametrize("horizon", [1, 37, 250, 800])
    def test_horizon_hits_match(self, horizon):
        cluster, jobs = _philly_case(1, n_jobs=36, n_servers=6)
        rng = np.random.default_rng(7)
        arrivals = rng.integers(0, 600, size=len(jobs)).astype(np.int64)
        asg = [(j.jid, rng.choice(cluster.num_gpus, size=j.num_gpus,
                                  replace=False)) for j in jobs]
        tracked = simulate(cluster, jobs, asg, arrivals=arrivals,
                           horizon=horizon, readiness="tracked")
        rescan = simulate(cluster, jobs, asg, arrivals=arrivals,
                          horizon=horizon, readiness="rescan")
        _assert_sims_equal(tracked, rescan)

    def test_unknown_readiness_mode_rejected(self):
        cluster, jobs = _philly_case(0, n_jobs=12, n_servers=4)
        asg = [(j.jid, np.arange(j.num_gpus)) for j in jobs[:1]]
        with pytest.raises(ValueError, match="readiness"):
            simulate(cluster, jobs, asg, readiness="magic")

    def test_events_tile_the_run_with_arrival_gaps(self):
        """Idle gaps are part of the event stream in both modes, so the
        windows tile [0, makespan] exactly whenever the run completes."""
        cluster, jobs = _philly_case(2, n_jobs=24, n_servers=6)
        arrivals = (np.arange(len(jobs), dtype=np.int64) * 60)
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=10**6)
        sched = get_policy("ff")(request)
        for readiness in ("tracked", "rescan"):
            sim = simulate(cluster, jobs, sched.assignment,
                           arrivals=arrivals, readiness=readiness)
            assert sim.completed == len(jobs)
            t = 0
            for e in sim.events:
                assert e.t == t, "windows must be contiguous"
                t += e.dt
            assert t == sim.makespan
