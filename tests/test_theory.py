"""Property-based checks of the §6 guarantees (Lemmas 2-4, Theorems 5-6)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Cluster, Job, ScheduleRequest, get_policy,
                        philly_cluster, philly_workload, report, simulate)

def _sjf(cluster, jobs, horizon):
    return get_policy("sjf-bco")(
        ScheduleRequest(cluster=cluster, jobs=jobs, horizon=horizon))

job_st = st.builds(
    Job,
    jid=st.just(0),
    num_gpus=st.sampled_from([1, 2, 4, 8]),
    iters=st.integers(200, 3000),
    grad_size=st.floats(5e-4, 2e-3),
    batch=st.integers(8, 64),
    dt_fwd=st.floats(2e-4, 5e-4),
    dt_bwd=st.floats(4e-3, 1.2e-2),
)


@st.composite
def instances(draw):
    n_servers = draw(st.integers(2, 6))
    caps = tuple(draw(st.sampled_from([4, 8, 16])) for _ in range(n_servers))
    cluster = Cluster(capacities=caps)
    n_jobs = draw(st.integers(1, 12))
    jobs = []
    for i in range(n_jobs):
        j = draw(job_st)
        g = min(j.num_gpus, cluster.num_gpus)
        jobs.append(Job(jid=i, num_gpus=g, iters=j.iters, grad_size=j.grad_size,
                        batch=j.batch, dt_fwd=j.dt_fwd, dt_bwd=j.dt_bwd))
    return cluster, jobs


@given(instances())
@settings(max_examples=30, deadline=None)
def test_theorem5_chain_holds(instance):
    """End-to-end: schedule exists, simulates to completion, and the actual
    makespan respects the certified n_g * varphi * (u/l) chain vs the
    work-conservation lower bound."""
    cluster, jobs = instance
    sched = _sjf(cluster, jobs, 20000)
    sim = simulate(cluster, jobs, sched.assignment)
    assert sim.completed == len(jobs)
    rep = report(cluster, jobs, sched, sim)
    assert rep.certified, (
        f"makespan {rep.makespan} > bound "
        f"{rep.approx_ratio_bound * rep.lower_bound_makespan}")


@given(instances())
@settings(max_examples=30, deadline=None)
def test_lemma2_busy_time_within_theta(instance):
    """Lemma 2: no GPU's charged busy time exceeds the returned theta."""
    cluster, jobs = instance
    sched = _sjf(cluster, jobs, 20000)
    assert sched.max_busy_time <= sched.theta + 1e-6


@given(instances())
@settings(max_examples=20, deadline=None)
def test_lemma3_makespan_bound(instance):
    """Lemma 3: actual makespan <= n_g * W_max, with W_max measured in
    *actual* execution time (the busy clocks use estimates, so we bound by
    the simulated per-job durations placed on each GPU)."""
    cluster, jobs = instance
    sched = _sjf(cluster, jobs, 20000)
    sim = simulate(cluster, jobs, sched.assignment)
    busy = np.zeros(cluster.num_gpus)
    for j, gpus in sched.assignment:
        busy[gpus] += sim.finish[j] - sim.start[j]
    n_g = max(j.num_gpus for j in jobs)
    assert sim.makespan <= n_g * busy.max() + 1e-6


def test_theorem6_runtime_scales_with_log_horizon():
    """Thm. 6: bisection adds only a log T factor. Doubling T must not blow
    up wall time (coarse smoke check, not a microbenchmark)."""
    import time
    cluster = philly_cluster(10, seed=0)
    jobs = philly_workload(seed=0)[:60]
    t0 = time.time()
    _sjf(cluster, jobs, 1200)
    t1 = time.time()
    _sjf(cluster, jobs, 2400)
    t2 = time.time()
    assert (t2 - t1) < 4 * max(t1 - t0, 0.05)


def test_iterations_conserved():
    """Eq. (9): a job finishes exactly when accumulated phi reaches F_j —
    finishing earlier than its contention-free optimum is impossible."""
    cluster = philly_cluster(8, seed=3)
    jobs = philly_workload(seed=3)[:40]
    sched = _sjf(cluster, jobs, 20000)
    sim = simulate(cluster, jobs, sched.assignment)
    from repro.core import nominal_rho
    for j in jobs:
        dur = sim.finish[j.jid] - sim.start[j.jid]
        assert dur >= nominal_rho(cluster, j) - 1


def test_contention_advantage_grows_with_xi1():
    """Beyond-paper ablation: SJF-BCO's advantage over LS widens as the
    contention coefficient grows (the paper's central thesis)."""
    from repro.core.extensions import contention_sweep
    rows = contention_sweep(seed=1, xi1s=(0.2, 1.0))
    assert rows[-1]["advantage_vs_ls"] > rows[0]["advantage_vs_ls"]
    assert all(r["advantage_vs_ls"] > 1.0 for r in rows)


def test_adaptive_variant_trades_makespan_for_jct():
    """SJF-BCO+ (greedy per-job pack-or-spread) must improve avg JCT; the
    paper's kappa-level control stays better on makespan."""
    cluster = philly_cluster(20, seed=1)
    jobs = philly_workload(seed=1)
    request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)
    base = simulate(cluster, jobs, get_policy("sjf-bco")(request).assignment)
    plus = simulate(cluster, jobs,
                    get_policy("sjf-bco-adaptive")(request).assignment)
    assert plus.avg_jct < base.avg_jct
    assert base.makespan <= plus.makespan
