"""RAR collective tests (paper §3): correctness vs psum, the 2(w-1)
communication schedule, and bandwidth-optimality of the exchanged volume.

Multi-device cases run in subprocesses so the forced host-device count
never leaks into other tests (the dry-run is the only place 512 devices
are allowed)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

pytest.importorskip("repro.dist", reason="distributed substrate not present")
from repro.dist.rar import exchange_bytes_per_worker


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr
    return out.stdout


class TestRingAllReduce:
    @pytest.mark.parametrize("w", [2, 4, 8])
    def test_matches_psum(self, w):
        out = _run(f"""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_all_reduce
            mesh = jax.make_mesh(({w},), ("data",))
            x = jnp.arange({w}*37, dtype=jnp.float32).reshape({w}, 37)
            def g(x):
                return jax.lax.psum(x, "data") - ring_all_reduce(x, "data")
            d = jax.jit(jax.shard_map(g, mesh=mesh, in_specs=P("data"),
                                      out_specs=P("data")))(x)
            print("MAXDIFF", float(jnp.abs(d).max()))
        """, devices=w)
        assert "MAXDIFF 0.0" in out

    def test_schedule_is_2_w_minus_1_permutes(self):
        """The compiled HLO must contain exactly 2(w-1) collective-permute
        ops -- the Share-Reduce + Share-Only phases of Fig. 1."""
        out = _run("""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_all_reduce
            mesh = jax.make_mesh((8,), ("data",))
            x = jnp.zeros((8, 64), jnp.float32)
            c = jax.jit(jax.shard_map(lambda x: ring_all_reduce(x, "data"),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"))
                ).lower(x).compile()
            print("PERMUTES", c.as_text().count("collective-permute("))
        """)
        assert "PERMUTES 14" in out

    def test_reduce_scatter_and_all_gather_phases(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_reduce_scatter, ring_all_gather
            mesh = jax.make_mesh((4,), ("data",))
            x = jnp.arange(4*8, dtype=jnp.float32).reshape(4, 8)
            def f(x):
                chunk = ring_reduce_scatter(x[0], "data")
                return ring_all_gather(chunk, "data")[None]
            out = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data"),
                                        out_specs=P("data")))(x)
            exp = np.repeat(np.asarray(x).sum(0)[None], 4, 0)
            np.testing.assert_allclose(np.asarray(out), exp, rtol=1e-6)
            print("PHASES_OK")
        """, devices=4)
        assert "PHASES_OK" in out

    def test_grad_sync_in_training(self):
        """End-to-end: RAR data-parallel step == single-device step on the
        concatenated batch (gradient averaging equivalence)."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.models import build_model
            from repro.dist.steps import make_rar_train_step, make_train_step
            from repro.optim.adamw import AdamWConfig
            from repro.optim import adamw
            cfg = get_config("llama3.2-1b").reduced()
            model = build_model(cfg, max_seq=64)
            params = model.init(jax.random.PRNGKey(0))
            ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
            opt = adamw.init(ocfg, params)
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                                  (4, 32), 0, cfg.vocab)}
            mesh = jax.make_mesh((4,), ("data",))
            rar_step = make_rar_train_step(model, ocfg, mesh)
            p1, o1, m1 = rar_step(params, opt, batch)
            ref_step = make_train_step(model, ocfg)
            p2, o2, m2 = jax.jit(ref_step)(params, opt, batch)
            d = max(float(jnp.abs(a - b).max()) for a, b in zip(
                jax.tree.leaves(p1), jax.tree.leaves(p2)))
            print("LOSS_DIFF", abs(float(m1["loss"]) - float(m2["loss"])))
            print("PARAM_MAXDIFF", d)
        """, devices=4)
        loss_diff = float(out.split("LOSS_DIFF")[1].split()[0])
        assert loss_diff < 1e-6, f"loss mismatch: {loss_diff}"
        # Adam amplifies fp-reassociation noise (grads summed in ring order
        # vs one fused reduction) when v ~ 0; 2e-4 bounds one lr=1e-3 step.
        diff = float(out.split("PARAM_MAXDIFF")[1].strip())
        assert diff < 2e-4, f"RAR-DP diverged from reference: {diff}"


class TestBandwidthOptimality:
    def test_volume_asymptotically_independent_of_w(self):
        d = 1.0e9
        vols = [exchange_bytes_per_worker(d, w) for w in range(2, 257)]
        assert all(v < 2 * d for v in vols)
        assert vols[-1] / vols[0] < 2.0   # 2x total range from w=2 to w=256
        assert (vols[-1] - vols[-2]) / d < 1e-4

    def test_server_worker_scales_linearly_but_rar_does_not(self):
        """§3: SW architecture moves 2wd per iteration; RAR moves
        2d(w-1)/w per worker — constant-ish."""
        d = 1.0
        sw = [2 * w * d for w in (2, 8, 32)]
        rar = [exchange_bytes_per_worker(d, w) for w in (2, 8, 32)]
        assert sw[2] / sw[0] == 16.0
        assert rar[2] / rar[0] < 2.0
