"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle.

Sweeps shapes and dtypes per the deliverable spec and asserts allclose
against ``repro.kernels.ref``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# These run in Pallas interpret mode on CPU (the kernels default to
# interpret=True off-accelerator), so no `gpu` marker: CI runs them.

from repro.kernels import ref
from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.rmsnorm import rmsnorm as rn_kernel
from repro.kernels.swiglu import swiglu as sg_kernel


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _assert_close(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **_tol(dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,S,hd", [
        (1, 1, 128, 64), (2, 4, 256, 64), (1, 2, 512, 128), (2, 1, 128, 256),
    ])
    def test_causal_matches_ref(self, B, H, S, hd, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
        k = jax.random.normal(ks[1], (B, H, S, hd), dtype)
        v = jax.random.normal(ks[2], (B, H, S, hd), dtype)
        out = fa_kernel(q, k, v, causal=True, block_q=128, block_k=128)
        exp = ref.flash_attention(q, k, v, causal=True)
        _assert_close(out, exp, dtype)

    @pytest.mark.parametrize("window", [32, 128, 300])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (jax.random.normal(ks[i], (1, 2, 256, 64), jnp.float32)
                   for i in range(3))
        out = fa_kernel(q, k, v, causal=True, window=window,
                        block_q=64, block_k=64)
        exp = ref.flash_attention(q, k, v, causal=True, window=window)
        _assert_close(out, exp, jnp.float32)

    def test_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q, k, v = (jax.random.normal(ks[i], (1, 2, 128, 64), jnp.float32) * 3
                   for i in range(3))
        out = fa_kernel(q, k, v, causal=True, softcap=50.0,
                        block_q=64, block_k=64)
        exp = ref.flash_attention(q, k, v, causal=True, softcap=50.0)
        _assert_close(out, exp, jnp.float32)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 2, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (2, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (2, 2, 256, 64), jnp.float32)
        out = fa_kernel(q, k, v, causal=False, block_q=64, block_k=64)
        exp = ref.flash_attention(q, k, v, causal=False)
        _assert_close(out, exp, jnp.float32)

    def test_ops_wrapper_gqa_and_padding(self):
        """Model layout [B,S,H,hd], GQA repeat, non-multiple seq lens."""
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        B, S, H, K, hd = 2, 200, 8, 2, 64
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True)
        kk = jnp.repeat(k, H // K, axis=2).transpose(0, 2, 1, 3)
        vv = jnp.repeat(v, H // K, axis=2).transpose(0, 2, 1, 3)
        exp = ref.flash_attention(q.transpose(0, 2, 1, 3), kk, vv,
                                  causal=True).transpose(0, 2, 1, 3)
        _assert_close(out, exp, jnp.float32)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("rows,d", [(8, 128), (256, 512), (1024, 4096),
                                        (64, 3584)])
    def test_matches_ref(self, rows, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = jax.random.normal(ks[0], (rows, d), dtype)
        s = jax.random.normal(ks[1], (d,), dtype) + 1.0
        out = rn_kernel(x, s, block_rows=min(256, rows))
        _assert_close(out, ref.rmsnorm(x, s), dtype)

    def test_ops_wrapper_nd(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64, 128))
        s = jnp.ones((128,))
        _assert_close(ops.rmsnorm(x, s), ref.rmsnorm(
            x.reshape(-1, 128), s).reshape(x.shape), jnp.float32)


class TestSwiGLU:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("M,K,N", [(128, 512, 128), (256, 1024, 512),
                                       (128, 256, 384)])
    def test_matches_ref(self, M, K, N, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (M, K), dtype) * 0.1
        wg = jax.random.normal(ks[1], (K, N), dtype) * 0.05
        wu = jax.random.normal(ks[2], (K, N), dtype) * 0.05
        out = sg_kernel(x, wg, wu, block_m=128, block_n=128,
                        block_k=min(512, K))
        _assert_close(out, ref.swiglu(x, wg, wu), dtype)

    def test_ops_wrapper_batched(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 256)) * 0.1
        wg = jax.random.normal(jax.random.PRNGKey(2), (256, 128)) * 0.05
        wu = jax.random.normal(jax.random.PRNGKey(3), (256, 128)) * 0.05
        out = ops.swiglu(x, wg, wu)
        exp = ref.swiglu(x.reshape(-1, 256), wg, wu).reshape(2, 64, 128)
        _assert_close(out, exp, jnp.float32)


class TestKernelVsModelLayer:
    """The kernels must agree with the model's in-line reference math."""

    def test_flash_equals_model_sdpa(self):
        from repro.models.layers import _sdpa
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        B, S, H, hd = 2, 128, 4, 64
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        exp = _sdpa(q, k, v, pos, pos, causal=True, window=0, softcap=0.0,
                    compute_dtype=jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True)
        _assert_close(out.reshape(B, S, H * hd), exp, jnp.float32)


class TestTauKernel:
    """The Eq. (6)-(8) stack kernel vs the NumPy contention engines."""

    def _case(self, seed=0, n_cands=6):
        from repro.core import philly_cluster, philly_workload
        rng = np.random.default_rng(seed)
        cluster = philly_cluster(6, seed=seed)
        jobs = philly_workload(seed=seed, mix=((1, 4), (2, 4), (4, 4),
                                               (8, 2)))
        S = cluster.num_servers
        stack = np.zeros((n_cands, len(jobs), S), dtype=np.int64)
        for c in range(n_cands):
            for i, job in enumerate(jobs):
                for _ in range(job.num_gpus):
                    stack[c, i, rng.integers(S)] += 1
        return cluster, jobs, stack

    def test_tau_stack_matches_numpy_f32(self):
        """Without x64 the kernel computes in float32: approximate."""
        from repro.core.contention import _job_terms, evaluate_many
        from repro.kernels.tau import tau_stack
        cluster, jobs, stack = self._case()
        ref_model = evaluate_many(cluster, jobs, stack)
        G, share, compute = _job_terms(jobs)
        p, n_srv, tau = tau_stack(cluster, G, share, compute, stack)
        assert np.array_equal(p, ref_model.p)       # integer: exact
        np.testing.assert_allclose(tau, ref_model.tau, rtol=1e-5)

    def test_tau_backend_bit_identity_x64(self):
        """With x64, the kernel path of stack_model / evaluate_many is
        bit-identical to the NumPy engines (same op order, float64)."""
        from repro.core.contention import evaluate, evaluate_many, tau_backend
        x64_was = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        try:
            cluster, jobs, stack = self._case(seed=3)
            ref_model = evaluate_many(cluster, jobs, stack)
            with tau_backend("kernel"):
                kern = evaluate_many(cluster, jobs, stack)
            assert np.array_equal(ref_model.p, kern.p)
            assert np.array_equal(ref_model.tau, kern.tau)
            assert np.array_equal(ref_model.phi, kern.phi)
            assert np.array_equal(ref_model.bandwidth, kern.bandwidth)
            for c in range(stack.shape[0]):
                per = evaluate(cluster, jobs, stack[c])
                assert np.array_equal(per.tau, kern.tau[c])
        finally:
            jax.config.update("jax_enable_x64", x64_was)

    def test_unknown_tau_backend_rejected(self):
        from repro.core.contention import tau_backend
        with pytest.raises(ValueError, match="tau backend"):
            with tau_backend("cuda"):
                pass
