"""Behaviour tests for SJF-BCO (Algs. 1-3) and the §7 baselines, driven
through the unified scheduling API (registry + ScheduleRequest)."""
import numpy as np
import pytest

from repro.core import (Cluster, Job, ScheduleRequest, get_policy,
                        philly_cluster, philly_workload, simulate)


@pytest.fixture(scope="module")
def philly():
    cluster = philly_cluster(20, seed=1)
    jobs = philly_workload(seed=1)
    return cluster, jobs


@pytest.fixture(scope="module")
def philly_request(philly):
    cluster, jobs = philly
    return ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)


@pytest.fixture(scope="module")
def sjf_schedule(philly_request):
    return get_policy("sjf-bco")(philly_request)


def _check_valid(cluster, jobs, schedule):
    seen = set()
    for j, gpus in schedule.assignment:
        assert len(gpus) == jobs[j].num_gpus, "Eq. (1): exactly G_j GPUs"
        assert len(np.unique(gpus)) == len(gpus)
        assert np.all((0 <= gpus) & (gpus < cluster.num_gpus))
        assert j not in seen, "each job scheduled exactly once"
        seen.add(j)
    assert seen == set(range(len(jobs))), "all jobs scheduled"


class TestScheduleValidity:
    def test_sjf_bco_schedules_every_job_once(self, philly, sjf_schedule):
        cluster, jobs = philly
        _check_valid(cluster, jobs, sjf_schedule)

    def test_baselines_schedule_every_job_once(self, philly, philly_request):
        cluster, jobs = philly
        for name in ("ff", "ls", "rand"):
            _check_valid(cluster, jobs, get_policy(name)(philly_request))

    def test_server_capacity_never_exceeded(self, philly, sjf_schedule):
        # Each GPU hosts one worker at a time (FIFO queues) so per-server
        # concurrent usage is bounded by O_s by construction; verify the
        # static per-GPU assignment maps into real GPUs of real servers.
        cluster, jobs = philly
        Y = cluster.placement_matrix([g for _, g in sjf_schedule.assignment])
        assert Y.shape[1] == cluster.num_servers
        assert (Y.sum(axis=1) == [jobs[j].num_gpus
                                  for j, _ in sjf_schedule.assignment]).all()

    def test_legacy_shims_removed(self):
        # The one-release deprecation overlap is over: the free-function
        # entrypoints and the POLICIES dict are gone; the registry is the
        # only policy lookup.
        import repro.core
        import repro.core.baselines as baselines
        import repro.core.extensions as extensions
        import repro.core.online as online
        import repro.core.sjf_bco as sjf_bco_mod
        for name in ("sjf_bco", "Schedule", "first_fit", "list_scheduling",
                     "random_policy", "reserved_bandwidth",
                     "sjf_bco_adaptive"):
            assert name not in repro.core.__all__, name
        assert not hasattr(sjf_bco_mod, "sjf_bco")
        assert not hasattr(sjf_bco_mod, "Schedule")
        for name in ("POLICIES", "first_fit", "list_scheduling",
                     "random_policy", "reserved_bandwidth"):
            assert not hasattr(baselines, name), name
        assert not hasattr(extensions, "sjf_bco_adaptive")
        assert not hasattr(online, "schedule_online")

    def test_registry_covers_every_policy(self, philly):
        from repro.core import list_policies
        assert set(list_policies()) >= {"sjf-bco", "sjf-bco-adaptive",
                                        "ff", "ls", "rand", "reserved"}
        cluster, jobs = philly
        request = ScheduleRequest(cluster=cluster, jobs=jobs[:10],
                                  horizon=1200)
        _check_valid(cluster, jobs[:10], get_policy("sjf-bco")(request))


class TestSimulator:
    def test_all_jobs_complete(self, philly, sjf_schedule):
        cluster, jobs = philly
        sim = simulate(cluster, jobs, sjf_schedule.assignment)
        assert sim.completed == len(jobs)
        assert not sim.horizon_hit
        assert np.all(sim.finish >= sim.start)

    def test_single_job_runs_at_contention_free_speed(self):
        cluster = Cluster(capacities=(8, 8))
        job = Job(jid=0, num_gpus=4, iters=1000, grad_size=1e-3, batch=32,
                  dt_fwd=3e-4, dt_bwd=8e-3)
        sim = simulate(cluster, [job], [(0, np.arange(4))])
        # Fully inside server 0: B = b_intra, gamma = xi2, no contention.
        share = (1e-3 / 4) * 3
        tau = 2 * share / cluster.b_intra + share / cluster.gpu_speed \
            + cluster.xi2 + 3e-4 * 32 + 8e-3
        expected = int(np.ceil(1000 / np.floor(1 / tau)))
        assert sim.makespan == expected

    def test_contention_slows_straddling_jobs(self):
        cluster = Cluster(capacities=(4, 4))
        jobs = [Job(jid=i, num_gpus=4, iters=2000, grad_size=2e-3, batch=32,
                    dt_fwd=3e-4, dt_bwd=8e-3) for i in range(2)]
        # Both straddle: GPUs {0,1,4,5} and {2,3,6,7}.
        contended = simulate(cluster, jobs,
                             [(0, np.array([0, 1, 4, 5])),
                              (1, np.array([2, 3, 6, 7]))])
        # Each in its own server: no contention.
        packed = simulate(cluster, jobs,
                          [(0, np.arange(4)), (1, np.arange(4, 8))])
        assert contended.makespan > packed.makespan
        assert contended.peak_contention == 2
        assert packed.peak_contention == 0

    def test_gang_scheduling_serializes_conflicts(self):
        cluster = Cluster(capacities=(2,))
        jobs = [Job(jid=i, num_gpus=2, iters=100, grad_size=1e-3, batch=32,
                    dt_fwd=3e-4, dt_bwd=8e-3) for i in range(2)]
        sim = simulate(cluster, jobs, [(0, np.arange(2)), (1, np.arange(2))])
        assert sim.start[1] == sim.finish[0], "job 1 waits for job 0's GPUs"

    def test_deterministic(self, philly, sjf_schedule):
        cluster, jobs = philly
        a = simulate(cluster, jobs, sjf_schedule.assignment)
        b = simulate(cluster, jobs, sjf_schedule.assignment)
        assert a.makespan == b.makespan
        assert np.array_equal(a.finish, b.finish)
        assert np.array_equal(a.start, b.start)

    def test_horizon_hit_charges_partial_busy_slots(self):
        # A job cut off by the horizon still occupied its GPUs: utilization
        # must reflect the partial window, not report ~0.
        cluster = Cluster(capacities=(4,))
        job = Job(jid=0, num_gpus=4, iters=10**6, grad_size=1e-3, batch=32,
                  dt_fwd=3e-4, dt_bwd=8e-3)
        sim = simulate(cluster, [job], [(0, np.arange(4))], horizon=50)
        assert sim.horizon_hit
        assert sim.completed == 0
        assert sim.busy_gpu_slots > 0
        assert sim.utilization == pytest.approx(1.0)

    def test_events_cover_the_run(self, philly, sjf_schedule):
        cluster, jobs = philly
        sim = simulate(cluster, jobs, sjf_schedule.assignment)
        assert sim.events, "piecewise-constant windows recorded"
        assert max(e.contention for e in sim.events) == sim.peak_contention
        assert sim.events[-1].t + sim.events[-1].dt == sim.makespan
        assert sim.mean_contention <= sim.peak_contention


class TestPaperClaims:
    """Fig. 4 qualitative claims: SJF-BCO beats FF and RAND on makespan."""

    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_sjf_bco_beats_ff_and_rand(self, seed):
        cluster = philly_cluster(20, seed=seed)
        jobs = philly_workload(seed=seed)
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)
        mk = {}
        for name in ("sjf-bco", "ff", "rand"):
            sched = get_policy(name)(request)
            mk[name] = simulate(cluster, jobs, sched.assignment).makespan
        assert mk["sjf-bco"] < mk["ff"]
        assert mk["sjf-bco"] < mk["rand"]

    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_sjf_bco_beats_or_matches_ls(self, seed):
        cluster = philly_cluster(20, seed=seed)
        jobs = philly_workload(seed=seed)
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)
        sjf = simulate(cluster, jobs,
                       get_policy("sjf-bco")(request).assignment).makespan
        ls = simulate(cluster, jobs,
                      get_policy("ls")(request).assignment).makespan
        assert sjf <= ls
