"""repro.ckpt round-trip guarantees (the training-side analogue of the
service store's journal replay: state out == state back in, exactly)."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
jnp = pytest.importorskip("jax.numpy")

from repro import ckpt


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embed": {"w": jnp.asarray(rng.normal(size=(8, 4)),
                                   dtype=jnp.float32)},
        "blocks": [
            {"kernel": jnp.asarray(rng.normal(size=(4, 4)),
                                   dtype=jnp.float32),
             "bias": jnp.zeros((4,), dtype=jnp.float32)},
            {"kernel": jnp.asarray(rng.normal(size=(4, 4)),
                                   dtype=jnp.float32),
             "bias": jnp.ones((4,), dtype=jnp.float32)},
        ],
        "head": jnp.asarray(rng.normal(size=(4, 2)), dtype=jnp.float32),
    }


class TestCkptRoundtrip:
    def test_nested_pytree_bitwise(self, tmp_path):
        params = _params()
        path = str(tmp_path / "state.npz")
        ckpt.save(path, params=params, step=17)
        back, opt, step = ckpt.load(path, params_like=params)
        assert step == 17 and opt is None
        flat_a = jax.tree_util.tree_leaves(params)
        flat_b = jax.tree_util.tree_leaves(back)
        assert len(flat_a) == len(flat_b)
        for a, b in zip(flat_a, flat_b):
            assert a.dtype == b.dtype
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_opt_state_roundtrip(self, tmp_path):
        params = _params(1)
        opt = {"mu": jax.tree_util.tree_map(jnp.zeros_like, params),
               "nu": jax.tree_util.tree_map(jnp.ones_like, params)}
        path = str(tmp_path / "state.npz")
        ckpt.save(path, params=params, opt_state=opt, step=3)
        p2, o2, step = ckpt.load(path, params_like=params, opt_like=opt)
        assert step == 3
        for a, b in zip(jax.tree_util.tree_leaves(opt),
                        jax.tree_util.tree_leaves(o2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_double_roundtrip_stable(self, tmp_path):
        """save -> load -> save -> load is a fixed point."""
        params = _params(2)
        p1_path, p2_path = str(tmp_path / "a.npz"), str(tmp_path / "b.npz")
        ckpt.save(p1_path, params=params, step=1)
        p1, _, _ = ckpt.load(p1_path, params_like=params)
        ckpt.save(p2_path, params=p1, step=1)
        p2, _, _ = ckpt.load(p2_path, params_like=params)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_missing_key_fails_loud(self, tmp_path):
        params = _params(3)
        path = str(tmp_path / "state.npz")
        ckpt.save(path, params=params)
        bigger = dict(params, extra=jnp.zeros((2,)))
        with pytest.raises(KeyError):
            ckpt.load(path, params_like=bigger)
