"""Edge cases beyond tests/test_rar.py: the w=1 degenerate ring, the §3
exchange-volume formula at its boundaries, non-divisible tensor sizes
through the ring_reduce_scatter zero-padding, and non-power-of-two rings.

Multi-device cases run in subprocesses (same pattern as test_rar.py) so
the forced host-device count never leaks into other tests."""
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("repro.dist", reason="distributed substrate not present")
from repro.dist.rar import exchange_bytes_per_worker


def _run(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr
    return out.stdout


class TestExchangeVolumeEdges:
    def test_degenerate_single_worker_ring_is_free(self):
        """w=1: no neighbours, no exchange — exactly 0 bytes."""
        assert exchange_bytes_per_worker(1.0e9, 1) == 0.0

    def test_invalid_ring_width_rejected(self):
        with pytest.raises(ValueError):
            exchange_bytes_per_worker(1.0, 0)
        with pytest.raises(ValueError):
            exchange_bytes_per_worker(1.0, -3)

    @pytest.mark.parametrize("w", [2, 3, 5, 8, 64, 1024])
    def test_closed_form(self, w):
        d = 3.5e8
        assert exchange_bytes_per_worker(d, w) == pytest.approx(
            2.0 * d * (w - 1) / w)

    def test_monotone_in_w_and_bounded(self):
        d = 1.0
        vols = [exchange_bytes_per_worker(d, w) for w in range(1, 200)]
        assert all(b > a for a, b in zip(vols, vols[1:]))   # strictly up
        assert all(v < 2 * d for v in vols)                 # sup = 2d

    def test_zero_gradient(self):
        assert exchange_bytes_per_worker(0.0, 8) == 0.0


class TestDegenerateRingCollectives:
    def test_w1_ring_is_identity(self):
        """A 1-worker ring must not emit any collective-permute and must
        return the input unchanged (reduce over one contributor)."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_all_reduce
            mesh = jax.make_mesh((1,), ("data",))
            x = jnp.arange(7, dtype=jnp.float32)[None]
            f = jax.jit(jax.shard_map(lambda x: ring_all_reduce(x, "data"),
                mesh=mesh, in_specs=P("data"), out_specs=P("data")))
            np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))
            txt = f.lower(x).compile().as_text()
            print("PERMUTES", txt.count("collective-permute("))
        """, devices=1)
        assert "PERMUTES 0" in out


class TestPaddingNonDivisible:
    @pytest.mark.parametrize("n", [10, 37, 129])
    def test_all_reduce_matches_psum_when_w_does_not_divide(self, n):
        """ring sizes that do NOT divide the tensor exercise the zero-pad
        path of ring_reduce_scatter; the result must still equal psum."""
        out = _run(f"""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_all_reduce
            mesh = jax.make_mesh((4,), ("data",))
            x = jnp.arange(4 * {n}, dtype=jnp.float32).reshape(4, {n})
            def g(x):
                return jax.lax.psum(x, "data") - ring_all_reduce(x, "data")
            d = jax.jit(jax.shard_map(g, mesh=mesh, in_specs=P("data"),
                                      out_specs=P("data")))(x)
            print("MAXDIFF", float(jnp.abs(d).max()))
        """, devices=4)
        assert "MAXDIFF 0.0" in out

    def test_reduce_scatter_chunks_cover_padded_sum(self):
        """Worker i owns chunk i of the zero-padded flattened sum; the
        trimmed concatenation reconstructs the full reduction."""
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_reduce_scatter
            w, n = 4, 10                      # ceil(10/4)=3 -> 2 pad zeros
            mesh = jax.make_mesh((w,), ("data",))
            x = jnp.arange(w * n, dtype=jnp.float32).reshape(w, n)
            chunks = jax.jit(jax.shard_map(
                lambda x: ring_reduce_scatter(x[0], "data")[None],
                mesh=mesh, in_specs=P("data"), out_specs=P("data")))(x)
            assert chunks.shape == (w, 3), chunks.shape
            flat = np.asarray(chunks).reshape(-1)
            np.testing.assert_allclose(flat[:n], np.asarray(x).sum(0))
            np.testing.assert_array_equal(flat[n:], 0.0)   # the padding
            print("PAD_OK")
        """, devices=4)
        assert "PAD_OK" in out

    def test_multidim_tensor_keeps_shape(self):
        out = _run("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_all_reduce
            mesh = jax.make_mesh((4,), ("data",))
            x = jnp.arange(4 * 5 * 3, dtype=jnp.float32).reshape(4, 5, 3)
            def g(x):
                y = ring_all_reduce(x[0], "data")
                assert y.shape == (5, 3), y.shape
                return (jax.lax.psum(x[0], "data") - y)[None]
            d = jax.jit(jax.shard_map(g, mesh=mesh, in_specs=P("data"),
                                      out_specs=P("data")))(x)
            print("MAXDIFF", float(jnp.abs(d).max()))
        """, devices=4)
        assert "MAXDIFF 0.0" in out


class TestNonPowerOfTwoRing:
    def test_w3_matches_psum_and_permute_count(self):
        """2(w-1) = 4 permutes at w=3, correctness included — rings are not
        restricted to power-of-two widths."""
        out = _run("""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.dist.rar import ring_all_reduce
            mesh = jax.make_mesh((3,), ("data",))
            x = jnp.arange(3 * 11, dtype=jnp.float32).reshape(3, 11)
            f = jax.jit(jax.shard_map(
                lambda x: jax.lax.psum(x, "data") - ring_all_reduce(x, "data"),
                mesh=mesh, in_specs=P("data"), out_specs=P("data")))
            print("MAXDIFF", float(jnp.abs(f(x)).max()))
            g = jax.jit(jax.shard_map(lambda x: ring_all_reduce(x, "data"),
                mesh=mesh, in_specs=P("data"), out_specs=P("data")))
            print("PERMUTES", g.lower(x).compile().as_text()
                  .count("collective-permute("))
        """, devices=3)
        assert "MAXDIFF 0.0" in out
        assert "PERMUTES 4" in out
