"""Substrate tests: optimizer, data pipeline, checkpointing, serving loop."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("repro.dist", reason="distributed substrate not present")
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # only the property test needs hypothesis
    HAVE_HYPOTHESIS = False

from repro import ckpt
from repro.configs import get_config
from repro.data import DataConfig, batch_iterator, make_batch
from repro.dist.steps import make_serve_step, make_train_step
from repro.models import build_model
from repro.models.config import InputShape
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig


class TestAdamW:
    def _setup(self, **kw):
        cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100, **kw)
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        return cfg, params, adamw.init(cfg, params)

    def test_descends_quadratic(self):
        cfg = AdamWConfig(lr=5e-2, warmup_steps=0, total_steps=400,
                          weight_decay=0.0)
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        state = adamw.init(cfg, params)

        def loss(p):
            return jnp.sum(jnp.square(p["w"] - 2.0)) + jnp.sum(jnp.square(p["b"] + 1.0))

        l0 = float(loss(params))
        step = jax.jit(lambda p, s: adamw.apply(cfg, jax.grad(loss)(p), p, s)[:2])
        for _ in range(400):
            params, state = step(params, state)
        assert float(loss(params)) < 0.05 * l0

    def test_clip_bounds_update(self):
        cfg, params, state = self._setup(clip_norm=1.0)
        grads = jax.tree.map(lambda p: jnp.full_like(p, 1e6), params)
        new_params, _, m = adamw.apply(cfg, grads, params, state)
        assert float(m["grad_norm"]) > 1e6
        delta = max(float(jnp.abs(a - b).max())
                    for a, b in zip(jax.tree.leaves(params),
                                    jax.tree.leaves(new_params)))
        assert delta < 1.0  # clipped + Adam-normalised

    def test_schedule_warmup_and_cosine(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(101)]
        assert lrs[5] < lrs[10]                    # warming up
        assert lrs[10] == pytest.approx(1.0, abs=0.05)
        assert lrs[100] == pytest.approx(cfg.min_lr_ratio, abs=0.05)

    def test_bf16_moments(self):
        cfg, params, state = self._setup(moment_dtype="bfloat16")
        assert state["m"]["w"].dtype == jnp.bfloat16

    def test_grad_accumulation_matches_full_batch(self):
        cfg = get_config("llama3.2-1b").reduced()
        model = build_model(cfg, max_seq=32)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 32), 0, cfg.vocab)}
        o1 = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5,
                         grad_accum_steps=1)
        o4 = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5,
                         grad_accum_steps=4)
        p1, _, m1 = jax.jit(make_train_step(model, o1))(
            params, adamw.init(o1, params), batch)
        p4, _, m4 = jax.jit(make_train_step(model, o4))(
            params, adamw.init(o4, params), batch)
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
        d = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
        assert d < 2e-4  # fp reassociation through Adam only


class TestData:
    def test_deterministic_by_step(self):
        cfg = get_config("llama3.2-1b").reduced()
        shape = InputShape("t", 16, 4, "train")
        a = make_batch(cfg, shape, 7)
        b = make_batch(cfg, shape, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = make_batch(cfg, shape, 8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_tokens_in_vocab_and_zipf_skewed(self):
        cfg = get_config("llama3.2-1b").reduced()
        shape = InputShape("t", 256, 16, "train")
        toks = make_batch(cfg, shape, 0)["tokens"]
        assert toks.min() >= 0 and toks.max() < cfg.vocab
        # Zipf: low ids should be much more frequent than high ids
        low = (toks < cfg.vocab // 10).mean()
        assert low > 0.5

    def test_family_specific_keys(self):
        shape = InputShape("t", 16, 2, "train")
        vlm = make_batch(get_config("internvl2-1b").reduced(), shape, 0)
        assert set(vlm) == {"tokens", "patches"}
        audio = make_batch(get_config("whisper-tiny").reduced(), shape, 0)
        assert set(audio) == {"frames", "tokens"}

    def test_iterator_advances(self):
        cfg = get_config("llama3.2-1b").reduced()
        it = batch_iterator(cfg, InputShape("t", 16, 2, "train"))
        b0, b1 = next(it), next(it)
        assert not np.array_equal(b0["tokens"], b1["tokens"])


class TestCheckpoint:
    def test_roundtrip_params_and_opt(self):
        cfg = get_config("xlstm-350m").reduced()
        model = build_model(cfg, max_seq=32)
        params = model.init(jax.random.PRNGKey(3))
        ocfg = AdamWConfig()
        opt = adamw.init(ocfg, params)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            ckpt.save(path, params=params, opt_state=opt, step=42)
            p2, o2, step = ckpt.load(path, params_like=params, opt_like=opt)
        assert step == 42
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_rejected(self):
        params = {"w": jnp.ones((2, 2))}
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ck.npz")
            ckpt.save(path, params=params)
            with pytest.raises(ValueError):
                ckpt.load(path, params_like={"w": jnp.ones((3, 3))})


class TestServingLoop:
    def test_greedy_decode_is_deterministic(self):
        cfg = get_config("llama3.2-1b").reduced()
        model = build_model(cfg, max_seq=32)
        params = model.init(jax.random.PRNGKey(0))
        serve = jax.jit(make_serve_step(model))

        def gen():
            cache = model.init_cache(2, 32)
            tok = jnp.zeros((2,), jnp.int32)
            toks = []
            for pos in range(8):
                tok, _, cache = serve(params, cache, tok,
                                      jnp.full((2,), pos, jnp.int32))
                toks.append(np.asarray(tok))
            return np.stack(toks)

        np.testing.assert_array_equal(gen(), gen())

    def test_rolling_cache_window_decode(self):
        """long_500k mechanics: cache smaller than the sequence rolls and
        still decodes finite values past the wrap point."""
        cfg = get_config("gemma2-9b").reduced()
        model = build_model(cfg, max_seq=64)
        params = model.init(jax.random.PRNGKey(0))
        serve = jax.jit(make_serve_step(model))
        slots = 8                                  # tiny rolling window
        cache = model.init_cache(1, slots)
        tok = jnp.zeros((1,), jnp.int32)
        for pos in range(20):                      # wraps 2.5 times
            tok, logits, cache = serve(params, cache, tok,
                                       jnp.full((1,), pos, jnp.int32))
            assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        # all slot positions within the last window
        pos_arr = np.asarray(jax.tree.leaves(cache["kv"].pos)[0])
        assert pos_arr.max() == 19 and pos_arr.min() >= 12


def _loss_is_finite_for_seed(seed):
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg, max_seq=16)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, InputShape("t", 16, 2, "train"), 0,
                       DataConfig(seed=seed))
    loss, _ = jax.jit(model.loss_fn)(params, jax.tree.map(jnp.asarray, batch))
    assert np.isfinite(float(loss))


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_loss_finite_for_any_data_seed(seed):
        """Property: the training loss is finite for arbitrary data."""
        _loss_is_finite_for_seed(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 7, 2**31 - 1])
    def test_loss_finite_for_any_data_seed(seed):
        """Fallback sample of the property when hypothesis is absent."""
        _loss_is_finite_for_seed(seed)


class TestInt8KVCache:
    def test_decode_matches_fp_cache(self):
        import dataclasses
        cfg = get_config("llama3.2-1b").reduced()
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        m = build_model(cfg, max_seq=32)
        m8 = build_model(cfg8, max_seq=32)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8,), 0, cfg.vocab)
        c, c8 = m.init_cache(1, 16), m8.init_cache(1, 16)
        d = jax.jit(m.decode_step)
        d8 = jax.jit(m8.decode_step)
        for pos in range(8):
            l, c = d(params, c, toks[pos][None],
                     jnp.asarray([pos], jnp.int32))
            l8, c8 = d8(params, c8, toks[pos][None],
                        jnp.asarray([pos], jnp.int32))
            assert float(jnp.abs(l - l8).max()) < 0.5
            assert int(l.argmax()) == int(l8.argmax())

    def test_cache_is_actually_int8(self):
        import dataclasses
        cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                                  kv_cache_dtype="int8")
        m = build_model(cfg, max_seq=32)
        cache = m.init_cache(1, 16)
        k = jax.tree.leaves(cache["kv"].k)[0]
        assert k.dtype == jnp.int8
        assert cache["kv"].k_scale is not None
