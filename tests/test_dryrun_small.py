"""Miniature dry-run: the full lower+compile+roofline path on an 8-device
(2,2,2) mesh in a subprocess — fast CI coverage of launch/dryrun.py and
launch/roofline.py without the 512-device compile times."""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 8, naive: bool = False) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    if naive:
        env["REPRO_NAIVE_SHARDING"] = "1"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_COMMON = """
import jax, dataclasses
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, input_specs, INPUT_SHAPES
from repro.dist import sharding as shd
from repro.dist.steps import make_serve_step, make_train_step
from repro.launch import roofline
from repro.models import build_model
from repro.models.config import InputShape
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
"""


class TestMiniDryrun:
    @pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-moe-16b",
                                      "xlstm-350m", "whisper-tiny"])
    def test_train_step_lowers_and_compiles(self, arch):
        out = _run(_COMMON + f"""
cfg = get_config("{arch}").reduced()
shape = InputShape("mini", 64, 8, "train")
model = build_model(cfg, max_seq=64)
params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
p_shard = shd.named(shd.param_specs(params_sds, mesh, cfg), mesh)
ocfg = AdamWConfig()
opt_sds = jax.eval_shape(partial(adamw.init, ocfg), params_sds)
o_shard = shd.named(shd.param_specs(opt_sds, mesh, cfg), mesh)
batch_sds = input_specs(cfg, shape)
b_shard = shd.named(shd.batch_specs(batch_sds, mesh), mesh)
step = make_train_step(model, ocfg)
with jax.set_mesh(mesh):
    c = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None)
                ).lower(params_sds, opt_sds, batch_sds).compile()
flops, byts = roofline.cost_terms(c)
assert flops > 0 and byts > 0
txt = c.as_text()
xf, xb = roofline.loop_cost_correction(txt)
stats = roofline.parse_collectives(txt)
print("OK", flops + xf, stats.total_bytes)
""")
        assert "OK" in out

    def test_decode_step_lowers_with_cache_sharding(self):
        out = _run(_COMMON + """
cfg = get_config("llama3.2-1b").reduced()
model = build_model(cfg, max_seq=64)
params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
p_shard = shd.named(shd.param_specs(params_sds, mesh, cfg), mesh)
cache_sds = jax.eval_shape(lambda: model.init_cache(8, 64))
c_shard = shd.named(shd.cache_specs(cache_sds, mesh), mesh)
serve = make_serve_step(model)
tok = jax.ShapeDtypeStruct((8,), jax.numpy.int32)
with jax.set_mesh(mesh):
    c = jax.jit(serve, in_shardings=(p_shard, c_shard, None, None),
                out_shardings=(None, None, c_shard), donate_argnums=(1,)
                ).lower(params_sds, cache_sds, tok, tok).compile()
print("OK", c.memory_analysis().temp_size_in_bytes >= 0)
""")
        assert "OK" in out

    def test_naive_vs_optimized_sharding_both_compile(self):
        code = _COMMON + """
cfg = get_config("internvl2-1b").reduced()
shape = InputShape("mini", 64, 8, "train")
model = build_model(cfg, max_seq=64)
params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
p_shard = shd.named(shd.param_specs(params_sds, mesh, cfg), mesh)
batch_sds = input_specs(cfg, shape)
b_shard = shd.named(shd.batch_specs(batch_sds, mesh), mesh)
with jax.set_mesh(mesh):
    c = jax.jit(model.prefill, in_shardings=(p_shard, b_shard)
                ).lower(params_sds, batch_sds).compile()
print("OK")
"""
        assert "OK" in _run(code, naive=False)
        assert "OK" in _run(code, naive=True)


class TestRooflineParser:
    def test_loop_multiplier_and_collective_expansion(self):
        """Scan of matmuls sharded over a mesh: the parser must expand the
        while trip count for both FLOPs and collective bytes."""
        out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import roofline

mesh = jax.make_mesh((8,), ("model",))
x = jnp.zeros((64, 64))
ws = jnp.zeros((16, 64, 64))

def f(x, ws):
    def body(x, w):
        return x @ w, None
    y, _ = jax.lax.scan(body, x, ws)
    return y

s = NamedSharding(mesh, P(None, "model"))
ws_s = NamedSharding(mesh, P(None, None, "model"))
c = jax.jit(f, in_shardings=(s, ws_s)).lower(x, ws).compile()
txt = c.as_text()
base_flops, _ = roofline.cost_terms(c)
xf, xb = roofline.loop_cost_correction(txt)
total = base_flops + xf
expected = 16 * 2 * 64 * 64 * 64 / 8      # 16 iterations, sharded /8
ratio = total / expected
assert 0.5 < ratio < 3.0, (total, expected)
stats = roofline.parse_collectives(txt)
print("OK", ratio, stats.total_count)
""")
        assert "OK" in out

    def test_invariant_weights_not_charged_per_iteration(self):
        from repro.launch.roofline import _invariant_names
        body = """
  %p = (f32[8,8], f32[4,8,8], s32[]) parameter(0)
  %w = f32[8,8]{1,0} get-tuple-element(%p), index=0
  %xs = f32[4,8,8]{2,1,0} get-tuple-element(%p), index=1
  %i = s32[] get-tuple-element(%p), index=2
  %x = f32[8,8]{1,0} dynamic-slice(%xs, %i), dynamic_slice_sizes={1,8,8}
  %y = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (f32[8,8], f32[4,8,8], s32[]) tuple(%w, %xs, %i)
"""
        inv = _invariant_names(body)
        assert "w" in inv and "xs" in inv
        assert "i" in inv  # also passed through

    def test_dtype_table_covers_common_types(self):
        from repro.launch.roofline import _DTYPE_BYTES
        for dt, n in [("bf16", 2), ("f32", 4), ("s32", 4), ("pred", 1)]:
            assert _DTYPE_BYTES[dt] == n
