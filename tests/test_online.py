"""Online (dynamic-arrival) scheduling through the unified API + the
flash-kernel model path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (ScheduleRequest, get_policy, philly_cluster,
                        philly_workload, simulate)
from repro.core.online import poisson_arrivals, run_online, stream_request


class TestOnlineScheduling:
    @pytest.mark.parametrize("rate", [0.2, 0.5, 2.0])
    def test_all_jobs_complete_after_their_arrival(self, rate):
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        stream = poisson_arrivals(jobs, rate=rate, seed=1)
        _, sim = run_online(cluster, stream)
        assert sim.completed == len(jobs)
        arr = {a.job.jid: a.arrival for a in stream}
        for j in jobs:
            assert sim.start[j.jid] >= arr[j.jid], "started before arrival"

    def test_high_rate_approaches_batch_quality(self):
        """As the arrival rate -> infinity the stream degenerates to the
        batch setting; online should be within ~2.5x of offline SJF-BCO
        (it lacks the theta bisection + SJF sort)."""
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)
        offline = simulate(cluster, jobs,
                           get_policy("sjf-bco")(request).assignment).makespan
        stream = poisson_arrivals(jobs, rate=50.0, seed=1)
        _, sim = run_online(cluster, stream)
        assert sim.makespan < 2.5 * offline

    def test_low_rate_tracks_arrivals(self):
        """At low load the makespan is dominated by the last arrival, not
        by queueing: drain time stays small."""
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        stream = poisson_arrivals(jobs, rate=0.2, seed=1)
        _, sim = run_online(cluster, stream)
        last = max(a.arrival for a in stream)
        assert sim.makespan >= last
        assert sim.makespan < last + 400   # bounded drain

    def test_assignment_respects_capacity(self):
        cluster = philly_cluster(4, seed=2)
        jobs = philly_workload(seed=2)[:20]
        stream = poisson_arrivals(jobs, rate=0.5, seed=2)
        request = stream_request(cluster, stream)
        asg = get_policy("sjf-bco")(request).assignment
        for j, gpus in asg:
            assert len(np.unique(gpus)) == len(gpus)
            assert np.all(gpus < cluster.num_gpus)

    def test_every_policy_handles_arrivals(self):
        """The unified code path: each registered policy accepts an
        arrival-carrying request through the same signature."""
        from repro.core import list_policies
        cluster = philly_cluster(6, seed=3)
        jobs = philly_workload(seed=3)[:24]
        jobs = [dataclasses.replace(j, jid=i) for i, j in enumerate(jobs)]
        arrivals = np.arange(len(jobs), dtype=np.int64) * 2
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=10**6)
        for name in list_policies():
            sched = get_policy(name)(request)
            sim = simulate(cluster, jobs, sched.assignment, arrivals=arrivals)
            assert sim.completed == len(jobs), name
            assert np.all(sim.start >= arrivals), name

    def test_schedule_online_shim_warns(self):
        cluster = philly_cluster(4, seed=2)
        jobs = philly_workload(seed=2)[:10]
        jobs = [dataclasses.replace(j, jid=i) for i, j in enumerate(jobs)]
        stream = poisson_arrivals(jobs, rate=0.5, seed=2)
        from repro.core.online import schedule_online
        with pytest.deprecated_call():
            asg = schedule_online(cluster, stream)
        assert len(asg) == len(jobs)


class TestFlashKernelModelPath:
    def test_prefill_matches_jnp_path(self):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                                  compute_dtype="float32")
        cfg_k = dataclasses.replace(cfg, use_flash_kernel=True)
        m = build_model(cfg, 256)
        mk = build_model(cfg_k, 256)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 256), 0, cfg.vocab)}
        a = np.asarray(jax.jit(m.prefill)(params, batch), np.float32)
        b = np.asarray(jax.jit(mk.prefill)(params, batch), np.float32)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
