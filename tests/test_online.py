"""Online (dynamic-arrival) scheduling through the unified API + the
flash-kernel model path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (Cluster, Job, ScheduleRequest, get_policy,
                        philly_cluster, philly_workload, simulate)
from repro.core.online import poisson_arrivals, run_online, stream_request


class TestOnlineScheduling:
    @pytest.mark.parametrize("rate", [0.2, 0.5, 2.0])
    def test_all_jobs_complete_after_their_arrival(self, rate):
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        stream = poisson_arrivals(jobs, rate=rate, seed=1)
        _, sim = run_online(cluster, stream)
        assert sim.completed == len(jobs)
        arr = {a.job.jid: a.arrival for a in stream}
        for j in jobs:
            assert sim.start[j.jid] >= arr[j.jid], "started before arrival"

    def test_high_rate_approaches_batch_quality(self):
        """As the arrival rate -> infinity the stream degenerates to the
        batch setting; online should be within ~2.5x of offline SJF-BCO
        (it lacks the theta bisection + SJF sort)."""
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)
        offline = simulate(cluster, jobs,
                           get_policy("sjf-bco")(request).assignment).makespan
        stream = poisson_arrivals(jobs, rate=50.0, seed=1)
        _, sim = run_online(cluster, stream)
        assert sim.makespan < 2.5 * offline

    def test_low_rate_tracks_arrivals(self):
        """At low load the makespan is dominated by the last arrival, not
        by queueing: drain time stays small."""
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        stream = poisson_arrivals(jobs, rate=0.2, seed=1)
        _, sim = run_online(cluster, stream)
        last = max(a.arrival for a in stream)
        assert sim.makespan >= last
        assert sim.makespan < last + 400   # bounded drain

    def test_assignment_respects_capacity(self):
        cluster = philly_cluster(4, seed=2)
        jobs = philly_workload(seed=2)[:20]
        stream = poisson_arrivals(jobs, rate=0.5, seed=2)
        request = stream_request(cluster, stream)
        asg = get_policy("sjf-bco")(request).assignment
        for j, gpus in asg:
            assert len(np.unique(gpus)) == len(gpus)
            assert np.all(gpus < cluster.num_gpus)

    def test_every_policy_handles_arrivals(self):
        """The unified code path: each registered policy accepts an
        arrival-carrying request through the same signature."""
        from repro.core import list_policies
        cluster = philly_cluster(6, seed=3)
        jobs = philly_workload(seed=3)[:24]
        jobs = [dataclasses.replace(j, jid=i) for i, j in enumerate(jobs)]
        arrivals = np.arange(len(jobs), dtype=np.int64) * 2
        request = ScheduleRequest(cluster=cluster, jobs=jobs,
                                  arrivals=arrivals, horizon=10**6)
        for name in list_policies():
            sched = get_policy(name)(request)
            sim = simulate(cluster, jobs, sched.assignment, arrivals=arrivals,
                           quotas=sched.quotas)
            assert sim.completed == len(jobs), name
            assert np.all(sim.start >= arrivals), name

    def test_avg_jct_measures_time_in_system(self):
        """avg_jct under arrivals is mean(finish - arrival), not the mean
        absolute finish slot (the two only coincide when everything
        arrives at t=0)."""
        cluster = Cluster(capacities=(2,))
        jobs = [Job(jid=i, num_gpus=2, iters=100, grad_size=1e-3, batch=32,
                    dt_fwd=3e-4, dt_bwd=8e-3) for i in range(2)]
        arrivals = np.array([0, 500])
        asg = [(0, np.arange(2)), (1, np.arange(2))]
        sim = simulate(cluster, jobs, asg, arrivals=arrivals)
        assert sim.completed == 2
        per_job = (sim.finish - arrivals).astype(float)
        assert sim.avg_jct == pytest.approx(per_job.mean())
        # Staggered arrivals: the absolute-finish average is way off
        # (here each job takes ~2 slots but job 1 finishes after slot 500).
        absolute = sim.finish.astype(float).mean()
        assert abs(sim.avg_jct - absolute) > 100
        # Batch runs keep the old definition (arrival == 0 for all).
        batch = simulate(cluster, jobs, asg)
        assert batch.avg_jct == pytest.approx(
            batch.finish.astype(float).mean())

    def test_avg_queueing_delay_decomposes_jct(self):
        """avg_queueing_delay is mean(start - arrival); on a
        contention-free scenario (one gang at a time, so service time is
        the nominal rho) JCT decomposes exactly into queueing + service:
        avg_jct == avg_queueing_delay + mean(finish - start)."""
        cluster = Cluster(capacities=(2,))
        jobs = [Job(jid=i, num_gpus=2, iters=100, grad_size=1e-3, batch=32,
                    dt_fwd=3e-4, dt_bwd=8e-3) for i in range(3)]
        arrivals = np.array([0, 1, 500])
        asg = [(i, np.arange(2)) for i in range(3)]
        sim = simulate(cluster, jobs, asg, arrivals=arrivals)
        assert sim.completed == 3
        queueing = (sim.start - arrivals).astype(float).mean()
        service = (sim.finish - sim.start).astype(float).mean()
        assert sim.avg_queueing_delay == pytest.approx(queueing)
        assert sim.avg_jct == pytest.approx(
            sim.avg_queueing_delay + service)
        # job 2 arrives into an idle cluster: zero queueing for it, while
        # job 1 waited behind job 0 on the only gang-capable server
        assert sim.start[2] == arrivals[2]
        assert sim.start[1] > arrivals[1]
        # batch runs: arrival == 0 for all, so the delay is just the
        # mean start slot
        batch = simulate(cluster, jobs, asg)
        assert batch.avg_queueing_delay == pytest.approx(
            batch.start.astype(float).mean())

    def test_run_report_exposes_queueing_delay(self):
        from repro.core import (ArrivalSpec, ClusterSpec, Scenario,
                                WorkloadSpec, run_scenario)
        rep = run_scenario(Scenario(
            cluster=ClusterSpec(num_servers=4, seed=2),
            workload=WorkloadSpec(seed=2, num_jobs=12),
            arrivals=ArrivalSpec(rate=0.2, seed=2)))
        assert rep.avg_queueing_delay == rep.sim.avg_queueing_delay
        assert 0.0 <= rep.avg_queueing_delay < np.inf

    def test_idle_gap_emits_zero_active_event(self):
        """Idling to the next arrival is a recorded zero-active window, so
        time-weighted stats (ContentionStats.mean_active/mean) cover
        wall-clock time instead of silently weighting busy windows only."""
        from repro.core import ContentionStats
        cluster = Cluster(capacities=(2,))
        jobs = [Job(jid=i, num_gpus=2, iters=100, grad_size=1e-3, batch=32,
                    dt_fwd=3e-4, dt_bwd=8e-3) for i in range(2)]
        arrivals = np.array([0, 500])
        asg = [(0, np.arange(2)), (1, np.arange(2))]
        sim = simulate(cluster, jobs, asg, arrivals=arrivals)
        idle = [e for e in sim.events if e.active == 0]
        assert idle, "the arrival gap must appear in the event stream"
        assert all(e.busy_gpus == 0 and e.contention == 0 for e in idle)
        # The windows now tile the whole run, start to makespan.
        assert sum(e.dt for e in sim.events) == sim.makespan
        stats = ContentionStats.from_sim(sim)
        # ~496 of ~502 slots are idle: the wall-clock mean_active is tiny,
        # where busy-only weighting would have reported ~1.
        assert stats.mean_active < 0.1

    def test_stream_request_replaces_schedule_online(self):
        # schedule_online is gone (deprecation overlap over); the
        # registry path over a stream_request covers the same ground.
        cluster = philly_cluster(4, seed=2)
        jobs = philly_workload(seed=2)[:10]
        jobs = [dataclasses.replace(j, jid=i) for i, j in enumerate(jobs)]
        stream = poisson_arrivals(jobs, rate=0.5, seed=2)
        import repro.core.online as online
        assert not hasattr(online, "schedule_online")
        asg = get_policy("sjf-bco")(stream_request(cluster, stream)).assignment
        assert len(asg) == len(jobs)


class TestFlashKernelModelPath:
    def test_prefill_matches_jnp_path(self):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                                  compute_dtype="float32")
        cfg_k = dataclasses.replace(cfg, use_flash_kernel=True)
        m = build_model(cfg, 256)
        mk = build_model(cfg_k, 256)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 256), 0, cfg.vocab)}
        a = np.asarray(jax.jit(m.prefill)(params, batch), np.float32)
        b = np.asarray(jax.jit(mk.prefill)(params, batch), np.float32)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
