"""Online (dynamic-arrival) scheduling extension + flash-kernel model path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import philly_cluster, philly_workload, simulate, sjf_bco
from repro.core.online import poisson_arrivals, run_online, schedule_online


class TestOnlineScheduling:
    @pytest.mark.parametrize("rate", [0.2, 0.5, 2.0])
    def test_all_jobs_complete_after_their_arrival(self, rate):
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        stream = poisson_arrivals(jobs, rate=rate, seed=1)
        _, sim = run_online(cluster, stream)
        assert sim.completed == len(jobs)
        arr = {a.job.jid: a.arrival for a in stream}
        for j in jobs:
            assert sim.start[j.jid] >= arr[j.jid], "started before arrival"

    def test_high_rate_approaches_batch_quality(self):
        """As the arrival rate -> infinity the stream degenerates to the
        batch setting; online should be within ~2.5x of offline SJF-BCO
        (it lacks the theta bisection + SJF sort)."""
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        offline = simulate(cluster, jobs,
                           sjf_bco(cluster, jobs, 1200).assignment).makespan
        stream = poisson_arrivals(jobs, rate=50.0, seed=1)
        _, sim = run_online(cluster, stream)
        assert sim.makespan < 2.5 * offline

    def test_low_rate_tracks_arrivals(self):
        """At low load the makespan is dominated by the last arrival, not
        by queueing: drain time stays small."""
        cluster = philly_cluster(20, seed=1)
        jobs = philly_workload(seed=1)
        stream = poisson_arrivals(jobs, rate=0.2, seed=1)
        _, sim = run_online(cluster, stream)
        last = max(a.arrival for a in stream)
        assert sim.makespan >= last
        assert sim.makespan < last + 400   # bounded drain

    def test_assignment_respects_capacity(self):
        cluster = philly_cluster(4, seed=2)
        jobs = philly_workload(seed=2)[:20]
        stream = poisson_arrivals(jobs, rate=0.5, seed=2)
        asg = schedule_online(cluster, stream)
        for j, gpus in asg:
            assert len(np.unique(gpus)) == len(gpus)
            assert np.all(gpus < cluster.num_gpus)


class TestFlashKernelModelPath:
    def test_prefill_matches_jnp_path(self):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                                  compute_dtype="float32")
        cfg_k = dataclasses.replace(cfg, use_flash_kernel=True)
        m = build_model(cfg, 256)
        mk = build_model(cfg_k, 256)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 256), 0, cfg.vocab)}
        a = np.asarray(jax.jit(m.prefill)(params, batch), np.float32)
        b = np.asarray(jax.jit(mk.prefill)(params, batch), np.float32)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
