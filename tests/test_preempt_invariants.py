"""Property-based invariant suite for the preemption primitives.

Drives a random evict / replace / resize / arrival sequence (hypothesis
when available, a seed-sampled fallback otherwise -- the same gate as
``test_substrate``) simultaneously through all three contention engines
and asserts, after EVERY op:

  (a) the engines agree bit-for-bit (U/R clocks, est windows, straddler
      suffix lists, assignment, per-segment quotas), and a fresh state
      replaying the exact op log -- the core of what
      ``Daemon.recover`` does -- rebuilds the incremental state's clocks
      bit-identically;
  (b) no GPU is oversubscribed: per GPU, the committed segment windows
      are pairwise disjoint;
  (c) total residual work is conserved: per job, the segment quotas plus
      any sidelined residual sum back to the submitted F_j;
  (d) a ``refined_rho`` probe equals the post-commit stored rho for
      every placement, on every engine.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import PlacementState, philly_cluster, philly_workload
from repro.core.api import nominal_rho
from repro.core.preempt import _best_candidate, evict, evictable, replace

ENGINES = ("reference", "batched", "incremental")
U_FACTOR = 1.5
THETA = 1e9


def _assert_engines_agree(states):
    ref = states[ENGINES[0]]
    for name in ENGINES[1:]:
        st_ = states[name]
        assert np.array_equal(ref.U, st_.U), name
        assert np.array_equal(ref.R, st_.R), name
        assert ref.est_start == st_.est_start, name
        assert ref.est_finish == st_.est_finish, name
        assert ref.seg_rho == st_.seg_rho, name
        assert ref.seg_start == st_.seg_start, name
        assert ref.seg_quota == st_.seg_quota, name
        assert ref.placed_fin == st_.placed_fin, name
        assert ref._straddle_fin == st_._straddle_fin, name
        assert len(ref.assignment) == len(st_.assignment), name
        for (j1, g1), (j2, g2) in zip(ref.assignment, st_.assignment):
            assert j1 == j2 and np.array_equal(g1, g2), name


def _assert_no_oversubscription(state):
    """Per GPU, the committed segment windows are pairwise disjoint."""
    per_gpu: dict[int, list[tuple[float, float]]] = {}
    for e, (jid, gpus) in enumerate(state.assignment):
        start = state.seg_start[e]
        fin = state.placed_fin[state.seg_row[e]]
        for g in gpus.tolist():
            per_gpu.setdefault(g, []).append((start, fin))
    for g, spans in per_gpu.items():
        spans.sort()
        for (s0, f0), (s1, f1) in zip(spans, spans[1:]):
            assert s1 >= f0 - 1e-9, \
                f"GPU {g} oversubscribed: [{s0},{f0}) overlaps [{s1},{f1})"


def _assert_conservation(state, totals, sidelined):
    """Per job: segment quotas + sidelined residual == submitted F_j."""
    placed: dict[int, float] = {}
    for e, (jid, _) in enumerate(state.assignment):
        placed[jid] = placed.get(jid, 0.0) + state.seg_quota[e]
    for jid, total in totals.items():
        got = placed.get(jid, 0.0) + sidelined.get(jid, 0.0)
        assert got == pytest.approx(total, rel=1e-9), \
            f"job {jid}: {got} != submitted {total}"


def _replay_oplog(cluster, oplog, engine):
    """A fresh state fed the exact recorded ops -- the core-level analogue
    of the service daemon's journal replay."""
    fresh = PlacementState(cluster, engine=engine)
    for op in oplog:
        if op[0] == "advance":
            fresh.advance_to(op[1])
        elif op[0] == "commit":
            _, job, gpus, rho, start = op
            fresh.commit(job, gpus, rho, start, U_FACTOR)
        else:
            _, jid, t, num_gpus = op
            res = evict(fresh, jid, t, U_FACTOR, num_gpus=num_gpus)
            assert res is not None
    return fresh


def _commit_everywhere(states, oplog, job):
    """Place ``job`` via the shared FA-FFP/LBSGF pick on every engine;
    each engine derives its own candidate and they must agree (that IS
    invariant (a)).  Returns False when no engine can place it."""
    picks = {}
    for name, st_ in states.items():
        picks[name] = _best_candidate(st_, job, nominal_rho(st_.cluster, job),
                                      U_FACTOR, THETA)
    ref = picks[ENGINES[0]]
    for name in ENGINES[1:]:
        if ref is None:
            assert picks[name] is None, name
        else:
            fin, gpus, rho, start = ref
            fin2, gpus2, rho2, start2 = picks[name]
            assert (fin, rho, start) == (fin2, rho2, start2), name
            assert np.array_equal(gpus, gpus2), name
    if ref is None:
        return False
    for name, st_ in states.items():
        fin, gpus, rho, start = picks[name]
        # (d) the probe the pick was scored with == what commit stores
        rho_probe, start_probe = st_.refined_rho(job, gpus)
        assert (rho_probe, start_probe) == (rho, start), name
        st_.commit(job, gpus, rho, start, U_FACTOR)
        assert st_.seg_rho[-1] == rho and st_.seg_start[-1] == start, name
        assert st_.est_finish[job.jid] == start + rho, name
    oplog.append(("commit", job, picks[ENGINES[0]][1], ref[2], ref[3]))
    return True


def _run_sequence(seed: int) -> None:
    rng = np.random.default_rng(seed)
    cluster = philly_cluster(3, seed=int(rng.integers(10)))
    jobs = [dataclasses.replace(j, jid=i) for i, j in enumerate(
        philly_workload(seed=int(rng.integers(100)))[:8])]
    states = {e: PlacementState(cluster, engine=e) for e in ENGINES}
    oplog: list[tuple] = []
    totals: dict[int, float] = {}
    sidelined: dict[int, float] = {}
    pending = list(jobs)
    clock = 0.0
    evictions = 0
    for _ in range(24):
        clock += float(rng.integers(0, 40))
        for st_ in states.values():
            st_.advance_to(clock)
        oplog.append(("advance", clock))
        do_arrive = pending and (rng.random() < 0.6 or not states[
            ENGINES[0]].est_finish)
        if do_arrive:
            job = pending.pop(0)
            totals[job.jid] = float(job.iters)
            if not _commit_everywhere(states, oplog, job):
                del totals[job.jid]
        else:
            st0 = states[ENGINES[0]]
            live = sorted(jid for jid, f in st0.est_finish.items()
                          if f > clock + 1e-9
                          and evictable(st0, jid, clock)
                          and jid not in sidelined)
            if not live:
                continue
            victim = live[int(rng.integers(len(live)))]
            vjob = st0.placed_jobs[st0.seg_row[st0._entry_of[victim]]]
            shrink = rng.random() < 0.3 and vjob.num_gpus > 1
            ng = max(1, vjob.num_gpus // 2) if shrink else None
            residuals = {}
            for name, st_ in states.items():
                residuals[name] = evict(st_, victim, clock, U_FACTOR,
                                        num_gpus=ng)
            ref = residuals[ENGINES[0]]
            assert all(r == ref for r in residuals.values())
            assert ref is not None     # evictable() said so
            evictions += 1
            oplog.append(("evict", victim, clock,
                          ng if ng is not None else ref.num_gpus))
            if not _commit_everywhere(states, oplog, ref):
                sidelined[victim] = float(ref.iters)
        _assert_engines_agree(states)
        for st_ in states.values():
            _assert_no_oversubscription(st_)
        _assert_conservation(states[ENGINES[0]], totals, sidelined)
    if evictions == 0:
        # Unlucky draw: force one clean-removal eviction so every seed
        # exercises the primitives.
        big = dataclasses.replace(jobs[0], jid=len(jobs), iters=10**5)
        totals[big.jid] = float(big.iters)
        assert _commit_everywhere(states, oplog, big)
        st0 = states[ENGINES[0]]
        t = st0.seg_start[st0._entry_of[big.jid]]
        for st_ in states.values():
            assert evict(st_, big.jid, t, U_FACTOR) is not None
        oplog.append(("evict", big.jid, t, big.num_gpus))
        sidelined[big.jid] = float(big.iters)
        evictions += 1
        _assert_engines_agree(states)
        _assert_conservation(states[ENGINES[0]], totals, sidelined)
    assert evictions > 0, "sequence never exercised the primitives"
    # (a) the op log rebuilds the live clocks bit-for-bit, on any engine
    live = states["incremental"]
    for engine in ENGINES:
        fresh = _replay_oplog(cluster, oplog, engine)
        assert np.array_equal(fresh.U, live.U), engine
        assert np.array_equal(fresh.R, live.R), engine
        assert fresh.seg_quota == live.seg_quota, engine
        assert fresh._straddle_fin == live._straddle_fin, engine
        assert fresh.est_finish == live.est_finish, engine


def test_replace_respects_budget():
    """replace() refuses a residual that would bust Eq. (16)."""
    cluster = philly_cluster(2, seed=0)
    jobs = philly_workload(seed=0)[:2]
    jobs = [dataclasses.replace(j, jid=i) for i, j in enumerate(jobs)]
    state = PlacementState(cluster)
    assert _best_candidate(state, jobs[0],
                           nominal_rho(cluster, jobs[0]), U_FACTOR, THETA)
    fin, gpus, rho, start = _best_candidate(
        state, jobs[0], nominal_rho(cluster, jobs[0]), U_FACTOR, THETA)
    state.commit(jobs[0], gpus, rho, start, U_FACTOR)
    res = evict(state, 0, rho / 2, U_FACTOR)
    assert res is not None and 0 < res.iters < jobs[0].iters
    tight = float(state.U[gpus].max())          # no headroom at all
    assert not replace(state, res, gpus, tight, U_FACTOR)
    assert replace(state, res, gpus, THETA, U_FACTOR)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_random_preemption_invariants(seed):
        _run_sequence(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 7, 2**31 - 1])
    def test_random_preemption_invariants(seed):
        _run_sequence(seed)
